"""LRAM build-time kernels: Pallas lattice lookup + numpy oracle."""
