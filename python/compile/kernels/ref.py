"""Pure-numpy brute-force oracle for the LRAM lookup.

Deliberately *independent* of the isometry-reduction machinery in
`lattice_tables.py` / `e8.py`: lattice points near a query are found by a
parity-split depth-first enumeration with distance pruning, so a bug in
the reduction or the 232-point table cannot hide in the oracle.
"""

from __future__ import annotations

import math

import numpy as np

from .lattice_tables import kernel_f, torus_index, validate_K

SQRT8 = math.sqrt(8.0)


def ball_points(q: np.ndarray, r2: float = 8.0) -> np.ndarray:
    """All points of Lambda with ||p - q||^2 < r2, by DFS enumeration.

    For each coordinate the admissible integer values of a given parity
    within distance sqrt(r2) are enumerated closest-first; partial
    squared-distance pruning keeps the search tiny (the ball holds at
    most 121 points for r2 = 8).
    """
    q = np.asarray(q, dtype=np.float64)
    r = math.sqrt(r2)
    out: list[list[int]] = []
    for parity in (0, 1):
        cands = []
        for i in range(8):
            lo, hi = math.ceil(q[i] - r), math.floor(q[i] + r)
            vs = [v for v in range(lo, hi + 1) if ((v % 2) + 2) % 2 == parity]
            vs.sort(key=lambda v: abs(v - q[i]))
            cands.append(vs)
        if any(not c for c in cands):
            continue
        acc = [0] * 8

        def dfs(i: int, d2: float, ssum: int) -> None:
            if i == 8:
                if ssum % 4 == 0:
                    out.append(list(acc))
                return
            for v in cands[i]:
                nd2 = d2 + (v - q[i]) ** 2
                if nd2 >= r2:
                    # candidates are sorted by closeness; all later ones
                    # are at least as far, so stop scanning this level.
                    break
                acc[i] = v
                dfs(i + 1, nd2, ssum + v)

        dfs(0, 0.0, 0)
    if not out:
        return np.zeros((0, 8), dtype=np.int64)
    return np.array(sorted(out), dtype=np.int64)


def lookup_all(q: np.ndarray, K) -> tuple[np.ndarray, np.ndarray]:
    """Oracle lookup without top-k truncation.

    Returns ``(idx, w)`` for every lattice point with nonzero kernel
    weight: memory indices (sorted by descending weight) and the weights.
    """
    K = validate_K(K)
    pts = ball_points(q, r2=8.0)
    if len(pts) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    d2 = ((pts - np.asarray(q)[None, :]) ** 2).sum(-1)
    w = kernel_f(d2)
    keep = w > 0
    pts, w = pts[keep], w[keep]
    order = np.argsort(-w, kind="stable")
    return torus_index(pts[order], K), w[order]


def lookup_topk(q: np.ndarray, K, k: int = 32):
    """Oracle lookup truncated to the k highest-weight points (paper's
    k = 32 restriction).  Pads with (0, 0.0) when fewer than k points
    carry weight."""
    idx, w = lookup_all(q, K)
    idx, w = idx[:k], w[:k]
    if len(idx) < k:
        idx = np.pad(idx, (0, k - len(idx)))
        w = np.pad(w, (0, k - len(w)))
    return idx, w


def phi(q: np.ndarray, values: np.ndarray, K, k: int | None = 32) -> np.ndarray:
    """Reference phi(q) = sum_k f(d(q,k)) v_k (optionally top-k truncated)."""
    idx, w = lookup_all(q, K)
    if k is not None:
        idx, w = idx[:k], w[:k]
    if len(idx) == 0:
        return np.zeros(values.shape[1], dtype=values.dtype)
    return (w[:, None] * values[idx]).sum(0)


def theta(z: np.ndarray, values: np.ndarray, K, k: int | None = 32) -> np.ndarray:
    """Reference activation layer theta (paper section 2.3).

    ``z`` is a length-16 real vector interpreted as 8 complex numbers
    (re_1, im_1, ..., re_8, im_8); the torus point is
    q_i = (K_i / 2pi) * arg z_i and the output is scaled by the harmonic
    mean term (sum_i 1/|z_i|)^{-1}.
    """
    K = validate_K(K)
    z = np.asarray(z, dtype=np.float64).reshape(8, 2)
    mag = np.sqrt((z**2).sum(-1))
    if (mag == 0).any():
        return np.zeros(values.shape[1], dtype=values.dtype)
    ang = np.arctan2(z[:, 1], z[:, 0])
    q = K.astype(np.float64) / (2 * math.pi) * ang
    scale = 1.0 / (1.0 / mag).sum()
    return scale * phi(q, values, K, k=k)
