"""L1 Pallas kernel: E8-lattice memory lookup (paper section 2.6).

For a block of query points q in R^8 the kernel

  1. quantizes q to the nearest point x0 of Lambda = 2*E8 (branch-free
     coset decoder);
  2. applies the isometry reduction (translation by x0, then a signed
     permutation with an even number of sign changes) mapping the
     residual into the fundamental region F — the sort is a fixed
     19-comparator Batcher network on 8 lanes so the whole block
     vectorizes with no data-dependent control flow (TPU-friendly; this
     replaces the per-thread scalar loop of the paper's CUDA kernel);
  3. scores the fixed table of 232 candidate lattice points (the only
     points that can lie within the kernel radius sqrt(8) of F) with
     f(r) = max(0, 1 - r^2/8)^4;
  4. keeps the top-32 weights (paper: >= 90% of total weight), maps the
     surviving candidates back through the inverse isometry, and emits
     their O(1) torus memory indices, weights, and the partial
     derivatives dw/dq needed for the custom VJP.

The kernel runs under ``interpret=True`` so it lowers to plain HLO that
the rust PJRT CPU client can execute; on a real TPU the same BlockSpec
tiling applies (see DESIGN.md section "Hardware adaptation").
"""

from __future__ import annotations

import functools
import math
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .lattice_tables import neighbor_table, num_locations, validate_K

#: Batcher odd-even mergesort network for 8 lanes (19 comparators).
SORT_NETWORK = (
    (0, 1), (2, 3), (4, 5), (6, 7),
    (0, 2), (1, 3), (4, 6), (5, 7),
    (1, 2), (5, 6),
    (0, 4), (1, 5), (2, 6), (3, 7),
    (2, 4), (3, 5),
    (1, 2), (3, 4), (5, 6),
)

K_TOP_DEFAULT = 32

#: Candidate-selection implementation (perf A/B; see EXPERIMENTS.md §Perf):
#:   "onehot" — (B,k,232)x(232,8) one-hot contraction (MXU-friendly);
#:   "take"   — plain axis-0 gather (embedding-style; CPU-friendly).
#: Both round-trip through the 0.5.1 HLO parser (the lookup_check
#: integration test verifies whichever is active).
GATHER_IMPL = os.environ.get("LRAM_GATHER_IMPL", "take")


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def topk_desc(x, k: int):
    """Top-k along the last axis, descending: (values, indices).

    Deliberately NOT `jax.lax.top_k`, for two reasons:

    * jax >= 0.8 lowers top_k to an HLO `topk` instruction with a
      `largest` attribute that the bundled xla_extension 0.5.1 text
      parser rejects; a variadic descending `lax.sort` carrying an iota
      payload lowers to a plain `sort`, which round-trips cleanly.
    * sort's builtin JVP routes through a batched gather this jax/jaxlib
      pairing cannot transpose; the custom VJP below scatters the value
      cotangent with a one-hot contraction instead (k is tiny, so the
      one-hot is cheap).
    """
    return _topk_fwd_impl(x, k)


def _topk_fwd_impl(x, k: int):
    """Iterative argmax-and-mask top-k.

    Neither `lax.top_k` (emits a `largest` attribute the 0.5.1 HLO parser
    rejects) nor a variadic `lax.sort` (payload operand miscompiles on the
    0.5.1 PJRT CPU backend — it replicates the max element) survives the
    AOT round-trip, so select the k maxima with k argmax/mask passes:
    only reduce/select ops, which round-trip exactly.  k is 32 and the
    candidate axis is 232, so the cost is negligible.
    """

    vals, idxs = [], []
    w = x
    for _ in range(k):  # unrolled: no while-loop in the lowered HLO
        i = jnp.argmax(w, axis=-1).astype(jnp.int32)
        v = jnp.max(w, axis=-1)
        onehot = jax.nn.one_hot(i, w.shape[-1], dtype=w.dtype)
        w = jnp.where(onehot > 0, -1e30, w)
        vals.append(v)
        idxs.append(i)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _topk_fwd(x, k: int):
    vals, idx = _topk_fwd_impl(x, k)
    return (vals, idx), (idx, x.shape[-1])


def _topk_bwd(k: int, res, cts):
    idx, n = res
    val_ct, _ = cts  # index cotangent is float0
    onehot = jax.nn.one_hot(idx, n, dtype=val_ct.dtype)  # (..., k, n)
    x_bar = jnp.einsum("...k,...kn->...n", val_ct, onehot)
    return (x_bar,)


topk_desc.defvjp(_topk_fwd, _topk_bwd)


def _decode_d8(y):
    """Nearest point of D8 to y, branch-free, batched over rows.

    NOTE (AOT portability): this file avoids `take_along_axis`-style
    batched gathers everywhere — jax 0.8 lowers them with
    operand_batching_dims, which the bundled xla_extension 0.5.1 parses
    but miscompiles (it broadcasts row 0).  One-hot contractions are used
    instead; they also map better onto the TPU MXU (see DESIGN.md
    "Hardware adaptation").
    """
    f = jnp.round(y)
    err = y - f
    worst = jnp.argmax(jnp.abs(err), axis=-1)
    onehot = jax.nn.one_hot(worst, 8, dtype=y.dtype)
    worst_err = jnp.sum(onehot * err, axis=-1, keepdims=True)  # gather-free
    step = jnp.where(worst_err >= 0, 1.0, -1.0)
    g = f + onehot * step
    odd = (jnp.sum(f, axis=-1).astype(jnp.int32) % 2) != 0
    return jnp.where(odd[:, None], g, f)


def _quantize(q):
    """Nearest point of Lambda = 2D8 u (2D8 + 1) to q."""
    even = 2.0 * _decode_d8(q / 2.0)
    odd = 2.0 * _decode_d8((q - 1.0) / 2.0) + 1.0
    de = jnp.sum((q - even) ** 2, axis=-1)
    do = jnp.sum((q - odd) ** 2, axis=-1)
    return jnp.where((de <= do)[:, None], even, odd)


def _sort_desc_tracked(t, s):
    """Sort |r| descending with the fixed comparator network, tracking the
    coordinate index and sign lanes alongside the key lane.

    t: (B, 8) keys (absolute residuals), s: (B, 8) signs (+-1 float).
    Returns (t_sorted, perm, s_sorted)."""
    p = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), t.shape)
    for i, j in SORT_NETWORK:
        ti, tj = t[:, i], t[:, j]
        swap = ti < tj  # descending
        t = t.at[:, i].set(jnp.where(swap, tj, ti)).at[:, j].set(jnp.where(swap, ti, tj))
        pi, pj = p[:, i], p[:, j]
        p = p.at[:, i].set(jnp.where(swap, pj, pi)).at[:, j].set(jnp.where(swap, pi, pj))
        si, sj = s[:, i], s[:, j]
        s = s.at[:, i].set(jnp.where(swap, sj, si)).at[:, j].set(jnp.where(swap, si, sj))
    return t, p, s


def _torus_index_i32(u, K):
    """O(1) memory index of integer lattice points u (B, k, 8) int32.

    K is a static Python tuple, so all divisor arithmetic folds to scalar
    constants (no captured array constants — pallas requirement)."""
    p = jnp.remainder(u[..., 0], 2)
    y = (u - p[..., None]) >> 1
    # jnp.remainder's sign follows the divisor, so m_i is already >= 0
    m = [jnp.remainder(y[..., i], int(K[i]) // 2) for i in range(8)]
    s = jnp.remainder(sum(m[:7]), 2)
    t = (m[7] - s) >> 1
    idx = p
    for i in range(7):
        idx = idx * (int(K[i]) // 2) + m[i]
    return idx * (int(K[7]) // 4) + t


def _lookup_block(q, nbr, K, k_top):
    """The kernel body on a (B, 8) block; pure jnp so it can run either
    inside pallas_call or directly (both paths are tested against the
    oracle and each other).

    Gather-free by construction (one-hot contractions instead of batched
    gathers): both an AOT-portability requirement and the natural MXU
    formulation on TPU — the permutation application becomes an 8x8
    matmul per query and the candidate selection a (k x 232) matmul.
    """
    q = q.astype(jnp.float32)
    x0 = _quantize(q)
    r = q - x0
    t, perm, s = _sort_desc_tracked(jnp.abs(r), jnp.where(r < 0, -1.0, 1.0))
    # parity fix: even number of sign flips (last lane absorbs the parity)
    nneg = jnp.sum((s < 0).astype(jnp.int32), axis=-1) % 2
    eps = s.at[:, 7].set(jnp.where(nneg == 1, -s[:, 7], s[:, 7]))
    # rs[j] = r[perm[j]] = s[j] * t[j]  (sign and magnitude travelled
    # through the sorting network together — no gather needed)
    z = t.at[:, 7].set(eps[:, 7] * s[:, 7] * t[:, 7])

    # score all 232 candidates in the reduced frame (isometry-invariant)
    nbrf = nbr.astype(jnp.float32)  # (232, 8)
    d2 = jnp.sum((z[:, None, :] - nbrf[None, :, :]) ** 2, axis=-1)  # (B, 232)
    w_all = jnp.maximum(0.0, 1.0 - d2 / 8.0) ** 4

    w, sel = topk_desc(w_all, k_top)  # (B, k_top)

    # selected candidates: (B, k, 8) in the reduced frame
    if GATHER_IMPL == "take":
        csel = jnp.take(nbrf, sel, axis=0)  # plain axis-0 gather
    else:
        sel_oh = jax.nn.one_hot(sel, nbr.shape[0], dtype=jnp.float32)
        csel = jnp.einsum("bkc,ci->bki", sel_oh, nbrf)

    # inverse isometry: u[b, s, perm[b, j]] = x0 + eps[b, j] * csel[b, s, j]
    # as a permutation-matrix contraction P[b, j, i] = 1{perm[b, j] = i}
    pmat = jax.nn.one_hot(perm, 8, dtype=jnp.float32)  # (B, 8, 8)
    signed = eps[:, None, :] * csel  # (B, k, 8) in sorted-lane order
    u_f = x0[:, None, :] + jnp.einsum("bkj,bji->bki", signed, pmat)
    u = jnp.round(u_f).astype(jnp.int32)  # exact: all integers

    idx = _torus_index_i32(u, K)

    # dw/dq = -(1 - d^2/8)^3 * (q - u); note (1 - d^2/8)^3 = w^(3/4) for
    # w > 0, which avoids re-gathering the selected distances
    base = jnp.power(jnp.maximum(w, 0.0), 0.75)  # (B, k)
    diff = q[:, None, :] - u_f  # (B, k, 8)
    dwdq = -base[:, :, None] * diff
    return idx, w, dwdq


def _pallas_kernel(q_ref, nbr_ref, idx_ref, w_ref, dw_ref, *, K, k_top):
    idx, w, dwdq = _lookup_block(q_ref[...], nbr_ref[...], K, k_top)
    idx_ref[...] = idx
    w_ref[...] = w
    dw_ref[...] = dwdq


def _round_up(n, b):
    return (n + b - 1) // b * b


@partial(jax.jit, static_argnums=(1, 2, 3, 4))
def e8_lookup(q, K: tuple, k_top: int = K_TOP_DEFAULT, block_q: int = 128,
              use_pallas: bool = True):
    """Lattice lookup for a batch of torus queries.

    Args:
      q: (B, 8) float32 query points in the Lambda coordinate frame.
      K: static 8-tuple of torus periods, each a multiple of 4.
      k_top: number of nearest lattice points kept (paper: 32).
      block_q: pallas block size along the batch dimension.
      use_pallas: route through pallas_call (interpret mode) or run the
        identical jnp body directly.

    Returns:
      idx: (B, k_top) int32 memory indices in [0, M);
      w:   (B, k_top) float32 kernel weights (descending);
      dwdq:(B, k_top, 8) float32 partial derivatives dw_i/dq_j.
    """
    Kv = validate_K(K)
    if num_locations(Kv) >= 2**31:
        raise ValueError("M must fit in int32 for the in-kernel index math")
    nbr = jnp.asarray(neighbor_table(), dtype=jnp.int32)
    B = q.shape[0]
    if not use_pallas:
        return _lookup_block(q, nbr, tuple(int(k) for k in Kv), k_top)

    Bp = _round_up(max(B, 1), block_q)
    qp = jnp.pad(q, ((0, Bp - B), (0, 0)))
    grid = (Bp // block_q,)
    idx, w, dwdq = pl.pallas_call(
        functools.partial(
            _pallas_kernel, K=tuple(int(k) for k in Kv), k_top=k_top
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 8), lambda i: (i, 0)),
            # the 232-point table is replicated into every block (the
            # analogue of CUDA constant memory)
            pl.BlockSpec((232, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k_top), lambda i: (i, 0)),
            pl.BlockSpec((block_q, k_top), lambda i: (i, 0)),
            pl.BlockSpec((block_q, k_top, 8), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k_top), jnp.int32),
            jax.ShapeDtypeStruct((Bp, k_top), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k_top, 8), jnp.float32),
        ],
        interpret=True,
    )(qp, nbr)
    return idx[:B], w[:B], dwdq[:B]


# ---------------------------------------------------------------------------
# Differentiable wrapper (the paper's "autograd-compatible wrapper")
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def lattice_lookup(q, K: tuple, k_top: int = K_TOP_DEFAULT, block_q: int = 128,
                   use_pallas: bool = True):
    """Differentiable (idx, w) lookup; gradients flow into q through the
    kernel-supplied dw/dq exactly as in the paper's CUDA wrapper."""
    idx, w, _ = e8_lookup(q, K, k_top, block_q, use_pallas)
    return idx, w


def _lookup_fwd(q, K, k_top, block_q, use_pallas):
    idx, w, dwdq = e8_lookup(q, K, k_top, block_q, use_pallas)
    return (idx, w), dwdq


def _lookup_bwd(K, k_top, block_q, use_pallas, dwdq, cts):
    _, w_ct = cts
    q_bar = jnp.einsum("bk,bki->bi", w_ct, dwdq)
    return (q_bar,)


lattice_lookup.defvjp(_lookup_fwd, _lookup_bwd)


# ---------------------------------------------------------------------------
# Full memory layer pieces used by the L2 model
# ---------------------------------------------------------------------------


def phi(q, values, K: tuple, k_top: int = K_TOP_DEFAULT, block_q: int = 128,
        use_pallas: bool = True):
    """phi(q) = sum over the k_top nearest lattice points of f(d) * v
    (differentiable in both q and values)."""
    idx, w = lattice_lookup(q, K, k_top, block_q, use_pallas)
    gathered = jnp.take(values, idx, axis=0)  # (B, k, m)
    return jnp.einsum("bk,bkm->bm", w, gathered)


def theta(z, values, K: tuple, k_top: int = K_TOP_DEFAULT, block_q: int = 128,
          use_pallas: bool = True, eps: float = 1e-6):
    """The activation layer (paper section 2.3).

    z: (B, 16) float32, interpreted as 8 complex numbers per row
    (re_1, im_1, ..., re_8, im_8).  Output: (B, m), positively
    homogeneous in z: theta(l*z) = l*theta(z) for l >= 0.
    """
    Kv = validate_K(K)
    zc = z.reshape(z.shape[0], 8, 2)
    mag = jnp.sqrt(jnp.sum(zc**2, axis=-1) + eps * eps)
    ang = jnp.arctan2(zc[..., 1], zc[..., 0])
    q = jnp.asarray(Kv, dtype=jnp.float32) / (2 * math.pi) * ang
    scale = 1.0 / jnp.sum(1.0 / mag, axis=-1)  # harmonic-mean term
    out = phi(q, values, tuple(int(k) for k in Kv), k_top, block_q, use_pallas)
    return scale[:, None] * out
