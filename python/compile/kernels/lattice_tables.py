"""Shared lattice math for the LRAM kernel (build-time, pure numpy).

Implements the scaled E8 lattice of Goucher & Troll (2021), section 2:

    Lambda = { x in (2Z)^8 u (2Z+1)^8 : sum(x) = 0 mod 4 }  (= 2*E8)

with packing radius sqrt(2), covering radius 2 and minimal vector norm
sqrt(8).  Provides:

  * `decode_d8` / `quantize` — nearest-point decoder (Conway-Sloane coset
    decoding over Lambda = 2*D8 u (2*D8 + 1));
  * `reduce_batch` — the paper's isometry reduction into the fundamental
    region F = { z1 >= ... >= z7 >= |z8|, z1+z2 <= 2, sum(z) <= 4 };
  * `neighbor_table` — the fixed table of the exactly 232 lattice points
    within distance < sqrt(8) of F (paper section 2.6), computed once via
    Dykstra projections onto F's halfspaces;
  * `kernel_f` — the compact kernel f(r) = max(0, 1 - r^2/8)^4;
  * `torus_index` / `torus_index_inverse` — the O(1) bijection
    Lambda / L_K -> [0, M) used to address memory slots, where
    L_K = prod(K_i Z) with K_i in 4Z and M = prod(K_i) / 256.

Everything here is mirrored in rust/src/lattice/ and cross-checked through
artifacts/lattice_fixture.json (see python/tests/test_fixture.py).
"""

from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

SQRT8 = math.sqrt(8.0)
#: determinant of Lambda = 2*E8  (2^8 * det E8 = 256)
DET_LAMBDA = 256
#: number of lattice points within distance < sqrt(8) of F (paper: 232)
N_NEIGHBORS = 232
#: paper section 2.5: lower bound on the total kernel weight
TOTAL_WEIGHT_LOWER = (22158 - 625 * math.sqrt(5)) / 24389

# ---------------------------------------------------------------------------
# Quantizer
# ---------------------------------------------------------------------------


def decode_d8(y: np.ndarray) -> np.ndarray:
    """Nearest point of D8 = { y in Z^8 : sum(y) even } to `y`.

    Standard Conway-Sloane decoder: round every coordinate; if the sum of
    the rounded point is odd, re-round the coordinate with the largest
    rounding error in the opposite direction.  Vectorized over any number
    of leading batch dimensions.
    """
    y = np.asarray(y, dtype=np.float64)
    f = np.round(y)
    err = y - f
    worst = np.argmax(np.abs(err), axis=-1)
    g = f.copy()
    sel = tuple(np.indices(worst.shape)) + (worst,)
    g[sel] = f[sel] + np.where(err[sel] >= 0, 1.0, -1.0)
    odd = (f.sum(-1).astype(np.int64) % 2) != 0
    return np.where(odd[..., None], g, f)


def quantize(q: np.ndarray) -> np.ndarray:
    """Nearest point of Lambda to `q` (ties broken toward the even coset)."""
    q = np.asarray(q, dtype=np.float64)
    even = 2.0 * decode_d8(q / 2.0)
    odd = 2.0 * decode_d8((q - 1.0) / 2.0) + 1.0
    de = ((q - even) ** 2).sum(-1)
    do = ((q - odd) ** 2).sum(-1)
    return np.where((de <= do)[..., None], even, odd)


def is_lattice_point(x) -> bool:
    """Membership test for Lambda."""
    x = np.asarray(x, dtype=np.int64)
    par = ((x % 2) + 2) % 2
    return bool((par == par[..., :1]).all() and int(x.sum()) % 4 == 0)


# ---------------------------------------------------------------------------
# Isometry reduction into the fundamental region F
# ---------------------------------------------------------------------------


def reduce_batch(q: np.ndarray):
    """Map each query into the fundamental region F.

    Returns ``(x0, perm, eps, z)`` where ``x0`` is the nearest lattice
    point, and ``z[j] = eps[j] * (q - x0)[perm[j]]`` lies in F.  ``eps``
    has an even number of -1 entries (modulo sign flips on exactly-zero
    coordinates, which are numerically irrelevant), so the signed
    permutation is a symmetry of Lambda.
    """
    q = np.asarray(q, dtype=np.float64)
    x0 = quantize(q)
    r = q - x0
    a = np.abs(r)
    perm = np.argsort(-a, axis=-1, kind="stable")
    t = np.take_along_axis(a, perm, axis=-1)
    rs = np.take_along_axis(r, perm, axis=-1)
    eps = np.where(rs < 0, -1.0, 1.0)
    # parity fix: if an odd number of signs were flipped, un-flip the last
    # (smallest-magnitude) coordinate so the sign change count is even.
    nneg = (rs < 0).sum(-1) % 2
    eps[..., 7] = np.where(nneg == 1, -eps[..., 7], eps[..., 7])
    z = t.copy()
    z[..., 7] = eps[..., 7] * rs[..., 7]
    return x0, perm, eps, z


def in_fundamental_region(z: np.ndarray, tol: float = 1e-9) -> bool:
    z = np.asarray(z, dtype=np.float64)
    mono = (z[..., :6] >= z[..., 1:7] - tol).all()
    last = (z[..., 6] >= np.abs(z[..., 7]) - tol).all()
    edge = (z[..., 0] + z[..., 1] <= 2 + tol).all()
    ssum = (z.sum(-1) <= 4 + tol).all()
    return bool(mono and last and edge and ssum)


# ---------------------------------------------------------------------------
# The 232-point neighbour table
# ---------------------------------------------------------------------------

#: Halfspaces a.z <= b whose intersection is F.
_F_HALFSPACES_A = np.array(
    [[0] * i + [-1, 1] + [0] * (6 - i) for i in range(6)]
    + [
        [0, 0, 0, 0, 0, 0, -1, 1],
        [0, 0, 0, 0, 0, 0, -1, -1],
        [1, 1, 0, 0, 0, 0, 0, 0],
        [1, 1, 1, 1, 1, 1, 1, 1],
    ],
    dtype=np.float64,
)
_F_HALFSPACES_B = np.array([0.0] * 8 + [2.0, 4.0])


def dist_to_F(p: np.ndarray, iters: int = 800) -> np.ndarray:
    """Distance from each row of `p` to F via Dykstra's projection onto the
    intersection of F's halfspaces.  Vectorized over rows."""
    p = np.atleast_2d(np.asarray(p, dtype=np.float64))
    A, b = _F_HALFSPACES_A, _F_HALFSPACES_B
    an = (A * A).sum(1)
    x = p.copy()
    y = np.zeros((len(A),) + p.shape)
    for _ in range(iters):
        for k in range(len(A)):
            w = x + y[k]
            viol = np.maximum(w @ A[k] - b[k], 0.0)
            x = w - (viol / an[k])[:, None] * A[k][None, :]
            y[k] = w - x
    return np.sqrt(((p - x) ** 2).sum(-1))


def _enumerate_candidates() -> np.ndarray:
    """All points of Lambda with |p| <= sqrt(24); superset of every point
    within sqrt(8) of F (F's circumradius is the covering radius 2, and
    (sqrt(8) + 2)^2 < 24)."""
    import itertools

    out = []
    for vals in ((-4, -2, 0, 2, 4), (-3, -1, 1, 3)):
        for tup in itertools.product(vals, repeat=8):
            if sum(v * v for v in tup) <= 24 and sum(tup) % 4 == 0:
                out.append(tup)
    return np.array(out, dtype=np.int64)


@lru_cache(maxsize=1)
def neighbor_table() -> np.ndarray:
    """The (232, 8) int table of all lattice points within < sqrt(8) of F,
    in canonical (lexicographic) order.  Matches the paper's QP count."""
    cand = _enumerate_candidates()
    d = dist_to_F(cand.astype(np.float64))
    nbr = cand[d < SQRT8 - 1e-6]
    assert len(nbr) == N_NEIGHBORS, f"expected 232 neighbours, got {len(nbr)}"
    order = np.lexsort(nbr.T[::-1])
    return np.ascontiguousarray(nbr[order])


# ---------------------------------------------------------------------------
# Kernel and lookup reference
# ---------------------------------------------------------------------------


def kernel_f(d2: np.ndarray) -> np.ndarray:
    """f(r) = max(0, 1 - r^2/8)^4 expressed in terms of r^2."""
    return np.maximum(0.0, 1.0 - np.asarray(d2) / 8.0) ** 4


def candidates_for(q: np.ndarray):
    """For a batch of queries, return original-frame candidate lattice
    points ``u`` (B, 232, 8) and squared distances ``d2`` (B, 232)."""
    q = np.atleast_2d(np.asarray(q, dtype=np.float64))
    x0, perm, eps, z = reduce_batch(q)
    nbr = neighbor_table().astype(np.float64)
    d2 = ((z[:, None, :] - nbr[None, :, :]) ** 2).sum(-1)
    # u[b, c, perm[b, j]] = x0[b, perm[b, j]] + eps[b, j] * nbr[c, j]
    B = q.shape[0]
    u = np.empty((B, nbr.shape[0], 8), dtype=np.float64)
    rows = np.arange(B)[:, None]
    u[rows, :, perm] = (
        np.take_along_axis(x0, perm, axis=-1)[:, :, None]
        + (eps[:, :, None] * nbr.T[None, :, :])
    )
    return u, d2


# ---------------------------------------------------------------------------
# Torus memory indexing
# ---------------------------------------------------------------------------


def validate_K(K) -> np.ndarray:
    K = np.asarray(K, dtype=np.int64)
    if K.shape != (8,):
        raise ValueError("K must have 8 entries")
    if (K % 4 != 0).any() or (K < 4).any():
        raise ValueError("each K_i must be a positive multiple of 4 so that L_K <= Lambda")
    return K


def num_locations(K) -> int:
    """M = |Lambda / L_K| = prod(K) / det(Lambda)."""
    K = validate_K(K)
    return int(np.prod(K) // DET_LAMBDA)


def torus_index(x: np.ndarray, K) -> np.ndarray:
    """O(1) bijection Lambda/L_K -> [0, M).

    Writes x = 2y + p with parity bit p and y in D8; packs p, y_1..y_7
    (mod K_i/2, mixed radix) and y_8 (mod K_8/4 after removing its parity,
    which is determined by y_1..y_7 because sum(y) is even).
    """
    K = validate_K(K)
    x = np.asarray(np.rint(x), dtype=np.int64)
    p = ((x[..., 0] % 2) + 2) % 2
    y = (x - p[..., None]) >> 1
    kh = K // 2
    m = ((y % kh) + kh) % kh
    s = m[..., :7].sum(-1) % 2
    t = (m[..., 7] - s) >> 1
    idx = p
    for i in range(7):
        idx = idx * kh[i] + m[..., i]
    return idx * (K[7] // 4) + t


def torus_index_inverse(idx, K) -> np.ndarray:
    """Canonical representative of memory slot `idx` (vectorized)."""
    K = validate_K(K)
    idx = np.asarray(idx, dtype=np.int64).copy()
    kh = K // 2
    t = idx % (K[7] // 4)
    idx //= K[7] // 4
    m = np.zeros(idx.shape + (8,), dtype=np.int64)
    for i in range(6, -1, -1):
        m[..., i] = idx % kh[i]
        idx //= kh[i]
    p = idx
    s = m[..., :7].sum(-1) % 2
    m[..., 7] = 2 * t + s
    return 2 * m + p[..., None]
