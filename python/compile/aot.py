"""AOT export: lower L2/L1 computations once to HLO text + manifests.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact `<name>.hlo.txt` ships with `<name>.meta.json` describing
its positional inputs/outputs:

  role "state":  fed back from the matching leading outputs step-to-step
                 (params, Adam moments, BatchNorm running stats);
  role "input":  fresh each call (token batches, step counter);
  outputs:       first len(state) entries are the new state, the rest are
                 results (loss, logits, access indices, ...).

Initial state tensors are written to `<variant>.state.bin` as raw
little-endian bytes in manifest order.

Usage:  python -m compile.aot --out ../artifacts [--sets core,micro,extra]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import lattice_tables as lt

# ---------------------------------------------------------------------------
# Model variants (scaled-down geometry; see DESIGN.md "Substitutions")
# ---------------------------------------------------------------------------

BASE = dict(vocab_size=4096, width=192, n_layers=4, n_heads=4, seq_len=96)

#: K tuples and their slot counts M = prod(K)/256 (verified in pytest):
K_2_14 = (8, 8, 8, 8, 8, 8, 4, 4)  # 2^14 locations
K_2_16 = (8, 8, 8, 8, 8, 8, 8, 8)  # 2^16
K_2_17 = (8, 8, 8, 8, 8, 8, 8, 16)  # 2^17
K_2_18 = (16, 16, 8, 8, 8, 8, 8, 8)  # 2^18  (paper's LRAM-small)
K_2_20 = (16, 16, 16, 16, 8, 8, 8, 8)  # 2^20  (paper's LRAM-medium)
K_2_22 = (16, 16, 16, 16, 16, 16, 8, 8)  # 2^22  (paper's LRAM-large)
K_2_24 = (16,) * 8  # 2^24


def variants(paper_scale: bool = False) -> dict[str, M.ModelConfig]:
    """Scaled-down slot counts by default (small 2^14 / medium 2^16 /
    large 2^18); --paper-scale restores the paper's 2^18 / 2^20 / 2^22."""
    if paper_scale:
        ks, km, kl = K_2_18, K_2_20, K_2_22
    else:
        ks, km, kl = K_2_14, K_2_16, K_2_18
    mk = lambda **kw: M.ModelConfig(**{**BASE, **kw}).validate()
    return {
        "baseline": mk(memory="none"),
        "lram_small": mk(memory="lram", lram_K=ks),
        "lram_medium": mk(memory="lram", lram_K=km),
        "lram_large": mk(memory="lram", lram_K=kl),
        "pkm": mk(memory="pkm", pkm_n_keys=128, pkm_heads=4, pkm_topk=32),
        # paper section 6 (future work): two layers reading ONE shared table
        "lram_shared": mk(memory="lram", lram_K=km, mem_layers=(1, 2)),
    }


TRAIN_BATCH = 8
EVAL_BATCH = 8
SERVE_BATCH = 4


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is LOAD-BEARING: the default elides big
    # literals as "{...}", which the 0.5.1-era HLO text parser silently
    # reads back as zeros — the 232-point lattice table would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def _dtype_tag(x) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32",
            "float64": "f64", "int64": "i64"}[str(np.asarray(x).dtype)]


def _leaf_names(tree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in flat:
        parts = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        names.append("/".join(parts))
    return names


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.index: list[dict] = []

    def export(self, name: str, fn, state_tree, inputs, extra_meta=None,
               n_result_outputs=None):
        """Lower fn(state_leaves..., input_leaves...) and write artifact +
        manifest.  fn must return (new_state_leaves..., results...).

        `inputs` is an ORDERED list of (name, example_array) pairs — the
        positional input order seen by the rust runtime is exactly this
        list (dicts would silently flatten in sorted-key order, which
        bit us once; never again).
        """
        t0 = time.time()
        state_leaves, state_def = jax.tree_util.tree_flatten(state_tree)
        input_names = [n for n, _ in inputs]
        input_leaves = [a for _, a in inputs]
        ns, ni = len(state_leaves), len(input_leaves)

        def flat_fn(*flat):
            st = jax.tree_util.tree_unflatten(state_def, flat[:ns])
            inp = dict(zip(input_names, flat[ns:]))
            return fn(st, inp)

        specs = [
            jax.ShapeDtypeStruct(np.asarray(x).shape, np.asarray(x).dtype)
            for x in state_leaves + input_leaves
        ]
        lowered = jax.jit(flat_fn).lower(*specs)
        text = to_hlo_text(lowered)
        hlo_path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(text)

        out_tree = jax.eval_shape(flat_fn, *specs)
        out_leaves = jax.tree_util.tree_leaves(out_tree)
        meta = {
            "artifact": f"{name}.hlo.txt",
            "state": [
                {"name": n, "shape": list(np.asarray(x).shape), "dtype": _dtype_tag(x)}
                for n, x in zip(_leaf_names(state_tree), state_leaves)
            ],
            "inputs": [
                {"name": n, "shape": list(np.asarray(x).shape), "dtype": _dtype_tag(x)}
                for n, x in zip(input_names, input_leaves)
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_tag(jnp.zeros((), o.dtype))}
                for o in out_leaves
            ],
            "n_state_outputs": ns if n_result_outputs is None
            else len(out_leaves) - n_result_outputs,
        }
        if extra_meta:
            meta.update(extra_meta)
        with open(os.path.join(self.out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        self.index.append({"name": name, "bytes": len(text)})
        print(f"  [{time.time()-t0:6.1f}s] {name}: {len(text)/1e6:.2f} MB hlo, "
              f"{ns} state + {ni} inputs -> {len(out_leaves)} outputs")
        return meta

    def write_state_bin(self, name: str, state_tree):
        leaves = jax.tree_util.tree_leaves(state_tree)
        path = os.path.join(self.out_dir, f"{name}.state.bin")
        with open(path, "wb") as f:
            for x in leaves:
                f.write(np.ascontiguousarray(np.asarray(x)).tobytes())
        sz = os.path.getsize(path)
        print(f"  wrote {name}.state.bin ({sz/1e6:.1f} MB)")


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


def full_state(cfg: M.ModelConfig, seed: int = 0):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    return {
        "params": params,
        "opt": M.init_opt_state(params),
        "bn": M.init_bn_state(cfg),
    }


def _memory_meta(cfg: M.ModelConfig) -> dict:
    if cfg.memory == "lram":
        return {"locations": cfg.lram_locations, "k_top": cfg.lram_k_top,
                "heads": cfg.lram_heads, "m": cfg.lram_m}
    if cfg.memory == "pkm":
        return {"locations": cfg.pkm_n, "k_top": cfg.pkm_topk,
                "heads": cfg.pkm_heads, "n_keys": cfg.pkm_n_keys}
    return {}


def export_training(w: ArtifactWriter, name: str, cfg: M.ModelConfig,
                    write_init: bool, B: int = TRAIN_BATCH):
    S = cfg.seq_len
    state = full_state(cfg)
    batch = [
        ("step", jnp.zeros((), jnp.int32)),
        ("tokens", jnp.zeros((B, S), jnp.int32)),
        ("targets", jnp.zeros((B, S), jnp.int32)),
        ("weights", jnp.zeros((B, S), jnp.float32)),
    ]

    def step_fn(st, inp):
        p, o, bn, loss = M.train_step(
            st["params"], st["opt"], st["bn"], inp["step"],
            inp["tokens"], inp["targets"], inp["weights"], cfg,
        )
        new_state = {"params": p, "opt": o, "bn": bn}
        return tuple(jax.tree_util.tree_leaves(new_state)) + (loss,)

    w.export(
        f"train_step_{name}", step_fn, state, batch,
        extra_meta={"kind": "train_step", "variant": name,
                    "batch": {"B": B, "S": S},
                    "config": dataclasses.asdict(cfg),
                    "n_params": M.count_params(state["params"]),
                    **_memory_meta(cfg)},
        n_result_outputs=1,
    )

    def eval_fn(st, inp):
        collect = cfg.memory in ("lram", "pkm")
        out = M.eval_loss(st["params"], st["bn"], inp["tokens"],
                          inp["targets"], inp["weights"], cfg,
                          collect_access=collect)
        return tuple(jax.tree_util.tree_leaves(st)) + tuple(out)

    eval_batch = [(k, v) for k, v in batch if k != "step"]
    nres = 4 if cfg.memory in ("lram", "pkm") else 2
    w.export(
        f"eval_loss_{name}", eval_fn, state, eval_batch,
        extra_meta={"kind": "eval_loss", "variant": name,
                    "batch": {"B": B, "S": S},
                    "access_outputs": cfg.memory in ("lram", "pkm"),
                    **_memory_meta(cfg)},
        n_result_outputs=nres,
    )

    def infer_fn(st, inp):
        logits, _, _ = M.forward(st["params"], inp["tokens"], cfg, st["bn"],
                                 train=False)
        return tuple(jax.tree_util.tree_leaves(st)) + (
            jax.nn.log_softmax(logits, axis=-1),
        )

    w.export(
        f"infer_logits_{name}", infer_fn, state,
        [("tokens", jnp.zeros((SERVE_BATCH, S), jnp.int32))],
        extra_meta={"kind": "infer_logits", "variant": name,
                    "batch": {"B": SERVE_BATCH, "S": S}},
        n_result_outputs=1,
    )

    if write_init:
        w.write_state_bin(name, state)


def export_micro(w: ArtifactWriter, widths=(256, 512, 1024, 2048),
                 lram_Ks=(K_2_14, K_2_18, K_2_22, K_2_24),
                 pkm_keys=(64, 128, 256, 512, 1024, 2048), B: int = 64):
    """Layer microbenches for Table 4 and Figure 3.

    All phases take x (B, w) batches.  The value-table gather lives in the
    rust memstore (split mode), so the LRAM artifacts are N-independent
    except for the index arithmetic baked in via K.
    """
    rng = jax.random.PRNGKey(1)

    for wd in widths:
        # ---- dense w -> 4w -> w (the replaced subnetwork) ----
        p = {
            "in": M._dense_init(rng, wd, 4 * wd),
            "out": M._dense_init(rng, 4 * wd, wd),
        }

        def dense_fn(st, inp):
            return tuple(jax.tree_util.tree_leaves(st)) + (
                M.dense_ffn_layer(inp["x"], st),
            )

        w.export(
            f"micro_dense_w{wd}", dense_fn, p,
            [("x", jnp.zeros((B, wd), jnp.float32))],
            extra_meta={"kind": "micro_dense", "width": wd, "batch": {"B": B},
                        "n_params": M.count_params(p)},
            n_result_outputs=1,
        )

        # ---- LRAM prefix (per K) + one suffix ----
        for K in lram_Ks:
            cfg = M.ModelConfig(**{**BASE, "width": wd, "memory": "lram",
                                   "lram_K": K}).validate()
            pp = {
                "query": M._dense_init(rng, wd, wd),
                "bn": {"g": jnp.ones((wd,)), "b": jnp.zeros((wd,))},
            }
            bn = {"mean": jnp.zeros((wd,)), "var": jnp.ones((wd,))}

            def prefix_fn(st, inp, cfg=cfg):
                idx, wts, scale = M.lram_layer_prefix(
                    inp["x"], st["p"], cfg, st["bn"]
                )
                return tuple(jax.tree_util.tree_leaves(st)) + (idx, wts, scale)

            nloc = lt.num_locations(K)
            w.export(
                f"micro_lram_prefix_w{wd}_n{nloc}", prefix_fn,
                {"p": pp, "bn": bn}, [("x", jnp.zeros((B, wd), jnp.float32))],
                extra_meta={"kind": "micro_lram_prefix", "width": wd,
                            "locations": nloc, "K": list(K),
                            "heads": cfg.lram_heads, "k_top": cfg.lram_k_top,
                            "m": cfg.lram_m, "batch": {"B": B}},
                n_result_outputs=3,
            )

        cfg = M.ModelConfig(**{**BASE, "width": wd, "memory": "lram",
                               "lram_K": lram_Ks[0]}).validate()
        h, kt, m = cfg.lram_heads, cfg.lram_k_top, cfg.lram_m
        ps = {"out": M._dense_init(rng, 4 * wd, wd)}

        def suffix_fn(st, inp, cfg=cfg):
            y = M.lram_layer_suffix(inp["gathered"], inp["w"], inp["scale"],
                                    st, cfg)
            return tuple(jax.tree_util.tree_leaves(st)) + (y,)

        w.export(
            f"micro_lram_suffix_w{wd}", suffix_fn, ps,
            [
                ("gathered", jnp.zeros((B, h, kt, m), jnp.float32)),
                ("w", jnp.zeros((B, h, kt), jnp.float32)),
                ("scale", jnp.zeros((B, h), jnp.float32)),
            ],
            extra_meta={"kind": "micro_lram_suffix", "width": wd,
                        "batch": {"B": B}},
            n_result_outputs=1,
        )

        # ---- PKM score (per sqrt(N)) + one combine ----
        for nk in pkm_keys:
            cfg = M.ModelConfig(**{**BASE, "width": wd, "memory": "pkm",
                                   "pkm_n_keys": nk}).validate()
            hd, dk = cfg.pkm_heads, cfg.pkm_dk
            pp = {
                "query": M._dense_init(rng, wd, hd * dk),
                "bn": {"g": jnp.ones((hd * dk,)), "b": jnp.zeros((hd * dk,))},
                "keys1": jnp.zeros((hd, nk, dk // 2), jnp.float32),
                "keys2": jnp.zeros((hd, nk, dk // 2), jnp.float32),
            }
            bn = {"mean": jnp.zeros((hd * dk,)), "var": jnp.ones((hd * dk,))}

            def score_fn(st, inp, cfg=cfg):
                idx, wts = M.pkm_layer_score(inp["x"], st["p"], cfg, st["bn"])
                return tuple(jax.tree_util.tree_leaves(st)) + (idx, wts)

            w.export(
                f"micro_pkm_score_w{wd}_nk{nk}", score_fn,
                {"p": pp, "bn": bn}, [("x", jnp.zeros((B, wd), jnp.float32))],
                extra_meta={"kind": "micro_pkm_score", "width": wd,
                            "n_keys": nk, "locations": nk * nk,
                            "heads": hd, "k_top": cfg.pkm_topk,
                            "batch": {"B": B}},
                n_result_outputs=2,
            )

        cfg = M.ModelConfig(**{**BASE, "width": wd, "memory": "pkm"}).validate()

        def combine_fn(st, inp):
            y = M.pkm_layer_combine(inp["gathered"], inp["w"])
            return tuple(jax.tree_util.tree_leaves(st)) + (y,)

        w.export(
            f"micro_pkm_combine_w{wd}", combine_fn,
            {"unused": jnp.zeros((1,), jnp.float32)},
            [
                ("gathered", jnp.zeros(
                    (B, cfg.pkm_heads, cfg.pkm_topk, wd), jnp.float32
                )),
                ("w", jnp.zeros((B, cfg.pkm_heads, cfg.pkm_topk), jnp.float32)),
            ],
            extra_meta={"kind": "micro_pkm_combine", "width": wd,
                        "batch": {"B": B}},
            n_result_outputs=1,
        )


def export_fixture(out_dir: str, n_queries: int = 256, seed: int = 42,
                   writer: ArtifactWriter | None = None):
    """Cross-language fixture: the rust lattice implementation must
    reproduce these exact tables and lookups (rust/tests/fixture.rs).

    When a writer is given, also export `lookup_check` — the bare L1
    kernel on the fixture's first 64 queries — so the rust integration
    tests verify the *compiled HLO* against the python oracle end to end
    (the regression net for every gotcha in DESIGN.md).
    """
    rng = np.random.default_rng(seed)
    K = np.asarray(K_2_16)
    qs = rng.uniform(-12, 12, size=(n_queries, 8))
    from .kernels import e8, ref

    if writer is not None:
        Kt = tuple(int(k) for k in K)

        def check_fn(st, inp):
            idx, wts, dwdq = e8.e8_lookup(inp["q"], Kt, 32, 32, True)
            return (st["unused"], idx, wts, dwdq)

        writer.export(
            "lookup_check", check_fn, {"unused": jnp.zeros((1,), jnp.float32)},
            [("q", jnp.zeros((64, 8), jnp.float32))],
            extra_meta={"kind": "lookup_check", "K": [int(k) for k in K],
                        "batch": {"B": 64, "S": 1}},
            n_result_outputs=3,
        )

    lookups = []
    for q in qs:
        idx, wts = ref.lookup_topk(q, K, k=32)
        lookups.append({"q": [float(v) for v in q],
                        "idx": [int(i) for i in idx],
                        "w": [round(float(x), 10) for x in wts]})
    x0 = lt.quantize(qs)
    sample_pts = lt.torus_index_inverse(
        np.arange(0, lt.num_locations(K), max(1, lt.num_locations(K) // 64),
                  dtype=np.int64), K)
    fixture = {
        "K": [int(k) for k in K],
        "num_locations": lt.num_locations(K),
        "neighbor_table": lt.neighbor_table().tolist(),
        "quantize": [
            {"q": [float(v) for v in q], "x": [int(v) for v in x]}
            for q, x in zip(qs[:64], x0[:64])
        ],
        "torus_roundtrip": sample_pts.tolist(),
        "lookups": lookups[:64],
    }
    path = os.path.join(out_dir, "lattice_fixture.json")
    with open(path, "w") as f:
        json.dump(fixture, f)
    print(f"  wrote lattice_fixture.json ({os.path.getsize(path)/1e3:.0f} KB)")


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sets", default="core,micro",
                    help="comma list: core (train/eval/infer for baseline, "
                         "lram_small, pkm), extra (lram_medium, lram_large), "
                         "micro (Table 4 / Fig 3 layers), fixture")
    ap.add_argument("--paper-scale", action="store_true",
                    help="use the paper's 2^18..2^22 slot counts")
    ap.add_argument("--widths", default="256,512,1024,2048")
    args = ap.parse_args()
    sets = set(args.sets.split(","))

    w = ArtifactWriter(args.out)
    vs = variants(args.paper_scale)
    if "core" in sets:
        print("== core training/eval/inference artifacts ==")
        for name in ("baseline", "lram_small", "pkm"):
            export_training(w, name, vs[name], write_init=True)
    if "extra" in sets:
        print("== extra variants ==")
        for name in ("lram_medium", "lram_large", "lram_shared"):
            export_training(w, name, vs[name], write_init=True)
    if "micro" in sets:
        print("== micro layer artifacts (Table 4 / Figure 3) ==")
        widths = tuple(int(x) for x in args.widths.split(","))
        export_micro(w, widths=widths)
    if "fixture" in sets or "core" in sets:
        export_fixture(args.out, writer=w)

    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(w.index, f, indent=1)
    print(f"done: {len(w.index)} artifacts")


if __name__ == "__main__":
    main()
