"""L2: BERT-style masked language model with LRAM / PKM / dense FFN variants.

Paper section 3.1: a residual tower of alternating self-attention and
fully-connected subnetworks; in the memory-augmented variants the FFN of
one designated layer is replaced by

    dense(w -> w)  ->  theta (n, m, h) = (8, m, w/16)  ->  dense(4w -> w)

where theta is the lattice-memory activation layer built on the L1 Pallas
kernel.  Everything is hand-rolled functional JAX (no flax/optax): params
and optimizer state are plain nested dicts so they flatten to a stable,
manifest-described list of arrays for the rust runtime.

Build-time only; never imported on the request path.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import e8
from .kernels.e8 import topk_desc
from .kernels.lattice_tables import num_locations, validate_K

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry + memory-layer configuration.

    The paper's setup is ``width=512, n_layers=6, seq_len=256, vocab=30k``;
    the defaults here are the scaled-down single-CPU geometry used by the
    reproduction runs (see DESIGN.md "Substitutions").
    """

    vocab_size: int = 4096
    width: int = 192
    n_layers: int = 4
    n_heads: int = 4
    seq_len: int = 96
    ffn_mult: int = 4  # r in Table 3
    memory: str = "none"  # none | lram | pkm
    mem_layer: int = 2  # 0-based index of the layer whose FFN is replaced
    #: paper §6 (future work): multiple memory layers reading ONE shared
    #: table of values — "no costlier to allow all l layers to read from
    #: a shared set of l*N memory locations".  When non-empty this
    #: overrides mem_layer; all listed layers get their own query/output
    #: projections but phi reads the same memory_values (and, for
    #: simplicity, shares one BatchNorm over the common query space).
    mem_layers: tuple = ()
    # LRAM (paper: n=8, m=64, h=w/16, k=32)
    lram_K: tuple = (8, 8, 8, 8, 8, 8, 8, 8)
    lram_m: int = 64
    lram_k_top: int = 32
    lram_block_q: int = 128
    lram_use_pallas: bool = True
    # PKM (paper config: 8 heads, N=2^16, value dim 512, key dim 64)
    pkm_n_keys: int = 128  # sqrt(N); N = n_keys^2 value slots
    pkm_heads: int = 4
    pkm_topk: int = 32
    pkm_dk: int = 64  # query/key dim per head (split into two halves)
    # misc
    pre_ln: bool = True  # pre-LN tower (stability deviation; see DESIGN.md)
    tie_embeddings: bool = False
    bn_momentum: float = 0.98

    @property
    def ffn_hidden(self) -> int:
        return self.ffn_mult * self.width

    @property
    def lram_heads(self) -> int:
        # 2 * h * n = width  with n = 8
        assert self.width % 16 == 0, "width must be a multiple of 16"
        return self.width // 16

    @property
    def lram_locations(self) -> int:
        return num_locations(self.lram_K)

    @property
    def pkm_n(self) -> int:
        return self.pkm_n_keys**2

    @property
    def memory_layer_set(self) -> tuple:
        if self.mem_layers:
            return tuple(sorted(self.mem_layers))
        return (self.mem_layer,)

    @property
    def shared_memory(self) -> bool:
        return len(self.memory_layer_set) > 1

    def validate(self) -> "ModelConfig":
        assert self.memory in ("none", "lram", "pkm")
        assert self.width % self.n_heads == 0
        if self.memory == "lram":
            validate_K(self.lram_K)
            assert self.lram_heads * self.lram_m == self.ffn_mult * self.width, (
                "h*m must equal 4w: got "
                f"h={self.lram_heads} m={self.lram_m} w={self.width}"
            )
        if self.mem_layers:
            assert self.memory == "lram", "shared memory layers require lram"
            assert all(0 <= i < self.n_layers for i in self.mem_layers)
            assert len(set(self.mem_layers)) == len(self.mem_layers)
        else:
            assert 0 <= self.mem_layer < self.n_layers
        return self


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def _dense_init(rng, n_in, n_out, scale=0.02):
    kw, _ = jax.random.split(rng)
    return {
        "w": (jax.random.normal(kw, (n_in, n_out), jnp.float32) * scale),
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _ln_init(n):
    return {"g": jnp.ones((n,), jnp.float32), "b": jnp.zeros((n,), jnp.float32)}


def init_params(rng, cfg: ModelConfig) -> Params:
    cfg.validate()
    keys = jax.random.split(rng, cfg.n_layers + 8)
    w = cfg.width
    p: Params = {
        "tok_embed": jax.random.normal(keys[0], (cfg.vocab_size, w)) * 0.02,
        "pos_embed": jax.random.normal(keys[1], (cfg.seq_len, w)) * 0.02,
        "final_ln": _ln_init(w),
        "head": {
            "transform": _dense_init(keys[2], w, w),
            "ln": _ln_init(w),
        },
    }
    if not cfg.tie_embeddings:
        p["head"]["out"] = _dense_init(keys[3], w, cfg.vocab_size)
    else:
        p["head"]["out_bias"] = jnp.zeros((cfg.vocab_size,), jnp.float32)

    if cfg.memory == "lram" and cfg.shared_memory:
        # paper §6: one table read by every memory layer
        p["shared_memory_values"] = (
            jax.random.normal(keys[-1], (cfg.lram_locations, cfg.lram_m)) * 0.02
        )

    for i in range(cfg.n_layers):
        k = jax.random.split(keys[4 + i], 8)
        layer: Params = {
            "ln1": _ln_init(w),
            "ln2": _ln_init(w),
            "attn": {
                "qkv": _dense_init(k[0], w, 3 * w),
                "out": _dense_init(k[1], w, w),
            },
        }
        if cfg.memory != "none" and i in cfg.memory_layer_set:
            if cfg.memory == "lram":
                layer["lram"] = {
                    "query": _dense_init(k[2], w, w),
                    # BN over the 2hn = w query channels
                    "bn": {"g": jnp.ones((w,)), "b": jnp.zeros((w,))},
                    "out": _dense_init(k[4], cfg.ffn_hidden, w),
                }
                if not cfg.shared_memory:
                    # the memory: M value vectors of dim m, shared by heads
                    layer["lram"]["memory_values"] = (
                        jax.random.normal(k[3], (cfg.lram_locations, cfg.lram_m))
                        * 0.02
                    )
            else:  # pkm
                hd = cfg.pkm_heads
                layer["pkm"] = {
                    "query": _dense_init(k[2], w, hd * cfg.pkm_dk),
                    "bn": {"g": jnp.ones((hd * cfg.pkm_dk,)), "b": jnp.zeros((hd * cfg.pkm_dk,))},
                    "keys1": jax.random.normal(
                        k[3], (hd, cfg.pkm_n_keys, cfg.pkm_dk // 2)
                    )
                    * (1.0 / math.sqrt(cfg.pkm_dk // 2)),
                    "keys2": jax.random.normal(
                        k[5], (hd, cfg.pkm_n_keys, cfg.pkm_dk // 2)
                    )
                    * (1.0 / math.sqrt(cfg.pkm_dk // 2)),
                    "memory_values": jax.random.normal(k[6], (cfg.pkm_n, w)) * 0.02,
                }
        else:
            layer["ffn"] = {
                "in": _dense_init(k[2], w, cfg.ffn_hidden),
                "out": _dense_init(k[3], cfg.ffn_hidden, w),
            }
        p[f"layer_{i}"] = layer
    return p


def init_bn_state(cfg: ModelConfig) -> Params:
    """Running BatchNorm statistics (train-updated, eval-consumed)."""
    if cfg.memory == "lram":
        n = cfg.width
    elif cfg.memory == "pkm":
        n = cfg.pkm_heads * cfg.pkm_dk
    else:
        return {"mean": jnp.zeros((1,)), "var": jnp.ones((1,))}
    return {"mean": jnp.zeros((n,)), "var": jnp.ones((n,))}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, p, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def dense(x, p):
    return x @ p["w"] + p["b"]


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def attention(x, p, n_heads):
    B, S, w = x.shape
    qkv = dense(x, p["qkv"]).reshape(B, S, 3, n_heads, w // n_heads)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(w // n_heads)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(B, S, w)
    return dense(out, p["out"])


def _batch_norm(x2d, bn_params, bn_state, train: bool, momentum: float):
    """BN over the flattened (batch*seq, channels) query matrix."""
    if train:
        mu = x2d.mean(0)
        var = x2d.var(0)
        new_state = {
            "mean": momentum * bn_state["mean"] + (1 - momentum) * mu,
            "var": momentum * bn_state["var"] + (1 - momentum) * var,
        }
    else:
        mu, var = bn_state["mean"], bn_state["var"]
        new_state = bn_state
    xn = (x2d - mu) * jax.lax.rsqrt(var + 1e-5)
    return xn * bn_params["g"] + bn_params["b"], new_state


def lram_ffn(x, p, cfg: ModelConfig, bn_state, train: bool,
             collect_access: bool = False, shared_values=None):
    """The memory-augmented subnetwork (paper section 3.1).

    Returns (y, new_bn_state, access) where access is (idx, w) per query
    when collect_access (used for Table 5 accounting), else None.
    `shared_values` carries the §6 shared table when configured.
    """
    B, S, w = x.shape
    h, n, m = cfg.lram_heads, 8, cfg.lram_m
    z = dense(x, p["query"])  # (B, S, w) with w = 2hn
    z2, new_state = _batch_norm(z.reshape(B * S, w), p["bn"], bn_state, train,
                                cfg.bn_momentum)
    zq = z2.reshape(B * S * h, 2 * n)
    K = tuple(int(k) for k in cfg.lram_K)

    # theta, inlined so we can optionally expose the accesses
    zc = zq.reshape(zq.shape[0], n, 2)
    mag = jnp.sqrt(jnp.sum(zc**2, axis=-1) + 1e-12)
    ang = jnp.arctan2(zc[..., 1], zc[..., 0])
    q = jnp.asarray(K, jnp.float32) / (2 * math.pi) * ang
    scale = 1.0 / jnp.sum(1.0 / mag, axis=-1)
    idx, wts = e8.lattice_lookup(
        q, K, cfg.lram_k_top, cfg.lram_block_q, cfg.lram_use_pallas
    )
    values = shared_values if shared_values is not None else p["memory_values"]
    gathered = jnp.take(values, idx, axis=0)  # (Q, k, m)
    out = scale[:, None] * jnp.einsum("qk,qkm->qm", wts, gathered)

    y = dense(out.reshape(B, S, h * m), p["out"])
    access = (idx, wts) if collect_access else None
    return y, new_state, access


def pkm_ffn(x, p, cfg: ModelConfig, bn_state, train: bool,
            collect_access: bool = False):
    """Product-key memory baseline (Lample et al. 2019), O(sqrt N) scoring."""
    B, S, w = x.shape
    hd, dk, half = cfg.pkm_heads, cfg.pkm_dk, cfg.pkm_dk // 2
    kk = cfg.pkm_topk
    z = dense(x, p["query"])  # (B, S, hd*dk)
    z2, new_state = _batch_norm(z.reshape(B * S, hd * dk), p["bn"], bn_state,
                                train, cfg.bn_momentum)
    q = z2.reshape(B * S, hd, dk)
    q1, q2 = q[..., :half], q[..., half:]
    s1 = jnp.einsum("qhd,hnd->qhn", q1, p["keys1"])  # (Q, hd, n_keys)
    s2 = jnp.einsum("qhd,hnd->qhn", q2, p["keys2"])
    t1, i1 = topk_desc(s1, kk)  # (Q, hd, kk)
    t2, i2 = topk_desc(s2, kk)
    # Cartesian product of the two top-k lists -> top-k overall
    comb = t1[..., :, None] + t2[..., None, :]  # (Q, hd, kk, kk)
    flat = comb.reshape(*comb.shape[:2], kk * kk)
    ts, ci = topk_desc(flat, kk)  # (Q, hd, kk)
    r1, r2 = _select_pkm_indices(i1, i2, ci, kk)
    idx = r1 * cfg.pkm_n_keys + r2  # (Q, hd, kk) in [0, N)
    wts = jax.nn.softmax(ts, axis=-1)
    gathered = jnp.take(p["memory_values"], idx, axis=0)  # (Q, hd, kk, w)
    out = jnp.einsum("qhk,qhkw->qw", wts, gathered)  # heads sum into w
    y = out.reshape(B, S, w)
    access = (idx.reshape(-1, kk), wts.reshape(-1, kk)) if collect_access else None
    return y, new_state, access


def _select_pkm_indices(i1, i2, ci, kk):
    """Resolve the Cartesian-product winners back to codebook rows via
    one-hot contractions (gather-free; see kernels/e8.py note)."""
    oh1 = jax.nn.one_hot(ci // kk, kk, dtype=jnp.float32)  # (..., kk, kk)
    oh2 = jax.nn.one_hot(ci % kk, kk, dtype=jnp.float32)
    r1 = jnp.einsum("...kc,...c->...k", oh1, i1.astype(jnp.float32))
    r2 = jnp.einsum("...kc,...c->...k", oh2, i2.astype(jnp.float32))
    # indices are < n_keys <= 2^24, exactly representable in f32
    return r1.astype(jnp.int32), r2.astype(jnp.int32)


def ffn(x, p):
    return dense(gelu(dense(x, p["in"])), p["out"])


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forward(params: Params, tokens, cfg: ModelConfig, bn_state, train: bool,
            collect_access: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V).

    Returns (logits, new_bn_state, access)."""
    B, S = tokens.shape
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :S]
    new_state, access = bn_state, None
    for i in range(cfg.n_layers):
        lp = params[f"layer_{i}"]
        if cfg.pre_ln:
            x = x + attention(layer_norm(x, lp["ln1"]), lp["attn"], cfg.n_heads)
            hin = layer_norm(x, lp["ln2"])
        else:
            x = layer_norm(x + attention(x, lp["attn"], cfg.n_heads), lp["ln1"])
            hin = x
        if "lram" in lp:
            delta, new_state, access = lram_ffn(
                hin, lp["lram"], cfg, bn_state, train, collect_access,
                shared_values=params.get("shared_memory_values"),
            )
        elif "pkm" in lp:
            delta, new_state, access = pkm_ffn(
                hin, lp["pkm"], cfg, bn_state, train, collect_access
            )
        else:
            delta = ffn(hin, lp["ffn"])
        if cfg.pre_ln:
            x = x + delta
        else:
            x = layer_norm(x + delta, lp["ln2"])
    x = layer_norm(x, params["final_ln"])
    h = params["head"]
    x = layer_norm(gelu(dense(x, h["transform"])), h["ln"])
    if cfg.tie_embeddings:
        logits = x @ params["tok_embed"].T + h["out_bias"]
    else:
        logits = dense(x, h["out"])
    return logits, new_state, access


def mlm_loss(logits, targets, weights):
    """Masked cross-entropy; returns (sum_nll, sum_weight).

    One-hot contraction instead of take_along_axis: batched gathers
    miscompile on the AOT target (see kernels/e8.py) and the one-hot
    form fuses into the softmax anyway.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logp.dtype)
    nll = -jnp.sum(logp * onehot, axis=-1)
    return jnp.sum(nll * weights), jnp.sum(weights)


# ---------------------------------------------------------------------------
# Optimiser: Adam with the paper's two learning-rate groups
# ---------------------------------------------------------------------------


LR_DENSE = 1e-4  # paper section 3.2
LR_MEMORY = 1e-3  # "to compensate for sparse access"
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def init_opt_state(params: Params) -> Params:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params)}


def _lr_tree(params: Params):
    """Per-leaf learning rate: memory value tables get LR_MEMORY."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def leaf_lr(path):
        names = [str(getattr(k, "key", k)) for k in path]
        return LR_MEMORY if any("memory_values" in n for n in names) else LR_DENSE

    lrs = [leaf_lr(path) for path, _ in flat]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, lrs)


def train_step(params, opt, bn_state, step, tokens, targets, weights,
               cfg: ModelConfig):
    """One Adam step; returns (params, opt, bn_state, loss)."""

    def loss_fn(p):
        logits, new_bn, _ = forward(p, tokens, cfg, bn_state, train=True)
        s, n = mlm_loss(logits, targets, weights)
        return s / jnp.maximum(n, 1.0), new_bn

    (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    lrs = _lr_tree(params)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t

    def upd(p, g, m, v, lr):
        m2 = ADAM_B1 * m + (1 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1 - ADAM_B2) * g * g
        p2 = p - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
        return p2, m2, v2

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"], lrs)
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}, new_bn, loss


def eval_loss(params, bn_state, tokens, targets, weights, cfg: ModelConfig,
              collect_access: bool = False):
    """Returns (sum_nll, sum_weight[, idx, w]) for perplexity accounting."""
    logits, _, access = forward(params, tokens, cfg, bn_state, train=False,
                                collect_access=collect_access)
    s, n = mlm_loss(logits, targets, weights)
    if collect_access:
        return s, n, access[0], access[1]
    return s, n


# ---------------------------------------------------------------------------
# Standalone layer functions (Table 4 / Figure 3 microbenches)
# ---------------------------------------------------------------------------


def dense_ffn_layer(x, p):
    """The replaced subnetwork: dense w -> 4w -> w with GELU."""
    return dense(gelu(dense(x, p["in"])), p["out"])


def lram_layer_prefix(x, p, cfg: ModelConfig, bn_state):
    """Split-mode phase A: queries -> (idx, w, scale).  The gather between
    prefix and suffix belongs to the rust memstore."""
    B, w = x.shape
    h, n = cfg.lram_heads, 8
    z = dense(x, p["query"])
    z2, _ = _batch_norm(z, p["bn"], bn_state, train=False,
                        momentum=cfg.bn_momentum)
    zq = z2.reshape(B * h, 2 * n)
    zc = zq.reshape(-1, n, 2)
    mag = jnp.sqrt(jnp.sum(zc**2, axis=-1) + 1e-12)
    ang = jnp.arctan2(zc[..., 1], zc[..., 0])
    K = tuple(int(k) for k in cfg.lram_K)
    q = jnp.asarray(K, jnp.float32) / (2 * math.pi) * ang
    scale = 1.0 / jnp.sum(1.0 / mag, axis=-1)
    idx, wts, _ = e8.e8_lookup(q, K, cfg.lram_k_top, cfg.lram_block_q,
                               cfg.lram_use_pallas)
    return idx.reshape(B, h, -1), wts.reshape(B, h, -1), scale.reshape(B, h)


def lram_layer_suffix(gathered, wts, scale, p, cfg: ModelConfig):
    """Split-mode phase B: combine gathered rows -> layer output.

    gathered: (B, h, k, m); wts: (B, h, k); scale: (B, h)."""
    B, h = wts.shape[0], wts.shape[1]
    out = scale[..., None] * jnp.einsum("bhk,bhkm->bhm", wts, gathered)
    return dense(out.reshape(B, h * cfg.lram_m), p["out"])


def pkm_layer_score(x, p, cfg: ModelConfig, bn_state):
    """Split-mode phase A for PKM: O(sqrt N) codebook scoring -> (idx, w)."""
    B, w = x.shape
    hd, dk, half, kk = cfg.pkm_heads, cfg.pkm_dk, cfg.pkm_dk // 2, cfg.pkm_topk
    z = dense(x, p["query"])
    z2, _ = _batch_norm(z, p["bn"], bn_state, train=False,
                        momentum=cfg.bn_momentum)
    q = z2.reshape(B, hd, dk)
    q1, q2 = q[..., :half], q[..., half:]
    s1 = jnp.einsum("qhd,hnd->qhn", q1, p["keys1"])
    s2 = jnp.einsum("qhd,hnd->qhn", q2, p["keys2"])
    t1, i1 = topk_desc(s1, kk)
    t2, i2 = topk_desc(s2, kk)
    comb = t1[..., :, None] + t2[..., None, :]
    ts, ci = topk_desc(comb.reshape(B, hd, kk * kk), kk)
    r1, r2 = _select_pkm_indices(i1, i2, ci, kk)
    idx = r1 * cfg.pkm_n_keys + r2
    return idx, jax.nn.softmax(ts, axis=-1)


def pkm_layer_combine(gathered, wts):
    """Split-mode phase B for PKM: (B, hd, k, w) x (B, hd, k) -> (B, w)."""
    return jnp.einsum("bhk,bhkw->bw", wts, gathered)


def count_params(params: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
