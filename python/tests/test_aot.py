"""AOT export regression tests — the interchange gotchas in DESIGN.md
("AOT interchange gotchas") must never come back."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def lower_text(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def test_large_constants_are_printed():
    """Gotcha #1: the 232x8 table must appear verbatim, never as {...}."""
    from compile.kernels import e8

    spec = jax.ShapeDtypeStruct((4, 8), jnp.float32)
    text = lower_text(lambda q: e8.e8_lookup(q, (8,) * 8, 8, 4, False), spec)
    assert "constant({...})" not in text, "elided constants would read back as zeros"
    # a distinctive row of the neighbor table must be embedded
    assert "232,8" in text


def test_no_topk_or_sort_instructions():
    """Gotchas #2/#3: no `topk`/`sort` ops in any lowered lookup."""
    from compile.kernels import e8

    spec = jax.ShapeDtypeStruct((4, 8), jnp.float32)
    for use_pallas in (False, True):
        text = lower_text(
            lambda q: e8.e8_lookup(q, (8,) * 8, 8, 4, use_pallas), spec
        )
        for needle in (" topk(", "largest=", " sort("):
            assert needle not in text, f"{needle} found (pallas={use_pallas})"


def test_no_batched_gather_in_train_step():
    """Gotcha #4: no operand_batching_dims gathers anywhere in training."""
    cfg = M.ModelConfig(
        vocab_size=256, width=64, n_layers=2, n_heads=2, seq_len=16,
        memory="lram", mem_layer=1, lram_K=(8, 8, 8, 8, 8, 8, 4, 4),
        lram_use_pallas=False,
    ).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = M.init_opt_state(params)
    bn = M.init_bn_state(cfg)

    def step(tokens, targets, weights):
        return M.train_step(params, opt, bn, jnp.int32(0), tokens, targets,
                            weights, cfg)[3]

    text = lower_text(
        step,
        jax.ShapeDtypeStruct((2, 16), jnp.int32),
        jax.ShapeDtypeStruct((2, 16), jnp.int32),
        jax.ShapeDtypeStruct((2, 16), jnp.float32),
    )
    assert "operand_batching_dims" not in text


def test_variants_have_expected_slot_counts():
    vs = aot.variants(paper_scale=False)
    assert vs["lram_small"].lram_locations == 2**14
    assert vs["lram_medium"].lram_locations == 2**16
    assert vs["lram_large"].lram_locations == 2**18
    vp = aot.variants(paper_scale=True)
    assert vp["lram_small"].lram_locations == 2**18  # paper Table 5
    assert vp["lram_medium"].lram_locations == 2**20
    assert vp["lram_large"].lram_locations == 2**22


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "index.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifests_are_consistent():
    """Every manifest: outputs >= n_state_outputs, state/input dtype tags
    valid, hlo file exists, state bin (if referenced) matches byte size."""
    for fname in os.listdir(ARTIFACT_DIR):
        if not fname.endswith(".meta.json"):
            continue
        with open(os.path.join(ARTIFACT_DIR, fname)) as f:
            m = json.load(f)
        assert os.path.exists(os.path.join(ARTIFACT_DIR, m["artifact"])), fname
        assert m["n_state_outputs"] <= len(m["outputs"]), fname
        for spec in m["state"] + m["inputs"] + m["outputs"]:
            assert spec["dtype"] in ("f32", "i32", "u32", "f64", "i64"), fname
            assert all(isinstance(d, int) and d >= 0 for d in spec["shape"])
        if m.get("variant"):
            bin_path = os.path.join(ARTIFACT_DIR, f"{m['variant']}.state.bin")
            if os.path.exists(bin_path):
                expect = sum(
                    int(np.prod(s["shape"])) * (8 if s["dtype"] in ("f64", "i64") else 4)
                    for s in m["state"]
                )
                assert os.path.getsize(bin_path) == expect, fname


@needs_artifacts
def test_train_and_eval_manifests_share_state_layout():
    """The trainer feeds eval with the train artifact's state: the two
    manifests must agree on every state tensor."""
    for variant in ("baseline", "lram_small", "pkm"):
        with open(os.path.join(ARTIFACT_DIR, f"train_step_{variant}.meta.json")) as f:
            train = json.load(f)
        with open(os.path.join(ARTIFACT_DIR, f"eval_loss_{variant}.meta.json")) as f:
            ev = json.load(f)
        assert [s["name"] for s in train["state"]] == [s["name"] for s in ev["state"]]
        assert [s["shape"] for s in train["state"]] == [s["shape"] for s in ev["state"]]


@needs_artifacts
def test_input_order_is_authored_not_sorted():
    """Gotcha #5: tokens must come before targets in the manifests."""
    with open(os.path.join(ARTIFACT_DIR, "eval_loss_baseline.meta.json")) as f:
        m = json.load(f)
    names = [s["name"] for s in m["inputs"]]
    assert names == ["tokens", "targets", "weights"], names
    with open(os.path.join(ARTIFACT_DIR, "train_step_baseline.meta.json")) as f:
        m = json.load(f)
    names = [s["name"] for s in m["inputs"]]
    assert names == ["step", "tokens", "targets", "weights"], names
