"""Tests for the shared lattice math (numpy reference layer)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from compile.kernels import lattice_tables as lt
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def queries(n, lo=-12.0, hi=12.0):
    return RNG.uniform(lo, hi, size=(n, 8))


# ---------------------------------------------------------------------------
# quantizer
# ---------------------------------------------------------------------------


def test_quantizer_returns_lattice_points():
    q = queries(500)
    x = lt.quantize(q)
    for row in x.astype(np.int64):
        assert lt.is_lattice_point(row)


def test_quantizer_is_nearest_vs_bruteforce():
    for q in queries(150):
        x = lt.quantize(q)
        pts = ref.ball_points(q, r2=16.0)  # covering radius 2 => nonempty
        assert len(pts) > 0
        d_brute = ((pts - q[None]) ** 2).sum(-1).min()
        d_quant = ((q - x) ** 2).sum()
        assert d_quant <= d_brute + 1e-9


def test_quantizer_fixed_points():
    # lattice points quantize to themselves
    pts = lt.neighbor_table().astype(np.float64)
    out = lt.quantize(pts)
    np.testing.assert_array_equal(out, pts)


def test_covering_radius_bound():
    q = queries(5000)
    x = lt.quantize(q)
    d = np.sqrt(((q - x) ** 2).sum(-1))
    assert d.max() <= 2.0 + 1e-9  # covering radius of Lambda is 2


@given(hnp.arrays(np.float64, (8,), elements=st.floats(-50, 50)))
@settings(max_examples=200, deadline=None)
def test_quantizer_translation_invariance(q):
    """Distance to the lattice is translation-invariant.  (The returned
    *point* may differ by tie-breaking when q is exactly equidistant to
    several lattice points — hypothesis happily generates such boundary
    floats — so the invariant is the distance, not the point.)"""
    shift = np.array([4, 0, 0, 0, 0, 0, 0, 0], dtype=np.float64)  # in Lambda
    a = lt.quantize(q)
    b = lt.quantize(q + shift)
    da = ((q - a) ** 2).sum()
    db = ((q + shift - b) ** 2).sum()
    np.testing.assert_allclose(da, db, atol=1e-9)
    assert lt.is_lattice_point(b.astype(np.int64))


# ---------------------------------------------------------------------------
# reduction
# ---------------------------------------------------------------------------


def test_reduction_lands_in_F():
    q = queries(3000)
    _, _, _, z = lt.reduce_batch(q)
    assert lt.in_fundamental_region(z)


def test_reduction_is_isometry():
    q = queries(500)
    x0, perm, eps, z = lt.reduce_batch(q)
    r = q - x0
    rs = np.take_along_axis(r, perm, axis=-1)
    np.testing.assert_allclose(np.abs(eps * rs), np.abs(z), atol=1e-12)
    np.testing.assert_allclose(
        (z**2).sum(-1), (r**2).sum(-1), atol=1e-9
    )


def test_reduction_even_sign_changes():
    q = queries(2000)
    _, _, eps, _ = lt.reduce_batch(q)
    assert (np.prod(eps, axis=-1) == 1.0).all()


# ---------------------------------------------------------------------------
# neighbour table
# ---------------------------------------------------------------------------


def test_neighbor_table_has_232_points():
    nbr = lt.neighbor_table()
    assert nbr.shape == (232, 8)
    # all are lattice points, all within sqrt(24) of the origin
    for row in nbr:
        assert lt.is_lattice_point(row)
    assert ((nbr**2).sum(-1) <= 24).all()
    # no duplicates
    assert len({tuple(r) for r in nbr}) == 232


def test_neighbor_table_covers_bruteforce_ball():
    """Candidates found through the reduction must equal the brute-force
    enumeration of lattice points within sqrt(8) of q."""
    for q in queries(100):
        u, d2 = lt.candidates_for(q)
        got = {
            tuple(map(int, u[0, i]))
            for i in range(u.shape[1])
            if d2[0, i] < 8.0 - 1e-9
        }
        want = {tuple(p) for p in ref.ball_points(q, r2=8.0 - 1e-9)}
        assert got == want


def test_candidate_distances_match_original_frame():
    q = queries(200)
    u, d2 = lt.candidates_for(q)
    d2_direct = ((q[:, None, :] - u) ** 2).sum(-1)
    np.testing.assert_allclose(d2, d2_direct, atol=1e-9)


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------


def test_kernel_support_and_smoothness():
    r2 = np.linspace(0, 12, 200)
    f = lt.kernel_f(r2)
    assert f[0] == 1.0
    assert (f[r2 >= 8.0] == 0.0).all()
    assert (np.diff(f) <= 1e-12).all()  # monotone decreasing in r^2


def test_interpolation_property():
    """phi(k) = v_k at lattice points (paper section 2.5)."""
    K = (8,) * 8
    M = lt.num_locations(K)
    values = RNG.normal(size=(M, 4))
    for _ in range(20):
        i = int(RNG.integers(0, M))
        x = lt.torus_index_inverse(np.int64(i), np.asarray(K)).astype(np.float64)
        out = ref.phi(x, values, K, k=None)
        np.testing.assert_allclose(out, values[i], atol=1e-9)


def test_total_weight_bounds():
    """Paper section 2.5: 0.851 <= total weight <= 1."""
    q = queries(5000)
    _, d2 = lt.candidates_for(q)
    w = lt.kernel_f(d2).sum(-1)
    assert w.min() >= lt.TOTAL_WEIGHT_LOWER - 1e-9
    assert w.max() <= 1.0 + 1e-9


def test_total_weight_is_one_at_lattice_points_and_deep_holes():
    # lattice point
    _, d2 = lt.candidates_for(np.zeros((1, 8)))
    assert abs(lt.kernel_f(d2).sum() - 1.0) < 1e-12
    # a deep hole of Lambda: distance 2 from nearest point, e.g. (1,...,1,-1)
    hole = np.array([[1.0, 1, 1, 1, 1, 1, 1, -1]])
    x0 = lt.quantize(hole)
    assert abs(((hole - x0) ** 2).sum() - 4.0) < 1e-9  # dist 2 = covering radius
    _, d2 = lt.candidates_for(hole)
    assert abs(lt.kernel_f(d2).sum() - 1.0) < 1e-6


def test_top32_weight_mass():
    """Paper: top-32 of the 232 candidates carry >= 90% of the weight."""
    q = queries(2000)
    _, d2 = lt.candidates_for(q)
    w = lt.kernel_f(d2)
    w_sorted = -np.sort(-w, axis=-1)
    frac = w_sorted[:, :32].sum(-1) / w.sum(-1)
    assert frac.min() >= 0.90
    assert frac.mean() >= 0.995 - 0.002


# ---------------------------------------------------------------------------
# torus indexing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "K",
    [
        (8,) * 8,
        (4,) * 8,
        (16, 16, 8, 8, 8, 8, 8, 8),
        (12, 8, 8, 8, 4, 4, 8, 8),
    ],
)
def test_torus_index_bijection(K):
    Kv = np.asarray(K)
    M = lt.num_locations(Kv)
    idx = np.arange(M, dtype=np.int64)
    x = lt.torus_index_inverse(idx, Kv)
    # representatives are lattice points
    par = ((x % 2) + 2) % 2
    assert (par == par[..., :1]).all()
    assert (x.sum(-1) % 4 == 0).all()
    back = lt.torus_index(x, Kv)
    np.testing.assert_array_equal(back, idx)


def test_torus_index_L_K_invariance():
    K = np.asarray((8, 8, 8, 8, 16, 8, 8, 4))
    for _ in range(500):
        q = RNG.uniform(-30, 30, 8)
        x = lt.quantize(q).astype(np.int64)
        j = lt.torus_index(x, K)
        shift = K * RNG.integers(-3, 4, size=8)
        assert lt.torus_index(x + shift, K) == j
        assert 0 <= j < lt.num_locations(K)


def test_num_locations_paper_sizes():
    # paper Table 5: LRAM-small/medium/large have 2^18 / 2^20 / 2^22 slots
    assert lt.num_locations((16, 16, 8, 8, 8, 8, 8, 8)) == 2**18
    assert lt.num_locations((16, 16, 16, 16, 8, 8, 8, 8)) == 2**20
    assert lt.num_locations((16,) * 6 + (8, 8)) == 2**22
