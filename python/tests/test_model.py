"""L2 model tests: shapes, training dynamics, scaling formulas (Table 3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.lattice_tables import num_locations

RNG = np.random.default_rng(3)

SMALL = dict(vocab_size=512, width=64, n_layers=2, n_heads=2, seq_len=32)


def cfg_for(memory, **kw):
    base = dict(SMALL, mem_layer=1)
    if memory == "lram":
        base.update(lram_K=(8, 8, 8, 8, 8, 8, 8, 4), mem_layer=1)
    if memory == "pkm":
        base.update(pkm_n_keys=16, pkm_heads=2, pkm_topk=8, mem_layer=1)
    base.update(kw)
    return M.ModelConfig(memory=memory, **base).validate()


def batch_for(cfg, B=2, rng=RNG):
    tokens = rng.integers(0, cfg.vocab_size, (B, cfg.seq_len)).astype(np.int32)
    targets = tokens.copy()
    weights = (rng.random((B, cfg.seq_len)) < 0.15).astype(np.float32)
    return jnp.asarray(tokens), jnp.asarray(targets), jnp.asarray(weights)


@pytest.mark.parametrize("memory", ["none", "lram", "pkm"])
def test_forward_shapes(memory):
    cfg = cfg_for(memory, lram_use_pallas=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bn = M.init_bn_state(cfg)
    tokens, _, _ = batch_for(cfg)
    logits, new_bn, _ = M.forward(params, tokens, cfg, bn, train=True)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("memory", ["none", "lram", "pkm"])
def test_train_step_reduces_loss(memory):
    """A few steps on one repeated batch must reduce the loss."""
    cfg = cfg_for(memory, lram_use_pallas=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = M.init_opt_state(params)
    bn = M.init_bn_state(cfg)
    tokens, targets, weights = batch_for(cfg, B=4)

    step_fn = jax.jit(
        lambda p, o, b, s: M.train_step(p, o, b, s, tokens, targets, weights, cfg)
    )
    losses = []
    for i in range(8):
        params, opt, bn, loss = step_fn(params, opt, bn, jnp.int32(i))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.05, losses


def test_memory_values_receive_sparse_updates():
    cfg = cfg_for("lram", lram_use_pallas=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = M.init_opt_state(params)
    bn = M.init_bn_state(cfg)
    tokens, targets, weights = batch_for(cfg, B=2)
    before = np.asarray(params[f"layer_{cfg.mem_layer}"]["lram"]["memory_values"]).copy()
    params2, *_ = M.train_step(params, opt, bn, jnp.int32(0), tokens, targets,
                               weights, cfg)
    after = np.asarray(params2[f"layer_{cfg.mem_layer}"]["lram"]["memory_values"])
    changed = (np.abs(after - before).sum(-1) > 0).mean()
    assert 0 < changed < 1.0, f"expected sparse row updates, changed={changed:.2%}"


def test_eval_loss_collects_access():
    cfg = cfg_for("lram", lram_use_pallas=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bn = M.init_bn_state(cfg)
    tokens, targets, weights = batch_for(cfg)
    s, n, idx, w = M.eval_loss(params, bn, tokens, targets, weights, cfg,
                               collect_access=True)
    Q = 2 * cfg.seq_len * cfg.lram_heads
    assert idx.shape == (Q, cfg.lram_k_top)
    assert w.shape == (Q, cfg.lram_k_top)
    M_loc = num_locations(cfg.lram_K)
    assert ((np.asarray(idx) >= 0) & (np.asarray(idx) < M_loc)).all()


def test_bn_running_stats_update():
    cfg = cfg_for("lram", lram_use_pallas=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bn = M.init_bn_state(cfg)
    tokens, targets, weights = batch_for(cfg)
    _, _, bn2, _ = M.train_step(params, M.init_opt_state(params), bn,
                                jnp.int32(0), tokens, targets, weights, cfg)
    assert not np.allclose(np.asarray(bn2["mean"]), np.asarray(bn["mean"]))


# ---------------------------------------------------------------------------
# Table 3: parameter-count formulas
# ---------------------------------------------------------------------------


def _layer_param_count(cfg, kind):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    lp = params[f"layer_{cfg.mem_layer}"]
    return M.count_params(lp[kind])


def test_table3_dense_params():
    """Dense 2-layer: 2 r w^2 (+ O(w) biases)."""
    cfg = cfg_for("none")
    w, r = cfg.width, cfg.ffn_mult
    got = _layer_param_count(cfg, "ffn")
    assert abs(got - 2 * r * w * w) <= (r + 1) * w + w


def test_table3_lram_params():
    """LRAM: m N + (5/4) r w^2 (+ O(w))."""
    cfg = cfg_for("lram")
    w, r = cfg.width, cfg.ffn_mult
    N = num_locations(cfg.lram_K)
    got = _layer_param_count(cfg, "lram")
    expect = cfg.lram_m * N + (5 * r // 4) * w * w
    assert abs(got - expect) <= 10 * w


def test_table3_pkm_params():
    """PKM: m N + 2 w sqrt(N)-ish keys + w^2-ish query net."""
    cfg = cfg_for("pkm")
    w = cfg.width
    got = _layer_param_count(cfg, "pkm")
    N = cfg.pkm_n
    keys = 2 * cfg.pkm_heads * cfg.pkm_n_keys * (cfg.pkm_dk // 2)
    query = w * cfg.pkm_heads * cfg.pkm_dk
    expect = w * N + keys + query
    assert abs(got - expect) <= 10 * (w + cfg.pkm_heads * cfg.pkm_dk)


def test_paper_geometry_param_counts():
    """At the paper's w=512 geometry the LRAM layer sizes line up with
    Table 2's deltas (memory table dominates)."""
    cfg = M.ModelConfig(
        vocab_size=512, width=512, n_layers=1, n_heads=8, seq_len=16,
        memory="lram", mem_layer=0, lram_K=(16, 16, 8, 8, 8, 8, 8, 8),
    ).validate()
    assert cfg.lram_heads == 32
    assert cfg.lram_heads * 16 == 512  # 2hn = w
    assert cfg.lram_heads * cfg.lram_m == 4 * 512  # hm = 4w
    assert num_locations(cfg.lram_K) == 2**18
    # paper: LRAM-small adds ~16M params (2^18 * 64)
    assert cfg.lram_m * num_locations(cfg.lram_K) == 2**18 * 64


def test_pre_ln_and_post_ln_both_run():
    for pre in (True, False):
        cfg = cfg_for("none", pre_ln=pre)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        tokens, _, _ = batch_for(cfg)
        logits, _, _ = M.forward(params, tokens, cfg, M.init_bn_state(cfg),
                                 train=False)
        assert np.isfinite(np.asarray(logits)).all()


def test_tied_embeddings():
    cfg = cfg_for("none", tie_embeddings=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    assert "out" not in params["head"]
    tokens, _, _ = batch_for(cfg)
    logits, _, _ = M.forward(params, tokens, cfg, M.init_bn_state(cfg),
                             train=False)
    assert logits.shape[-1] == cfg.vocab_size


# ---------------------------------------------------------------------------
# Paper section 6 (future work): shared memory across layers
# ---------------------------------------------------------------------------


def shared_cfg():
    return M.ModelConfig(
        memory="lram", mem_layers=(0, 1), lram_K=(8, 8, 8, 8, 8, 8, 8, 4),
        lram_use_pallas=False, **SMALL,
    ).validate()


def test_shared_memory_single_table():
    cfg = shared_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    assert "shared_memory_values" in params
    for i in (0, 1):
        lp = params[f"layer_{i}"]
        assert "lram" in lp and "memory_values" not in lp["lram"]
    # parameter saving vs two private tables
    import numpy as np
    table = int(np.prod(params["shared_memory_values"].shape))
    assert table == cfg.lram_locations * cfg.lram_m


def test_shared_memory_forward_and_training():
    cfg = shared_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = M.init_opt_state(params)
    bn = M.init_bn_state(cfg)
    tokens, targets, weights = batch_for(cfg, B=2)
    logits, _, _ = M.forward(params, tokens, cfg, bn, train=True)
    assert np.isfinite(np.asarray(logits)).all()
    p2, _, _, loss = M.train_step(params, opt, bn, jnp.int32(0), tokens,
                                  targets, weights, cfg)
    assert np.isfinite(float(loss))
    # the shared table receives gradient from BOTH layers
    before = np.asarray(params["shared_memory_values"])
    after = np.asarray(p2["shared_memory_values"])
    assert (before != after).any()


def test_shared_memory_loss_decreases():
    cfg = shared_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = M.init_opt_state(params)
    bn = M.init_bn_state(cfg)
    tokens, targets, weights = batch_for(cfg, B=4)
    step_fn = jax.jit(
        lambda p, o, b, s: M.train_step(p, o, b, s, tokens, targets, weights, cfg)
    )
    losses = []
    for i in range(8):
        params, opt, bn, loss = step_fn(params, opt, bn, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses
