"""L1 correctness: Pallas kernel vs the brute-force oracle.

This is the CORE correctness signal for the whole stack — everything the
rust coordinator executes flows through this kernel.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import e8, ref
from compile.kernels import lattice_tables as lt

RNG = np.random.default_rng(7)
K8 = (8,) * 8
K_MIX = (16, 16, 8, 8, 8, 8, 8, 8)  # 2^18 slots, paper's LRAM-small


def queries(n, lo=-12.0, hi=12.0, rng=RNG):
    return rng.uniform(lo, hi, size=(n, 8)).astype(np.float32)


def oracle_pairs(q, K, k_top):
    idx, w = ref.lookup_topk(np.asarray(q, np.float64), K, k=k_top)
    return idx, w


def compare_against_oracle(qs, K, k_top=32, use_pallas=True, atol=1e-4):
    idx, w, dwdq = map(
        np.asarray, e8.e8_lookup(jnp.asarray(qs), K, k_top, 64, use_pallas)
    )
    for b in range(len(qs)):
        oid, ow = oracle_pairs(qs[b], K, k_top)
        # weights: compare as sorted multisets (both descending)
        np.testing.assert_allclose(w[b], ow, atol=atol, rtol=1e-4)
        # index->weight map must agree for non-tied, nonzero weights
        got = {}
        for i, wi in zip(idx[b], w[b]):
            if wi > 1e-6:
                got[int(i)] = got.get(int(i), 0.0) + float(wi)
        want = {}
        for i, wi in zip(oid, ow):
            if wi > 1e-6:
                want[int(i)] = want.get(int(i), 0.0) + float(wi)
        assert set(got) == set(want), f"query {b}: index sets differ"
        for k in got:
            assert abs(got[k] - want[k]) < 1e-3


# ---------------------------------------------------------------------------
# pallas kernel vs oracle
# ---------------------------------------------------------------------------


def test_pallas_matches_oracle_uniform():
    compare_against_oracle(queries(64), K_MIX)


def test_pallas_matches_oracle_small_torus():
    compare_against_oracle(queries(48), K8)


def test_pallas_matches_oracle_near_lattice_points():
    base = lt.torus_index_inverse(
        np.arange(24, dtype=np.int64), np.asarray(K_MIX)
    ).astype(np.float32)
    qs = base + RNG.normal(0, 0.05, base.shape).astype(np.float32)
    compare_against_oracle(qs, K_MIX)


def test_pallas_matches_oracle_large_coordinates():
    compare_against_oracle(queries(32, lo=-200, hi=200), K_MIX)


def test_jnp_path_equals_pallas_path():
    qs = queries(96)
    a = e8.e8_lookup(jnp.asarray(qs), K_MIX, 32, 32, True)
    b = e8.e8_lookup(jnp.asarray(qs), K_MIX, 32, 32, False)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]), atol=1e-6)


@pytest.mark.parametrize("k_top", [8, 16, 32, 64])
def test_k_top_variants(k_top):
    compare_against_oracle(queries(16), K_MIX, k_top=k_top)


@pytest.mark.parametrize("block_q", [16, 64, 128])
def test_batch_not_multiple_of_block(block_q):
    qs = queries(37)
    idx, w, _ = e8.e8_lookup(jnp.asarray(qs), K_MIX, 32, block_q, True)
    assert idx.shape == (37, 32) and w.shape == (37, 32)
    compare_against_oracle(qs[:8], K_MIX)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes / ranges / dtypes
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 17),
    lo=st.floats(-100, 0),
    span=st.floats(0.5, 100),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_kernel_invariants_hypothesis(n, lo, span, seed):
    rng = np.random.default_rng(seed)
    qs = rng.uniform(lo, lo + span, size=(n, 8)).astype(np.float32)
    idx, w, dwdq = map(
        np.asarray, e8.e8_lookup(jnp.asarray(qs), K_MIX, 32, 32, False)
    )
    M = lt.num_locations(K_MIX)
    assert ((idx >= 0) & (idx < M)).all()
    assert (w >= 0).all() and (w <= 1 + 1e-6).all()
    # weights descending
    assert (np.diff(w, axis=-1) <= 1e-6).all()
    # total weight within the paper's bounds (top-32 keeps >= 90%)
    tot = w.sum(-1)
    assert (tot >= 0.90 * lt.TOTAL_WEIGHT_LOWER - 1e-4).all()
    assert (tot <= 1.0 + 1e-5).all()
    assert np.isfinite(dwdq).all()


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_query_dtypes(dtype):
    qs = queries(8).astype(dtype)
    idx, w, _ = e8.e8_lookup(jnp.asarray(qs), K_MIX, 32, 32, False)
    assert np.asarray(w).dtype == np.float32
    compare_against_oracle(qs.astype(np.float32)[:4], K_MIX)


# ---------------------------------------------------------------------------
# gradients
# ---------------------------------------------------------------------------


def test_dwdq_matches_finite_differences():
    qs = queries(12)
    _, w0, dwdq = map(np.asarray, e8.e8_lookup(jnp.asarray(qs), K_MIX, 32, 32, False))
    h = 1e-3
    for j in range(8):
        qp = qs.copy()
        qp[:, j] += h
        qm = qs.copy()
        qm[:, j] -= h
        _, wp, _ = e8.e8_lookup(jnp.asarray(qp), K_MIX, 32, 32, False)
        _, wm, _ = e8.e8_lookup(jnp.asarray(qm), K_MIX, 32, 32, False)
        fd = (np.asarray(wp) - np.asarray(wm)) / (2 * h)
        # candidate selection can change at region boundaries; compare only
        # entries whose weight sets moved smoothly
        mask = np.abs(fd - dwdq[:, :, j]) < 0.05
        frac = mask.mean()
        assert frac > 0.97, f"coordinate {j}: only {frac:.2%} smooth matches"


def test_phi_gradient_flows_to_queries_and_values():
    M = lt.num_locations(K8)
    values = jnp.asarray(RNG.normal(size=(M, 4)).astype(np.float32))
    qs = jnp.asarray(queries(6))

    def loss(q, v):
        return jnp.sum(e8.phi(q, v, K8, 32, 32, False) ** 2)

    gq, gv = jax.grad(loss, argnums=(0, 1))(qs, values)
    assert np.isfinite(np.asarray(gq)).all()
    assert np.asarray(gq).any(), "no gradient reached the queries"
    assert np.isfinite(np.asarray(gv)).all()
    assert (np.abs(np.asarray(gv)).sum(-1) > 0).sum() > 0


def test_phi_matches_oracle_with_values():
    K = K8
    M = lt.num_locations(K)
    values = RNG.normal(size=(M, 16)).astype(np.float32)
    qs = queries(24)
    out = np.asarray(e8.phi(jnp.asarray(qs), jnp.asarray(values), K, 32, 32, True))
    for b in range(len(qs)):
        want = ref.phi(qs[b].astype(np.float64), values.astype(np.float64), K, k=32)
        np.testing.assert_allclose(out[b], want, atol=5e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# theta activation layer
# ---------------------------------------------------------------------------


def test_theta_positive_homogeneity():
    """theta(l z) = l theta(z) for l >= 0 (paper section 2.3)."""
    K = K8
    M = lt.num_locations(K)
    values = jnp.asarray(RNG.normal(size=(M, 8)).astype(np.float32))
    z = jnp.asarray(RNG.normal(0, 2.0, size=(10, 16)).astype(np.float32))
    base = np.asarray(e8.theta(z, values, K, 32, 32, False, eps=0.0))
    for lam in (0.5, 2.0, 7.5):
        out = np.asarray(e8.theta(lam * z, values, K, 32, 32, False, eps=0.0))
        np.testing.assert_allclose(out, lam * base, rtol=2e-4, atol=1e-5)


def test_theta_matches_oracle():
    K = K8
    M = lt.num_locations(K)
    values = RNG.normal(size=(M, 8)).astype(np.float32)
    z = RNG.normal(0, 2.0, size=(12, 16)).astype(np.float32)
    out = np.asarray(
        e8.theta(jnp.asarray(z), jnp.asarray(values), K, 32, 32, False, eps=0.0)
    )
    for b in range(len(z)):
        want = ref.theta(z[b].astype(np.float64), values.astype(np.float64), K, k=32)
        np.testing.assert_allclose(out[b], want, atol=1e-4, rtol=2e-3)


def test_theta_gradients_finite_near_origin():
    K = K8
    M = lt.num_locations(K)
    values = jnp.asarray(RNG.normal(size=(M, 8)).astype(np.float32))
    z = jnp.asarray((RNG.normal(0, 1e-3, size=(4, 16))).astype(np.float32))

    def loss(zz):
        return jnp.sum(e8.theta(zz, values, K, 32, 32, False) ** 2)

    g = np.asarray(jax.grad(loss)(z))
    assert np.isfinite(g).all()
