//! Table 1 reproduction: lattice comparison in 8 and higher dimensions.
//!
//! Regenerates every row of the paper's Table 1: packing/covering radii
//! (classical constants, unimodular scale), Monte-Carlo min/max kernel-
//! support counts for Z^8 and E8, and analytic averages (ball volume =
//! expected point count for a unimodular lattice — the paper's own method
//! for K12 / Lambda16 / Lambda24).
//!
//! Run: `cargo bench --bench table1_lattices [-- --samples N]`
//! (default 300k; the paper used >= 1e7 — pass `--samples 10000000`).

use lram::lattice::{exotic, support};
use lram::util::cli::Args;
use lram::util::timing::Table;

fn main() {
    let args = Args::parse();
    let samples = args.u64("samples", 300_000).unwrap();
    let z8_samples = (samples / 20).max(2_000);
    eprintln!("Table 1: E8 MC samples = {samples}, Z8 MC samples = {z8_samples}");

    let t0 = std::time::Instant::now();
    let e8 = support::e8_support_stats(samples, 1);
    let z8 = support::z8_support_stats(z8_samples, 2);
    let (avg_frac, min_frac) = support::topk_weight_fraction(samples.min(200_000), 32, 3);
    eprintln!("MC done in {:.1}s", t0.elapsed().as_secs_f64());

    let infos = [exotic::Z8, exotic::E8, exotic::K12, exotic::BW16, exotic::LEECH];
    let mut t = Table::new(&[
        "Lattice", "Dim", "Det", "Packing", "Covering", "MinSupport", "AvgSupport", "MaxSupport",
    ]);
    for info in infos {
        let (min, max) = match info.name {
            "Z8" => (format!("{} (m.c.)", z8.min), format!("{} (m.c.)", z8.max)),
            "E8" => (format!("{} (m.c.)", e8.min), format!("{} (m.c.)", e8.max)),
            _ => ("-".into(), "-".into()),
        };
        t.row(&[
            info.name.to_string(),
            info.dim.to_string(),
            "1".into(),
            format!("{:.3}", info.packing_radius),
            format!("{:.3}", info.covering_radius),
            min,
            format!("{:.2}", info.avg_kernel_support()),
            max,
        ]);
    }
    println!("\n== Table 1 (paper: Z8 768/1039/1312, E8 45/64.94/121, K12 1138, L16 24704, L24 32373) ==\n");
    t.print();
    println!("\nE8 MC mean {:.3} (analytic {:.3})", e8.mean, exotic::E8.avg_kernel_support());
    println!(
        "top-32 weight capture: avg {:.2}%, min {:.2}%  (paper section 2.6: 99.5% / 90%)",
        avg_frac * 100.0,
        min_frac * 100.0
    );
    println!(
        "E8 vs Z8 average access ratio: {:.2}x (paper section 2.4: 16x)",
        exotic::Z8.avg_kernel_support() / exotic::E8.avg_kernel_support()
    );
}
