//! Design-choice ablations (DESIGN.md calls these out explicitly):
//!
//!  A. top-k restriction — weight captured and lookup cost vs k
//!     (the paper fixes k = 32: "99.5% of the weight on average, 90%
//!     minimum"; this sweep shows where that knee sits);
//!  B. kernel radius — the paper picks sqrt(2) x covering radius; what
//!     happens to support size and captured weight if the kernel were
//!     tighter/wider (changes candidate count, hence cost);
//!  C. lattice choice — Z^8 vs E8 access counts at equal spatial
//!     resolution (the §2.4 "16x fewer points" claim, measured);
//!  D. torus wrap (K_i = 4) vs no-wrap (K_i >= 8) lookup cost — the
//!     periodized-kernel case documented in DESIGN.md.
//!
//! Run: `cargo bench --bench ablations`

use lram::lattice::{e8, neighbors, support, LatticeLookup, TorusK};
use lram::util::rng::Rng;
use lram::util::timing::{bench, Table};

fn main() {
    let mut rng = Rng::new(42);

    // ---- A: top-k sweep -------------------------------------------------
    println!("\n== Ablation A: top-k restriction (paper: k = 32) ==\n");
    let mut t = Table::new(&["k", "avg weight %", "min weight %", "lookup us"]);
    for k in [4usize, 8, 16, 32, 64, 121] {
        let (avg, min) = support::topk_weight_fraction(20_000, k, 7);
        let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap();
        let mut lk = LatticeLookup::new(torus, k);
        let queries: Vec<[f64; 8]> =
            (0..256).map(|_| std::array::from_fn(|_| rng.uniform(-8.0, 8.0))).collect();
        let mut out = Default::default();
        let mut qi = 0;
        let s = bench(50, 2000, || {
            lk.lookup_into(&queries[qi & 255], &mut out);
            qi += 1;
        });
        t.row(&[
            k.to_string(),
            format!("{:.2}", avg * 100.0),
            format!("{:.2}", min * 100.0),
            format!("{:.2}", s.median_us()),
        ]);
    }
    t.print();
    println!("paper's k = 32 sits at the knee: ~99.5% avg weight at 1/4 the k = 121 cost.");

    // ---- B: kernel radius sweep ------------------------------------------
    println!("\n== Ablation B: kernel radius (paper: r0 = sqrt(8), = sqrt(2) x covering) ==\n");
    let mut t = Table::new(&["radius/sqrt(8)", "avg support", "avg weight(top32)/total"]);
    for scale in [0.75f64, 0.875, 1.0, 1.125, 1.25] {
        let r2 = 8.0 * scale * scale;
        // support size via MC on the candidate table (radius <= sqrt(8)
        // covered by the 232-table; larger radii need the full shell)
        let mut rng2 = Rng::new(11);
        let (mut count_sum, mut frac_sum) = (0u64, 0.0f64);
        let n = 20_000;
        let mut weights: Vec<f64> = Vec::with_capacity(232);
        for _ in 0..n {
            let q: [f64; 8] = std::array::from_fn(|_| rng2.uniform(0.0, 8.0));
            let red = e8::reduce(&q);
            weights.clear();
            let mut total = 0.0;
            let mut cnt = 0u64;
            for c in neighbors::neighbor_table_f64().iter() {
                let mut d2 = 0.0;
                for j in 0..8 {
                    let d = red.z[j] - c[j];
                    d2 += d * d;
                }
                if d2 < r2.min(8.0 + 1e-9) {
                    cnt += 1;
                    // renormalised kernel on the scaled support
                    let w = (1.0 - d2 / r2).max(0.0).powi(4);
                    total += w;
                    weights.push(w);
                }
            }
            count_sum += cnt;
            weights.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let kept: f64 = weights.iter().take(32).sum();
            frac_sum += if total > 0.0 { kept / total } else { 1.0 };
        }
        t.row(&[
            format!("{scale:.3}"),
            format!("{:.1}", count_sum as f64 / n as f64),
            format!("{:.3}", frac_sum / n as f64),
        ]);
    }
    t.print();
    println!("(radii above sqrt(8) truncated to the 232-candidate shell; the paper's");
    println!(" choice makes every query interior to some kernel while keeping ~65 points.)");

    // ---- C: Z8 vs E8 ------------------------------------------------------
    println!("\n== Ablation C: lattice choice at equal resolution (paper §2.4) ==\n");
    let e8s = support::e8_support_stats(100_000, 3);
    let z8s = support::z8_support_stats(5_000, 4);
    let mut t = Table::new(&["lattice", "avg points / lookup", "ratio"]);
    t.row(&["E8".into(), format!("{:.2}", e8s.mean), "1.0".into()]);
    t.row(&[
        "Z8".into(),
        format!("{:.2}", z8s.mean),
        format!("{:.2}x", z8s.mean / e8s.mean),
    ]);
    t.print();

    // ---- D: wrap vs no-wrap torus ------------------------------------------
    println!("\n== Ablation D: torus wrap (periodized kernel, min K_i = 4) ==\n");
    let mut t = Table::new(&["K", "slots", "lookup us", "avg distinct slots/query"]);
    for k in [[4i64, 4, 4, 4, 4, 4, 4, 4], [8, 8, 8, 8, 8, 8, 4, 4], [8; 8]] {
        let torus = TorusK::new(k).unwrap();
        let mut lk = LatticeLookup::new(torus, 32);
        let queries: Vec<[f64; 8]> =
            (0..256).map(|_| std::array::from_fn(|_| rng.uniform(-8.0, 8.0))).collect();
        let mut distinct = 0usize;
        for q in &queries {
            let r = lk.lookup(q);
            let set: std::collections::HashSet<u64> =
                r.hits.iter().map(|h| h.index).collect();
            distinct += set.len();
        }
        let mut out = Default::default();
        let mut qi = 0;
        let s = bench(50, 2000, || {
            lk.lookup_into(&queries[qi & 255], &mut out);
            qi += 1;
        });
        t.row(&[
            format!("{:?}", k),
            torus.num_locations().to_string(),
            format!("{:.2}", s.median_us()),
            format!("{:.1}", distinct as f64 / queries.len() as f64),
        ]);
    }
    t.print();
    println!("wrap cost is identical (same 232 candidates); tight tori just alias");
    println!("multiple lifts onto fewer distinct slots (periodized kernel, DESIGN.md).");
}
