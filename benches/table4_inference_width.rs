//! Table 4 reproduction: inference time per vector (µs) as a function of
//! width, dense `w -> 4w -> w` vs the LRAM layer in split mode.
//!
//! The paper's crossover (LRAM faster than dense beyond w ~ 8192 on an
//! RTX 3090) translates here to: dense cost grows ~ w^2 while LRAM cost
//! grows ~ w·(w/4 + const) with a much smaller quadratic coefficient
//! (5/8 of dense per Table 3), so the *ratio* dense/LRAM must grow
//! monotonically with width — that shape, not the absolute µs, is the
//! reproduction target.
//!
//! Run: `cargo bench --bench table4_inference_width [-- --widths 256,512,1024,2048 --n 2^18]`

use lram::runtime::Runtime;
use lram::splitmode::{DenseLayer, SplitLramLayer};
use lram::util::cli::Args;
use lram::util::rng::Rng;
use lram::util::timing::{bench, Table};

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let widths = args.u64_list("widths", &[256, 512, 1024, 2048])?;
    let locations = args.u64("n", 1 << 18)?;
    let samples = args.usize("samples", 15)?; // paper: median of 15 runs

    let rt = match Runtime::new(args.str("artifacts", "artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("Table 4 needs the PJRT runtime + artifacts; skipping ({e:#})");
            return Ok(());
        }
    };
    let mut table = Table::new(&[
        "Width", "Dense us/vec", "LRAM us/vec", "dense/lram", "LRAM params",
    ]);
    let mut rng = Rng::new(4);
    for &w in &widths {
        let w = w as usize;
        let mut dense = match DenseLayer::load(&rt, w) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("skipping width {w}: {e:#}");
                continue;
            }
        };
        let mut lram = SplitLramLayer::load(&rt, w, locations, false)?;
        let b = dense.batch;
        let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();

        let ds = bench(3, samples, || {
            dense.run(&x).unwrap();
        });
        let ls = bench(3, samples, || {
            lram.run(&x).unwrap();
        });
        let d_us = ds.median_us() / b as f64;
        let l_us = ls.median_us() / b as f64;
        table.row(&[
            w.to_string(),
            format!("{d_us:.2}"),
            format!("{l_us:.2}"),
            format!("{:.3}", d_us / l_us),
            format!("{:.1}M", lram.param_count() as f64 / 1e6),
        ]);
    }
    println!("\n== Table 4 (N = {locations} memory locations; batch-amortised, median of {samples}) ==\n");
    table.print();
    println!("\npaper shape: dense/lram ratio grows with width (crossover ~w=8192 on GPU).");
    Ok(())
}
