//! L3 hot-path microbenchmarks: the pure-rust lattice lookup (used by the
//! memstore/serving gather accounting) and the memstore row gather —
//! scalar reference vs the batched SoA engine (`lattice::batch`).
//!
//! Alongside the human-readable table this writes machine-readable
//! results to `BENCH_lattice.json` (parseable with `lram::util::json`;
//! see `util::timing::BenchReport`) so later PRs can track the perf
//! trajectory.  The headline row is batch-256 lookup+gather: the fused
//! engine must beat the seed scalar path by >= 3x single-threaded.
//!
//! Run: `cargo bench --bench lattice_hot_path`

use lram::lattice::{simd, BatchLookupEngine, BatchOutput, LatticeLookup, TorusK};
use lram::memstore::{QuantizedValueTable, ValueTable};
use lram::util::rng::Rng;
use lram::util::timing::{bench, host_fingerprint, BenchReport, Table};

fn torus() -> TorusK {
    TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap()
}

fn main() {
    let mut table = Table::new(&["op", "median", "p90", "per-unit"]);
    let mut report = BenchReport::new("lattice_hot_path");

    // single lookup (reduce + 232 scores + top-32 + index)
    let mut lk = LatticeLookup::new(torus(), 32);
    let mut rng = Rng::new(1);
    let queries: Vec<[f64; 8]> = (0..1024)
        .map(|_| std::array::from_fn(|_| rng.uniform(-8.0, 8.0)))
        .collect();
    let mut out = Default::default();
    let mut qi = 0;
    let s = bench(200, 4096, || {
        lk.lookup_into(&queries[qi & 1023], &mut out);
        qi += 1;
    });
    table.row(&[
        "scalar lookup".into(),
        format!("{:.2} us", s.median_us()),
        format!("{:.2} us", s.p90_ns / 1e3),
        format!("{:.1} ns/candidate", s.median_ns / 232.0),
    ]);
    report.entry("scalar_lookup", &[("median_us", s.median_us()), ("p90_us", s.p90_ns / 1e3)]);

    // quantize alone
    let s = bench(200, 4096, || {
        let q = &queries[qi & 1023];
        std::hint::black_box(lram::lattice::quantize(q));
        qi += 1;
    });
    table.row(&[
        "quantize (2 cosets)".into(),
        format!("{:.0} ns", s.median_ns),
        format!("{:.0} ns", s.p90_ns),
        "-".into(),
    ]);
    report.entry("quantize", &[("median_ns", s.median_ns)]);

    // memstore gather: 32 rows x 64 floats from a 2^22-row table
    let mut vt = ValueTable::zeros(1 << 22, 64).unwrap();
    vt.randomize(3, 0.02);
    let idx: Vec<u64> = (0..32 * 1024).map(|_| rng.below(1 << 22)).collect();
    let mut buf = vec![0.0f32; 32 * 64];
    let mut gi = 0;
    let s = bench(100, 4096, || {
        let base = (gi & 1023) * 32;
        vt.gather_rows(&idx[base..base + 32], &mut buf);
        gi += 1;
    });
    table.row(&[
        "gather 32x64 @ 2^22 rows".into(),
        format!("{:.2} us", s.median_us()),
        format!("{:.2} us", s.p90_ns / 1e3),
        format!("{:.1} ns/row", s.median_ns / 32.0),
    ]);
    report.entry("gather_rows_32x64", &[("median_us", s.median_us())]);

    // weighted gather (fused combine)
    let wts = vec![0.03125f32; 32];
    let mut acc = vec![0.0f32; 64];
    let s = bench(100, 4096, || {
        let base = (gi & 1023) * 32;
        vt.gather_weighted(&idx[base..base + 32], &wts, &mut acc);
        gi += 1;
    });
    table.row(&[
        "weighted gather 32x64".into(),
        format!("{:.2} us", s.median_us()),
        format!("{:.2} us", s.p90_ns / 1e3),
        format!("{:.1} ns/row", s.median_ns / 32.0),
    ]);
    report.entry("gather_weighted_32x64", &[("median_us", s.median_us())]);

    // ---- batched SoA engine: lookup throughput --------------------------
    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // flat query pool: 4096 queries, batches rotate over disjoint windows
    let pool: Vec<f64> = (0..4096 * 8).map(|_| rng.uniform(-8.0, 8.0)).collect();
    let thread_opts: Vec<usize> = if n_threads > 1 { vec![1, n_threads] } else { vec![1] };
    let mut soa = BatchOutput::default();
    for &batch in &[1usize, 32, 256, 1024] {
        for &threads in &thread_opts {
            if threads > 1 && batch < 32 {
                continue; // sharding a tiny batch is pure overhead
            }
            let engine = BatchLookupEngine::with_threads(torus(), 32, threads);
            let mut bi = 0;
            let samples = if batch >= 1024 { 256 } else { 2048 };
            let s = bench(32, samples, || {
                let start = (bi & 3) * batch * 8;
                engine.lookup_batch_into(&pool[start..start + batch * 8], &mut soa);
                bi += 1;
            });
            let qps = batch as f64 / (s.median_ns / 1e9);
            table.row(&[
                format!("engine lookup b={batch} t={threads}"),
                format!("{:.2} us", s.median_us()),
                format!("{:.2} us", s.p90_ns / 1e3),
                format!("{:.2} Mq/s", qps / 1e6),
            ]);
            report.entry(
                &format!("engine_lookup_b{batch}_t{threads}"),
                &[
                    ("batch", batch as f64),
                    ("threads", threads as f64),
                    ("median_us", s.median_us()),
                    ("qps", qps),
                ],
            );
        }
    }

    // ---- headline: batch-256 lookup+gather, scalar seed path vs fused --
    let mut gtab = ValueTable::zeros(1 << 18, 64).unwrap();
    gtab.randomize(5, 0.02);
    let batch = 256usize;

    // seed scalar path: per-query lookup (allocating Vec<Hit>) followed
    // by a per-query weighted gather — what consumers did before the
    // engine existed
    let mut scalar_out = vec![0.0f32; 64];
    let mut bi = 0;
    let s_scalar = bench(8, 64, || {
        let start = (bi & 3) * batch * 8;
        let results = lk.lookup_batch(&pool[start..start + batch * 8]);
        for r in &results {
            let idx: Vec<u64> = r.hits.iter().map(|h| h.index).collect();
            let w: Vec<f32> = r.hits.iter().map(|h| h.weight as f32).collect();
            gtab.gather_weighted(&idx, &w, &mut scalar_out);
        }
        std::hint::black_box(&scalar_out);
        bi += 1;
    });
    table.row(&[
        format!("scalar lookup+gather b={batch}"),
        format!("{:.2} us", s_scalar.median_us()),
        format!("{:.2} us", s_scalar.p90_ns / 1e3),
        format!("{:.2} Mq/s", batch as f64 * 1e3 / s_scalar.median_ns),
    ]);
    report.entry(
        "scalar_lookup_gather_b256",
        &[
            ("batch", batch as f64),
            ("median_us", s_scalar.median_us()),
            ("qps", batch as f64 / (s_scalar.median_ns / 1e9)),
        ],
    );

    let mut fused = vec![0.0f32; batch * 64];
    let mut speedup_t1 = 0.0;
    let mut f64_t1_median_ns = 0.0;
    for &threads in &thread_opts {
        let engine = BatchLookupEngine::with_threads(torus(), 32, threads);
        let s_fused = bench(16, 256, || {
            let start = (bi & 3) * batch * 8;
            engine.lookup_gather_into(&pool[start..start + batch * 8], &gtab, &mut soa, &mut fused);
            bi += 1;
        });
        let speedup = s_scalar.median_ns / s_fused.median_ns;
        if threads == 1 {
            speedup_t1 = speedup;
            f64_t1_median_ns = s_fused.median_ns;
        }
        table.row(&[
            format!("engine lookup+gather b={batch} t={threads}"),
            format!("{:.2} us", s_fused.median_us()),
            format!("{:.2} us", s_fused.p90_ns / 1e3),
            format!("{speedup:.2}x vs scalar"),
        ]);
        report.entry(
            &format!("engine_lookup_gather_b{batch}_t{threads}"),
            &[
                ("batch", batch as f64),
                ("threads", threads as f64),
                ("median_us", s_fused.median_us()),
                ("qps", batch as f64 / (s_fused.median_ns / 1e9)),
                ("speedup_vs_scalar", speedup),
            ],
        );
    }

    // ---- f32 SIMD serving path vs the f64 engine (same run, same iron) --
    // the gate field is the same-run ratio f32_speedup_vs_f64, which is
    // machine-independent (unlike raw qps); see docs/performance.md
    {
        let engine = BatchLookupEngine::with_threads(torus(), 32, 1);
        let s_f32 = bench(16, 256, || {
            let start = (bi & 3) * batch * 8;
            engine.lookup_gather_ragged_f32_into(
                &pool[start..start + batch * 8],
                &gtab,
                &mut soa,
                &mut fused,
            );
            bi += 1;
        });
        let f32_speedup = f64_t1_median_ns / s_f32.median_ns;
        table.row(&[
            format!("f32 [{}] lookup+gather b={batch} t=1", simd::active_kernel_name()),
            format!("{:.2} us", s_f32.median_us()),
            format!("{:.2} us", s_f32.p90_ns / 1e3),
            format!("{f32_speedup:.2}x vs f64"),
        ]);
        report.entry(
            "engine_lookup_gather_f32_b256_t1",
            &[
                ("batch", batch as f64),
                ("threads", 1.0),
                ("median_us", s_f32.median_us()),
                ("qps", batch as f64 / (s_f32.median_ns / 1e9)),
                ("f32_speedup_vs_f64", f32_speedup),
            ],
        );

        let qtab = QuantizedValueTable::from_table(&gtab).unwrap();
        let s_q8 = bench(16, 256, || {
            let start = (bi & 3) * batch * 8;
            engine.lookup_gather_ragged_q8_into(
                &pool[start..start + batch * 8],
                &qtab,
                &mut soa,
                &mut fused,
            );
            bi += 1;
        });
        let q8_speedup = f64_t1_median_ns / s_q8.median_ns;
        table.row(&[
            format!("f32-q8 [{}] lookup+gather b={batch} t=1", simd::active_kernel_name()),
            format!("{:.2} us", s_q8.median_us()),
            format!("{:.2} us", s_q8.p90_ns / 1e3),
            format!("{q8_speedup:.2}x vs f64"),
        ]);
        report.entry(
            "engine_lookup_gather_q8_b256_t1",
            &[
                ("batch", batch as f64),
                ("threads", 1.0),
                ("median_us", s_q8.median_us()),
                ("qps", batch as f64 / (s_q8.median_ns / 1e9)),
                ("q8_speedup_vs_f64", q8_speedup),
            ],
        );
    }

    // ---- sharded staged path: 4 shard workers vs 1 (same run) -----------
    // the gate field is the same-run ratio shard4_speedup_vs_shard1 —
    // fanning the staged score/select/gather across 4 owners must not
    // cost more than 10% vs one owner (floor 0.9x; on multi-core iron it
    // should win outright)
    {
        use lram::lattice::ShardPlan;
        use lram::model::{ShardedMemory, ValueShard};
        let rows = gtab.rows();
        let dim = 64usize;
        let engine = BatchLookupEngine::with_threads(torus(), 32, 1);
        let make = |n: usize| -> ShardedMemory {
            let plan = ShardPlan::new(rows, n);
            let mut shards = Vec::with_capacity(n);
            for s in 0..n {
                let r = plan.range(s);
                let owned = (r.end - r.start).max(1);
                let mut t = ValueTable::zeros(owned, dim).unwrap();
                if r.end > r.start {
                    t.load_from(&gtab.data()[r.start as usize * dim..r.end as usize * dim])
                        .unwrap();
                }
                shards.push(ValueShard { base: r.start, table: t, q8: None });
            }
            ShardedMemory::new(&engine, plan, shards).unwrap()
        };
        let mut sh1 = make(1);
        let mut sh4 = make(4);
        let s_sh1 = bench(16, 128, || {
            let start = (bi & 3) * batch * 8;
            sh1.lookup_gather(&pool[start..start + batch * 8], false, false, &mut soa, &mut fused)
                .unwrap();
            bi += 1;
        });
        let s_sh4 = bench(16, 128, || {
            let start = (bi & 3) * batch * 8;
            sh4.lookup_gather(&pool[start..start + batch * 8], false, false, &mut soa, &mut fused)
                .unwrap();
            bi += 1;
        });
        let shard4_speedup = s_sh1.median_ns / s_sh4.median_ns;
        table.row(&[
            format!("sharded lookup+gather b={batch} shards=1"),
            format!("{:.2} us", s_sh1.median_us()),
            format!("{:.2} us", s_sh1.p90_ns / 1e3),
            format!("{:.2} Mq/s", batch as f64 * 1e3 / s_sh1.median_ns),
        ]);
        table.row(&[
            format!("sharded lookup+gather b={batch} shards=4"),
            format!("{:.2} us", s_sh4.median_us()),
            format!("{:.2} us", s_sh4.p90_ns / 1e3),
            format!("{shard4_speedup:.2}x vs shards=1"),
        ]);
        report.entry(
            "engine_sharded_gather_b256",
            &[
                ("batch", batch as f64),
                ("shards", 4.0),
                ("median_us", s_sh4.median_us()),
                ("qps", batch as f64 / (s_sh4.median_ns / 1e9)),
                ("shard1_qps", batch as f64 / (s_sh1.median_ns / 1e9)),
                ("shard4_speedup_vs_shard1", shard4_speedup),
            ],
        );
    }

    // ---- serving throughput: the pure-rust EngineBackend ----------------
    // full-stack fill-mask batch (embed -> query projection -> fused
    // lattice lookup+gather -> combine -> vocab log-softmax): what one
    // serving shard sustains with no artifacts anywhere
    {
        use lram::server::{EngineBackend, EngineConfig, InferenceBackend};
        let cfg = EngineConfig { track_stats: false, ..EngineConfig::default() };
        let (b_max, seq_len) = (cfg.max_batch, cfg.seq_len);
        let vocab = 4096usize;
        let mut backend = EngineBackend::new(cfg, vocab).unwrap();
        let tokens: Vec<i32> =
            (0..(b_max * seq_len) as i32).map(|i| 5 + (i * 131) % (vocab as i32 - 5)).collect();
        let s = bench(4, 24, || {
            std::hint::black_box(backend.infer(&tokens).unwrap());
        });
        let req_s = b_max as f64 / (s.median_ns / 1e9);
        table.row(&[
            format!("engine-backend serve b={b_max} seq={seq_len}"),
            format!("{:.2} ms", s.median_ns / 1e6),
            format!("{:.2} ms", s.p90_ns / 1e6),
            format!("{req_s:.0} req/s"),
        ]);
        report.entry(
            "engine_backend_serve_b8",
            &[
                ("batch", b_max as f64),
                ("seq_len", seq_len as f64),
                ("median_ms", s.median_ns / 1e6),
                ("requests_per_s", req_s),
            ],
        );
    }

    println!("\n== L3 hot-path microbench ==\n");
    println!("simd dispatch: {} (LRAM_SIMD=off forces scalar)\n", simd::active_kernel_name());
    table.print();
    println!(
        "\nheadline: fused engine b256 t1 is {speedup_t1:.2}x the seed scalar path \
         (acceptance floor: 3x)"
    );
    report.set_host(&host_fingerprint());
    match report.write("BENCH_lattice.json") {
        Ok(()) => println!("machine-readable results -> BENCH_lattice.json"),
        Err(e) => eprintln!("could not write BENCH_lattice.json: {e}"),
    }
}
