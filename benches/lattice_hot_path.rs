//! L3 hot-path microbenchmarks: the pure-rust lattice lookup (used by the
//! memstore/serving gather accounting) and the memstore row gather.
//! These are the pieces the perf pass tunes; see EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench lattice_hot_path`

use lram::lattice::{LatticeLookup, TorusK};
use lram::memstore::ValueTable;
use lram::util::rng::Rng;
use lram::util::timing::{bench, Table};

fn main() {
    let mut table = Table::new(&["op", "median", "p90", "per-unit"]);

    // single lookup (reduce + 232 scores + top-32 + index)
    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap();
    let mut lk = LatticeLookup::new(torus, 32);
    let mut rng = Rng::new(1);
    let queries: Vec<[f64; 8]> = (0..1024)
        .map(|_| std::array::from_fn(|_| rng.uniform(-8.0, 8.0)))
        .collect();
    let mut out = Default::default();
    let mut qi = 0;
    let s = bench(200, 4096, || {
        lk.lookup_into(&queries[qi & 1023], &mut out);
        qi += 1;
    });
    table.row(&[
        "lattice lookup".into(),
        format!("{:.2} us", s.median_us()),
        format!("{:.2} us", s.p90_ns / 1e3),
        format!("{:.1} ns/candidate", s.median_ns / 232.0),
    ]);

    // quantize alone
    let s = bench(200, 4096, || {
        let q = &queries[qi & 1023];
        std::hint::black_box(lram::lattice::quantize(q));
        qi += 1;
    });
    table.row(&[
        "quantize (2 cosets)".into(),
        format!("{:.0} ns", s.median_ns),
        format!("{:.0} ns", s.p90_ns),
        "-".into(),
    ]);

    // memstore gather: 32 rows x 64 floats from a 2^22-row table
    let mut vt = ValueTable::zeros(1 << 22, 64).unwrap();
    vt.randomize(3, 0.02);
    let idx: Vec<u64> = (0..32 * 1024).map(|_| rng.below(1 << 22)).collect();
    let mut buf = vec![0.0f32; 32 * 64];
    let mut gi = 0;
    let s = bench(100, 4096, || {
        let base = (gi & 1023) * 32;
        vt.gather_rows(&idx[base..base + 32], &mut buf);
        gi += 1;
    });
    table.row(&[
        "gather 32x64 @ 2^22 rows".into(),
        format!("{:.2} us", s.median_us()),
        format!("{:.2} us", s.p90_ns / 1e3),
        format!("{:.1} ns/row", s.median_ns / 32.0),
    ]);

    // weighted gather (fused combine)
    let wts = vec![0.03125f32; 32];
    let mut acc = vec![0.0f32; 64];
    let s = bench(100, 4096, || {
        let base = (gi & 1023) * 32;
        vt.gather_weighted(&idx[base..base + 32], &wts, &mut acc);
        gi += 1;
    });
    table.row(&[
        "weighted gather 32x64".into(),
        format!("{:.2} us", s.median_us()),
        format!("{:.2} us", s.p90_ns / 1e3),
        format!("{:.1} ns/row", s.median_ns / 32.0),
    ]);

    println!("\n== L3 hot-path microbench ==\n");
    table.print();
}
