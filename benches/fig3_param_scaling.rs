//! Figure 3 reproduction: forward-pass time through the layer vs total
//! parameter count — LRAM (flat: O(1) lookup + rust O(1) gather), PKM
//! (grows as sqrt(N) in the scoring prefix), dense (a single point).
//!
//! Each measurement is the median of 15 successive runs divided by the
//! minibatch size, matching the paper's protocol.  The value tables live
//! in lazily-populated mmaps, so the billion-parameter points cost
//! physical memory only for rows actually gathered — the honest analogue
//! of the paper's "random access over the parameter storage" model.
//!
//! Run: `cargo bench --bench fig3_param_scaling [-- --widths 256,1024]`

use lram::pkm::cost;
use lram::runtime::Runtime;
use lram::splitmode::{DenseLayer, SplitLramLayer, SplitPkmLayer};
use lram::util::cli::Args;
use lram::util::rng::Rng;
use lram::util::timing::{bench, Table};

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let widths = args.u64_list("widths", &[256, 1024])?;
    let samples = args.usize("samples", 15)?;
    let lram_ns = args.u64_list("lram-n", &[1 << 14, 1 << 18, 1 << 22, 1 << 24])?;
    let pkm_keys = args.u64_list("pkm-keys", &[64, 128, 256, 512, 1024, 2048])?;

    let rt = Runtime::new(args.str("artifacts", "artifacts"))?;
    let mut rng = Rng::new(9);

    for &w in &widths {
        let w = w as usize;
        println!("\n== Figure 3, width w = {w} (us per vector, median of {samples}) ==\n");
        let mut table = Table::new(&["layer", "total params", "us/vec", "notes"]);

        if let Ok(mut dense) = DenseLayer::load(&rt, w) {
            let b = dense.batch;
            let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();
            let s = bench(3, samples, || {
                dense.run(&x).unwrap();
            });
            table.row(&[
                "dense".into(),
                format!("{:.2e}", cost::dense_params(w as u64, 4) as f64),
                format!("{:.2}", s.median_us() / b as f64),
                "single point".into(),
            ]);
        }

        for &n in &lram_ns {
            match SplitLramLayer::load(&rt, w, n, false) {
                Ok(mut lram) => {
                    let b = lram.batch;
                    let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();
                    let s = bench(3, samples, || {
                        lram.run(&x).unwrap();
                    });
                    table.row(&[
                        "LRAM".into(),
                        format!("{:.2e}", lram.param_count() as f64),
                        format!("{:.2}", s.median_us() / b as f64),
                        format!("N = 2^{}", (n as f64).log2() as u32),
                    ]);
                }
                Err(e) => eprintln!("LRAM N={n}: skipped ({e})"),
            }
        }

        for &nk in &pkm_keys {
            match SplitPkmLayer::load(&rt, w, nk as usize) {
                Ok(mut pkm) => {
                    let b = pkm.batch;
                    let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();
                    let s = bench(3, samples, || {
                        pkm.run(&x).unwrap();
                    });
                    table.row(&[
                        "PKM".into(),
                        format!("{:.2e}", pkm.param_count() as f64),
                        format!("{:.2}", s.median_us() / b as f64),
                        format!("sqrt(N) = {nk}"),
                    ]);
                }
                Err(e) => eprintln!("PKM nk={nk}: skipped ({e})"),
            }
        }
        table.print();
    }
    println!(
        "\npaper shape: LRAM essentially flat in N; PKM grows with sqrt(N); \
         LRAM faster than PKM across the board (1.8x..3.4x on GPU)."
    );
    Ok(())
}
