//! Figure 3 reproduction: forward-pass time through the layer vs total
//! parameter count — LRAM (flat: O(1) lookup + rust O(1) gather), PKM
//! (grows as sqrt(N) in the scoring prefix), dense (a single point).
//!
//! Each measurement is the median of 15 successive runs divided by the
//! minibatch size, matching the paper's protocol.  The value tables live
//! in lazily-populated mmaps, so the billion-parameter points cost
//! physical memory only for rows actually gathered — the honest analogue
//! of the paper's "random access over the parameter storage" model.
//!
//! Two families of rows:
//!
//! * **LRAM (rust engine)** — the pure-rust fused batch pipeline
//!   (`lattice::batch::BatchLookupEngine`), runnable with no artifacts:
//!   reduce → score → top-32 → torus index → weighted gather per query.
//!   This is the paper's O(1)-in-N claim measured end to end in rust.
//! * **dense / LRAM / PKM (split mode)** — the AOT'd HLO prefix/suffix
//!   around the rust gather; skipped with a note when the PJRT backend
//!   or the artifacts are unavailable.
//!
//! Run: `cargo bench --bench fig3_param_scaling [-- --widths 256,1024]`

use lram::lattice::{BatchLookupEngine, BatchOutput, TorusK};
use lram::memstore::ValueTable;
use lram::pkm::cost;
use lram::runtime::Runtime;
use lram::splitmode::{DenseLayer, SplitLramLayer, SplitPkmLayer};
use lram::util::cli::Args;
use lram::util::rng::Rng;
use lram::util::timing::{bench, Table};

/// Torus with `locations` slots (a power of two >= 2^8): distribute the
/// binary factors over the eight periods, largest first.
fn torus_for(locations: u64) -> Option<TorusK> {
    if !locations.is_power_of_two() {
        return None;
    }
    let l = locations.trailing_zeros();
    if l < 8 {
        return None;
    }
    let mut exp = [0u32; 8];
    for i in 0..(l - 8) as usize {
        exp[i % 8] += 1;
    }
    TorusK::new(std::array::from_fn(|j| 4i64 << exp[j])).ok()
}

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let widths = args.u64_list("widths", &[256, 1024])?;
    let samples = args.usize("samples", 15)?;
    let lram_ns = args.u64_list("lram-n", &[1 << 14, 1 << 18, 1 << 22, 1 << 24])?;
    let pkm_keys = args.u64_list("pkm-keys", &[64, 128, 256, 512, 1024, 2048])?;

    let rt = match Runtime::new(args.str("artifacts", "artifacts")) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("PJRT unavailable ({e:#}); split-mode rows skipped, engine rows still run");
            None
        }
    };
    let mut rng = Rng::new(9);

    for &w in &widths {
        let w = w as usize;
        println!("\n== Figure 3, width w = {w} (us per vector, median of {samples}) ==\n");
        let mut table = Table::new(&["layer", "total params", "us/vec", "notes"]);

        // pure-rust engine rows: m = 64-dim values, batch 256, k = 32
        let (b, m) = (256usize, 64usize);
        for &n in &lram_ns {
            let Some(torus) = torus_for(n) else {
                eprintln!("engine N={n}: not a power-of-two slot count, skipped");
                continue;
            };
            let mut vt = ValueTable::zeros(n, m)?;
            vt.randomize_rows(0xF16, 0.02, n.min(1 << 18));
            let engine = BatchLookupEngine::new(torus, 32);
            let queries: Vec<f64> = (0..b * 8).map(|_| rng.uniform(-8.0, 8.0)).collect();
            let mut lk = BatchOutput::default();
            let mut out = vec![0.0f32; b * m];
            let s = bench(3, samples, || {
                engine.lookup_gather_into(&queries, &vt, &mut lk, &mut out);
            });
            table.row(&[
                "LRAM (rust engine)".into(),
                format!("{:.2e}", vt.param_count() as f64),
                format!("{:.2}", s.median_us() / b as f64),
                format!("N = 2^{}", (n as f64).log2() as u32),
            ]);
        }

        if let Some(rt) = &rt {
            if let Ok(mut dense) = DenseLayer::load(rt, w) {
                let b = dense.batch;
                let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();
                let s = bench(3, samples, || {
                    dense.run(&x).unwrap();
                });
                table.row(&[
                    "dense".into(),
                    format!("{:.2e}", cost::dense_params(w as u64, 4) as f64),
                    format!("{:.2}", s.median_us() / b as f64),
                    "single point".into(),
                ]);
            }

            for &n in &lram_ns {
                match SplitLramLayer::load(rt, w, n, false) {
                    Ok(mut lram) => {
                        let b = lram.batch;
                        let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();
                        let s = bench(3, samples, || {
                            lram.run(&x).unwrap();
                        });
                        table.row(&[
                            "LRAM (split)".into(),
                            format!("{:.2e}", lram.param_count() as f64),
                            format!("{:.2}", s.median_us() / b as f64),
                            format!("N = 2^{}", (n as f64).log2() as u32),
                        ]);
                    }
                    Err(e) => eprintln!("LRAM N={n}: skipped ({e})"),
                }
            }

            for &nk in &pkm_keys {
                match SplitPkmLayer::load(rt, w, nk as usize) {
                    Ok(mut pkm) => {
                        let b = pkm.batch;
                        let x: Vec<f32> = (0..b * w).map(|_| rng.normal() as f32).collect();
                        let s = bench(3, samples, || {
                            pkm.run(&x).unwrap();
                        });
                        table.row(&[
                            "PKM".into(),
                            format!("{:.2e}", pkm.param_count() as f64),
                            format!("{:.2}", s.median_us() / b as f64),
                            format!("sqrt(N) = {nk}"),
                        ]);
                    }
                    Err(e) => eprintln!("PKM nk={nk}: skipped ({e})"),
                }
            }
        }
        table.print();
    }
    println!(
        "\npaper shape: LRAM essentially flat in N; PKM grows with sqrt(N); \
         LRAM faster than PKM across the board (1.8x..3.4x on GPU)."
    );
    Ok(())
}
