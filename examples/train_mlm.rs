//! End-to-end driver (DESIGN.md deliverable): train a masked language
//! model with an LRAM memory layer on the synthetic corpus, through the
//! full three-layer stack — rust data pipeline + coordinator, AOT'd JAX
//! train step, Pallas lattice kernel — and log the loss curve.
//!
//! Run:  cargo run --release --example train_mlm -- \
//!           [--variant lram_small] [--steps 300] [--eval-every 50]
//!
//! Outputs land in runs/<variant>-e2e/: trainloss.csv, valcurve.csv
//! (Figure-2 format), final.ckpt.  Record results in EXPERIMENTS.md.

use std::sync::Arc;

use lram::config::TrainConfig;
use lram::coordinator::Trainer;
use lram::runtime::Runtime;
use lram::util::cli::Args;

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let variant = args.str("variant", "lram_small");
    let mut cfg = TrainConfig {
        variant: variant.clone(),
        run_dir: args.str("run-dir", &format!("runs/{variant}-e2e")),
        steps: args.u64("steps", 300)?,
        eval_every: args.u64("eval-every", 50)?,
        eval_batches: args.u64("eval-batches", 8)?,
        ..TrainConfig::default()
    };
    cfg.artifact_dir = args.str("artifacts", "artifacts");

    let rt = Arc::new(Runtime::new(&cfg.artifact_dir)?);
    let params = rt
        .load(&format!("train_step_{variant}"))?
        .manifest
        .n_params
        .unwrap_or(0);
    println!(
        "training {variant} ({:.1}M params) for {} steps on the synthetic corpus",
        params as f64 / 1e6,
        cfg.steps
    );

    let mut trainer = Trainer::new(rt, cfg)?;
    let out = trainer.run()?;
    let test = trainer.evaluate_test()?;

    println!("\n=== E2E result ({}) ===", out.variant);
    println!("steps            : {}", out.steps);
    println!("final train loss : {:.4}", out.final_train_loss);
    println!("best val ppl     : {:.3}", out.best_val_ppl);
    println!("final val ppl    : {:.3}", out.final_val.perplexity);
    println!("test ppl         : {:.3}", test.perplexity);
    if let (Some(u), Some(kl)) = (out.final_val.utilization, out.final_val.kl_divergence) {
        println!("memory usage %   : {:.2}   (Table 5)", u * 100.0);
        println!("KL(access||unif) : {:.3}  (Table 5)", kl);
    }
    println!("wall time        : {:.1}s", out.wall_secs);
    println!("loss curve       : {}/valcurve.csv", out.run_dir.display());
    Ok(())
}
