//! Serving demo: start the fill-mask router, fire a few concurrent
//! requests at it from client threads, print predictions + batching
//! stats.  Demonstrates the vLLM-style dynamic batcher with python
//! nowhere on the request path.
//!
//! Run:  cargo run --release --example serve_mlm -- \
//!           [--variant lram_small] [--checkpoint runs/.../final.ckpt]
//!           [--requests 12]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::server::{serve, Batcher, BatcherConfig, BatcherInit};
use lram::util::cli::Args;

fn http_post(addr: &str, body: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    Ok(resp)
}

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let variant = args.str("variant", "lram_small");
    let addr = args.str("addr", "127.0.0.1:8077");
    let n_requests = args.usize("requests", 12)?;

    let checkpoint = match args.flags.get("checkpoint") {
        Some(p) => Some(std::fs::read(p)?),
        None => None,
    };
    let pipeline = DataPipeline::new(CorpusSpec::default(), 4096, 8, 1, 0.15)?;
    let bpe = Arc::new(pipeline.bpe);
    let batcher = match Batcher::spawn(
        BatcherInit {
            artifact_dir: args.str("artifacts", "artifacts"),
            artifact_name: format!("infer_logits_{variant}"),
            checkpoint,
        },
        bpe.clone(),
        BatcherConfig::default(),
    ) {
        Ok(b) => b,
        Err(e) => {
            eprintln!(
                "serving artifacts unavailable ({e:#});\nrunning the offline batch-engine \
                 demo instead\n"
            );
            return offline_engine_demo();
        }
    };
    {
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        let addr = addr.clone();
        std::thread::spawn(move || serve(&addr, batcher, bpe));
    }
    std::thread::sleep(std::time::Duration::from_millis(300));
    println!("server on http://{addr}; firing {n_requests} concurrent requests\n");

    let corpus = lram::data::synth::SynthCorpus::new(CorpusSpec::default());
    let mut handles = vec![];
    for i in 0..n_requests {
        let addr = addr.clone();
        // mask one word of a real corpus sentence
        let text = corpus.paragraph(i as u64 + 50);
        let words: Vec<&str> = text.split_whitespace().take(12).collect();
        let mut masked = words.clone();
        let pos = 2 + i % 6;
        if pos < masked.len() {
            masked[pos] = "[MASK]";
        }
        let body = format!(r#"{{"text": "{}", "top_k": 3}}"#, masked.join(" "));
        handles.push(std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let resp = http_post(&addr, &body).unwrap_or_default();
            (body, resp, t0.elapsed().as_secs_f64() * 1e3)
        }));
    }
    for h in handles {
        let (body, resp, ms) = h.join().unwrap();
        let line = resp.lines().last().unwrap_or("");
        let preview: String = line.chars().take(120).collect();
        println!("{:6.1} ms  {}\n          -> {}\n", ms, &body[..body.len().min(90)], preview);
    }

    // batching stats
    let mut s = TcpStream::connect(&addr)?;
    write!(s, "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n")?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    println!("router stats: {}", resp.lines().last().unwrap_or(""));
    Ok(())
}

/// No artifacts / no PJRT: demonstrate the serving-side hot path that
/// *is* pure rust — the fused batched lattice lookup+gather engine.
fn offline_engine_demo() -> anyhow::Result<()> {
    use lram::lattice::{BatchLookupEngine, BatchOutput, TorusK};
    use lram::memstore::{AccessStats, ValueTable};
    use lram::util::rng::Rng;

    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8])?; // LRAM-small: 2^18 slots
    let mut table = ValueTable::zeros(torus.num_locations(), 64)?;
    table.randomize(0xD130, 0.02);
    let engine = BatchLookupEngine::auto(torus, 32);
    let mut rng = Rng::new(40);
    let batch = 256usize;
    let queries: Vec<f64> = (0..batch * 8).map(|_| rng.uniform(-8.0, 8.0)).collect();
    let mut lk = BatchOutput::default();
    let mut out = vec![0.0f32; batch * 64];

    let t0 = std::time::Instant::now();
    let reps = 200;
    for _ in 0..reps {
        engine.lookup_gather_into(&queries, &table, &mut lk, &mut out);
    }
    let secs = t0.elapsed().as_secs_f64();

    let mut stats = AccessStats::new(torus.num_locations());
    stats.record_batch_f32(&lk.indices, &lk.weights);
    println!(
        "fused lookup+gather: batch {batch} x {reps} reps on {} threads -> {:.2} Mq/s",
        engine.n_threads(),
        (batch * reps) as f64 / secs / 1e6
    );
    println!(
        "one batch touches {} of {} slots (utilisation {:.3}%), total weight per query in \
         [0.851, 1]: first = {:.4}",
        (stats.utilization() * torus.num_locations() as f64) as u64,
        torus.num_locations(),
        stats.utilization() * 100.0,
        lk.total_weight[0]
    );
    println!("\n(run `make artifacts` to enable the full HTTP serving demo)");
    Ok(())
}
