//! Serving demo: start the fill-mask router behind the event-driven
//! keep-alive front door, fire concurrent requests at it from
//! persistent client connections, print predictions + batching stats.
//! Demonstrates the vLLM-style dynamic batcher with python nowhere on
//! the request path.
//!
//! # Quickstart (no artifacts, no PJRT — works on any machine)
//!
//! ```text
//! cargo run --release --example serve_mlm -- --backend engine --random-init
//! ```
//!
//! The `engine` backend is pure rust: token/position embeddings and a
//! query projection (the split-mode prefix shape), the fused
//! `BatchLookupEngine` lattice lookup+gather over a lazily-mapped value
//! table, and a dense suffix with log-softmax.  It is the paper's O(1)
//! random-access lookup served end-to-end — `POST /predict` with
//! `{"text": "the [MASK] sat", "top_k": 3}` returns top-k candidates
//! per mask, `GET /stats` reports batching, latency percentiles, queue
//! depth and value-table utilisation, `GET /healthz` liveness.
//!
//! # Backends
//!
//! * `--backend engine`    pure rust, always available; serves a trained
//!   `--checkpoint DIR` (from `lram train --backend engine --save DIR`),
//!   or untrained deterministic seed weights behind an explicit
//!   `--random-init`
//! * `--backend artifact`  AOT PJRT artifact (`infer_logits_<variant>`,
//!   needs `make artifacts` and a real PJRT runtime)
//! * `--backend auto`      checkpoint > artifact > seed engine (default;
//!   the seed fallback warns loudly)
//!
//! Front-door flags (see docs/serving.md): `--http-workers N`,
//! `--max-pending N`, `--keep-alive-timeout SECS`.  Other flags:
//! `[--variant lram_small] [--checkpoint ckpt/ | runs/.../final.ckpt]
//! [--clients 4] [--requests-per-client 3] [--addr 127.0.0.1:8077]
//! [--threads N]`

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::server::{ArtifactInit, Batcher, BatcherConfig, EngineConfig, HttpConfig, Server};
use lram::util::cli::Args;

/// Minimal keep-alive HTTP client: send a request, read exactly one
/// response (status line, headers, `Content-Length` body), leave the
/// connection open for the next call.
fn http_roundtrip(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    request: &str,
) -> anyhow::Result<(u16, String)> {
    use anyhow::Context as _;
    stream.write_all(request.as_bytes())?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .context("bad status line")?
        .parse()
        .context("non-numeric status")?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

fn post_predict(request_body: &str) -> String {
    format!(
        "POST /predict HTTP/1.1\r\nHost: demo\r\nContent-Length: {}\r\n\r\n{request_body}",
        request_body.len()
    )
}

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let variant = args.str("variant", "lram_small");
    let addr = args.str("addr", "127.0.0.1:8077");
    let backend = args.str("backend", "auto");
    let n_clients = args.usize("clients", 4)?;
    let per_client = args.usize("requests-per-client", 3)?;

    // --checkpoint: engine checkpoint directory or legacy artifact blob
    let (engine_ckpt, artifact_ckpt) = match args.flags.get("checkpoint") {
        Some(p) => lram::server::resolve_checkpoint_flag(p, args.usize("threads", 1)?)?,
        None => (None, None),
    };
    let pipeline = DataPipeline::new(CorpusSpec::default(), 4096, 8, 1, 0.15)?;
    let bpe = Arc::new(pipeline.bpe);

    let batcher_cfg = BatcherConfig {
        max_pending: args.usize("max-pending", BatcherConfig::default().max_pending)?,
        ..BatcherConfig::default()
    };
    let batcher = Batcher::spawn_for_flag(
        &backend,
        ArtifactInit {
            artifact_dir: args.str("artifacts", "artifacts"),
            artifact_name: format!("infer_logits_{variant}"),
            checkpoint: artifact_ckpt,
        },
        EngineConfig { threads: args.usize("threads", 1)?, ..EngineConfig::default() },
        engine_ckpt,
        args.bool("random-init", false)?,
        bpe.clone(),
        batcher_cfg,
    )?;
    let http = HttpConfig::default();
    let http = HttpConfig {
        workers: args.usize("http-workers", http.workers)?,
        keep_alive_timeout: std::time::Duration::from_secs_f64(
            args.f64("keep-alive-timeout", http.keep_alive_timeout.as_secs_f64())?,
        ),
        ..http
    };
    let server = Server::bind(&addr, batcher, bpe, http)?;
    let addr = server.local_addr().to_string();
    println!(
        "server on http://{addr}; firing {n_clients} keep-alive clients x \
         {per_client} requests each\n"
    );

    let corpus = lram::data::synth::SynthCorpus::new(CorpusSpec::default());
    let mut handles = vec![];
    for c in 0..n_clients {
        let addr = addr.clone();
        // mask one word of a few real corpus sentences; all requests of
        // a client ride the same persistent connection
        let bodies: Vec<String> = (0..per_client)
            .map(|i| {
                let text = corpus.paragraph((c * per_client + i) as u64 + 50);
                let words: Vec<&str> = text.split_whitespace().take(12).collect();
                let mut masked = words.clone();
                let pos = 2 + (c + i) % 6;
                if pos < masked.len() {
                    masked[pos] = "[MASK]";
                }
                format!(r#"{{"text": "{}", "top_k": 3}}"#, masked.join(" "))
            })
            .collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<(String, String, f64)>> {
            let mut stream = TcpStream::connect(&addr)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut out = Vec::new();
            for body in bodies {
                let t0 = std::time::Instant::now();
                let (status, resp) =
                    http_roundtrip(&mut stream, &mut reader, &post_predict(&body))?;
                anyhow::ensure!(status == 200, "request failed with {status}: {resp}");
                out.push((body, resp, t0.elapsed().as_secs_f64() * 1e3));
            }
            Ok(out)
        }));
    }
    for h in handles {
        for (body, resp, ms) in h.join().expect("client thread panicked")? {
            let preview: String = resp.chars().take(120).collect();
            println!("{:6.1} ms  {}\n          -> {}\n", ms, &body[..body.len().min(90)], preview);
        }
    }

    // batching + latency + front-door stats over the same kind of
    // persistent connection
    let mut stream = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let (_, stats) = http_roundtrip(
        &mut stream,
        &mut reader,
        "GET /stats HTTP/1.1\r\nHost: demo\r\nConnection: close\r\n\r\n",
    )?;
    println!("router stats: {stats}");
    // demo over: drain gracefully so in-flight batches complete
    server.shutdown();
    Ok(())
}
