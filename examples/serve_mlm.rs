//! Serving demo: start the fill-mask router, fire a few concurrent
//! requests at it from client threads, print predictions + batching
//! stats.  Demonstrates the vLLM-style dynamic batcher with python
//! nowhere on the request path.
//!
//! # Quickstart (no artifacts, no PJRT — works on any machine)
//!
//! ```text
//! cargo run --release --example serve_mlm -- --backend engine
//! ```
//!
//! The `engine` backend is pure rust: token/position embeddings and a
//! query projection (the split-mode prefix shape), the fused
//! `BatchLookupEngine` lattice lookup+gather over a lazily-mapped value
//! table, and a dense suffix with log-softmax.  It is the paper's O(1)
//! random-access lookup served end-to-end — `POST /predict` with
//! `{"text": "the [MASK] sat", "top_k": 3}` returns top-k candidates
//! per mask, `GET /stats` reports batching, latency and value-table
//! utilisation, `GET /healthz` liveness.
//!
//! # Backends
//!
//! * `--backend engine`    pure rust, always available; serves a trained
//!   `--checkpoint DIR` (from `lram train --backend engine --save DIR`),
//!   or untrained deterministic seed weights behind an explicit
//!   `--random-init`
//! * `--backend artifact`  AOT PJRT artifact (`infer_logits_<variant>`,
//!   needs `make artifacts` and a real PJRT runtime)
//! * `--backend auto`      checkpoint > artifact > seed engine (default;
//!   the seed fallback warns loudly)
//!
//! Other flags: `[--variant lram_small] [--checkpoint ckpt/ | runs/.../final.ckpt]
//! [--requests 12] [--addr 127.0.0.1:8077] [--threads N]`

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::server::{serve, ArtifactInit, Batcher, BatcherConfig, EngineConfig};
use lram::util::cli::Args;

fn http_post(addr: &str, body: &str) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    Ok(resp)
}

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let variant = args.str("variant", "lram_small");
    let addr = args.str("addr", "127.0.0.1:8077");
    let backend = args.str("backend", "auto");
    let n_requests = args.usize("requests", 12)?;

    // --checkpoint: engine checkpoint directory or legacy artifact blob
    let (engine_ckpt, artifact_ckpt) = match args.flags.get("checkpoint") {
        Some(p) => lram::server::resolve_checkpoint_flag(p, args.usize("threads", 1)?)?,
        None => (None, None),
    };
    let pipeline = DataPipeline::new(CorpusSpec::default(), 4096, 8, 1, 0.15)?;
    let bpe = Arc::new(pipeline.bpe);

    let batcher = Batcher::spawn_for_flag(
        &backend,
        ArtifactInit {
            artifact_dir: args.str("artifacts", "artifacts"),
            artifact_name: format!("infer_logits_{variant}"),
            checkpoint: artifact_ckpt,
        },
        EngineConfig { threads: args.usize("threads", 1)?, ..EngineConfig::default() },
        engine_ckpt,
        args.bool("random-init", false)?,
        bpe.clone(),
        BatcherConfig::default(),
    )?;
    {
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        let addr = addr.clone();
        std::thread::spawn(move || serve(&addr, batcher, bpe));
    }
    std::thread::sleep(std::time::Duration::from_millis(300));
    println!("server on http://{addr}; firing {n_requests} concurrent requests\n");

    let corpus = lram::data::synth::SynthCorpus::new(CorpusSpec::default());
    let mut handles = vec![];
    for i in 0..n_requests {
        let addr = addr.clone();
        // mask one word of a real corpus sentence
        let text = corpus.paragraph(i as u64 + 50);
        let words: Vec<&str> = text.split_whitespace().take(12).collect();
        let mut masked = words.clone();
        let pos = 2 + i % 6;
        if pos < masked.len() {
            masked[pos] = "[MASK]";
        }
        let body = format!(r#"{{"text": "{}", "top_k": 3}}"#, masked.join(" "));
        handles.push(std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let resp = http_post(&addr, &body).unwrap_or_default();
            (body, resp, t0.elapsed().as_secs_f64() * 1e3)
        }));
    }
    for h in handles {
        let (body, resp, ms) = h.join().unwrap();
        let line = resp.lines().last().unwrap_or("");
        let preview: String = line.chars().take(120).collect();
        println!("{:6.1} ms  {}\n          -> {}\n", ms, &body[..body.len().min(90)], preview);
    }

    // batching + memory stats
    let mut s = TcpStream::connect(&addr)?;
    write!(s, "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n")?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    println!("router stats: {}", resp.lines().last().unwrap_or(""));
    Ok(())
}
