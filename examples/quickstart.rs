//! Quickstart: the LRAM public API in five minutes.
//!
//! 1. pure-rust lattice lookups (no artifacts needed);
//! 2. the O(1) memstore gather at billion-parameter scale;
//! 3. if `make artifacts` has run: execute the AOT'd LRAM layer end to
//!    end through the PJRT runtime (split mode).
//!
//! Run: `cargo run --release --example quickstart`

use lram::lattice::{LatticeLookup, TorusK};
use lram::memstore::ValueTable;
use lram::runtime::Runtime;
use lram::splitmode::SplitLramLayer;
use lram::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    lram::util::logger::init();

    // --- 1. lattice lookups -------------------------------------------
    // A torus with 2^18 memory locations (the paper's LRAM-small).
    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8])?;
    println!("torus has {} memory locations", torus.num_locations());

    let mut lookup = LatticeLookup::new(torus, 32);
    let q = [0.3, -1.2, 2.7, 0.0, 4.4, -0.8, 1.1, 3.9];
    let result = lookup.lookup(&q);
    println!(
        "query {:?}\n  -> {} nearby slots, total weight {:.4} (paper bound [0.851, 1])",
        q,
        result.hits.len(),
        result.total_weight
    );
    for h in result.hits.iter().take(4) {
        println!("  slot {:7}  weight {:.4}  d^2 {:.3}", h.index, h.weight, h.d2);
    }

    // --- 2. the memstore: a billion parameters, O(1) access ------------
    let mut table = ValueTable::zeros(1 << 24, 64)?; // 2^30 params, 4 GB virtual
    println!(
        "\nvalue table: {} params, resident after creation: {} KB",
        table.param_count(),
        table.resident_bytes()? / 1024
    );
    let mut rng = Rng::new(7);
    let mut out = vec![0.0f32; 64];
    let idx: Vec<u64> = result.hits.iter().map(|h| h.index).collect();
    let wts: Vec<f32> = result.hits.iter().map(|h| h.weight as f32).collect();
    table.row_mut(idx[0])[0] = rng.normal() as f32; // touch something
    table.gather_weighted(&idx, &wts, &mut out);
    println!("weighted gather of {} rows done; out[0] = {:.5}", idx.len(), out[0]);
    println!("resident now: {} KB (only touched pages)", table.resident_bytes()? / 1024);

    // --- 3. the compiled LRAM layer (needs `make artifacts`) -----------
    match Runtime::new("artifacts") {
        Ok(rt) => match SplitLramLayer::load(&rt, 256, 1 << 18, true) {
            Ok(mut layer) => {
                let x: Vec<f32> =
                    (0..layer.batch * 256).map(|_| rng.normal() as f32).collect();
                let y = layer.run(&x)?;
                let stats = layer.stats.as_ref().unwrap();
                println!(
                    "\nsplit-mode LRAM layer: {} -> {} activations, \
                     {} slots touched in one batch, y[0] = {:.5}",
                    x.len(),
                    y.len(),
                    (stats.utilization() * stats.locations() as f64) as u64,
                    y[0]
                );
            }
            Err(e) => println!("\n(split-mode demo skipped: {e})"),
        },
        Err(e) => println!("\n(PJRT demo skipped: {e})"),
    }
    Ok(())
}
