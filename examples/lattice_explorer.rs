//! Lattice explorer: interactive tour of the E8 machinery the paper is
//! built on — quantization, isometry reduction, the 232-point table,
//! kernel weights, torus indexing.  Pure rust, no artifacts needed.
//!
//! Run: cargo run --release --example lattice_explorer -- [--seed 1]

use lram::lattice::{
    e8, exotic, kernel, neighbors, support, LatticeLookup, TorusK, SQRT8,
};
use lram::util::cli::Args;
use lram::util::rng::Rng;
use lram::util::timing::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let mut rng = Rng::new(args.u64("seed", 1)?);

    println!("== Lambda = 2*E8 = {{ x in (2Z)^8 u (2Z+1)^8 : sum(x) = 0 mod 4 }} ==\n");
    println!("packing radius  sqrt(2) = {:.4}", lram::lattice::PACKING_RADIUS);
    println!("covering radius       2");
    println!("minimal vector   sqrt(8) = {SQRT8:.4}");

    // a random query, step by step
    let q: [f64; 8] = std::array::from_fn(|_| rng.uniform(-6.0, 6.0));
    println!("\n-- query {q:?}");
    let x0 = e8::quantize(&q);
    println!("nearest lattice point: {x0:?}");
    let red = e8::reduce(&q);
    println!("reduced into F:        {:?}", red.z.map(|v| (v * 1e3).round() / 1e3));
    println!("permutation:           {:?}", red.perm);
    println!("signs (even # of -1):  {:?}", red.eps);

    // the 232-point table
    let nbr = neighbors::neighbor_table();
    println!("\n-- candidate table: {} lattice points within sqrt(8) of F", nbr.len());
    let mut by_norm: std::collections::BTreeMap<i64, usize> = Default::default();
    for p in nbr.iter() {
        *by_norm.entry(p.iter().map(|v| v * v).sum()).or_default() += 1;
    }
    for (n2, count) in &by_norm {
        println!("   |p|^2 = {n2:2}: {count:3} points");
    }

    // kernel weights along a path between two lattice points
    println!("\n-- kernel f(r) = max(0, 1 - r^2/8)^4 along an edge of the lattice");
    for i in 0..=8 {
        let t = i as f64 / 8.0;
        let d2 = (t * SQRT8).powi(2);
        let bar = "#".repeat((kernel::kernel_f(d2) * 40.0) as usize);
        println!("   r = {:4.2}  f = {:.4} {bar}", t * SQRT8, kernel::kernel_f(d2));
    }

    // torus memory + a lookup
    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8])?;
    let mut lk = LatticeLookup::new(torus, 32);
    let r = lk.lookup(&q);
    println!(
        "\n-- lookup on the 2^18-slot torus: {} hits, total weight {:.4}, top-32 keeps {:.2}%",
        r.hits.len(),
        r.total_weight,
        100.0 * r.hits.iter().map(|h| h.weight).sum::<f64>() / r.total_weight
    );

    // Table-1 style summary at small sample counts
    println!("\n-- kernel support statistics (quick MC; see bench table1_lattices)");
    let e8s = support::e8_support_stats(20_000, 5);
    let mut t = Table::new(&["lattice", "min", "avg", "max"]);
    t.row(&[
        "E8 (measured)".into(),
        e8s.min.to_string(),
        format!("{:.2}", e8s.mean),
        e8s.max.to_string(),
    ]);
    t.row(&[
        "E8 (paper)".into(),
        "45".into(),
        "64.94".into(),
        "121".into(),
    ]);
    t.row(&[
        "Z8 (analytic avg)".into(),
        "-".into(),
        format!("{:.0}", exotic::Z8.avg_kernel_support()),
        "-".into(),
    ]);
    t.print();
    Ok(())
}
