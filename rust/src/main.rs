//! `lram` — the LRAM coordinator CLI.
//!
//! Subcommands map onto the paper's experiments (see DESIGN.md):
//!
//! ```text
//! lram train   --variant lram_small --steps 300      # Table 2 / Figure 2
//! lram table1  [--samples 1000000]                   # lattice comparison
//! lram table2  --steps 300                           # all five variants
//! lram table3  [--width 512]                         # scaling formulas
//! lram table5  --variant lram_small                  # memory utilisation
//! lram serve   --variant lram_small --addr 0.0.0.0:8077
//! lram artifacts                                     # list compiled units
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use lram::config::TrainConfig;
use lram::coordinator::Trainer;
use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::lattice::{exotic, support};
use lram::pkm::cost;
use lram::runtime::Runtime;
use lram::server::{serve, ArtifactInit, Batcher, BatcherConfig, EngineConfig};
use lram::util::cli::Args;
use lram::util::timing::Table;

fn main() -> Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "table1" => cmd_table1(&args),
        "table2" => cmd_table2(&args),
        "table3" => cmd_table3(&args),
        "table5" => cmd_table5(&args),
        "serve" => cmd_serve(&args),
        "artifacts" => cmd_artifacts(&args),
        "corpus" => cmd_corpus(&args),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "lram — lattice-based differentiable RAM (Goucher & Troll 2021)

USAGE: lram <command> [--flags]

COMMANDS:
  train      train one variant (Table 2 / Figure 2 data point)
  table1     lattice comparison: packing/covering radii + kernel support
  table2     train all five variants and print the perplexity table
  table3     asymptotic parameter/op counts for dense / PKM / LRAM
  table5     memory utilisation + KL divergence over the validation set
  serve      MLM fill-mask server with dynamic batching
             (--backend artifact | engine | auto; engine is pure rust,
              needs no compiled artifacts)
  artifacts  list compiled AOT artifacts
  corpus     print sample paragraphs of the synthetic corpus

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: artifacts)
  --variant NAME    baseline | lram_small | lram_medium | lram_large | pkm
  --steps N         training steps (default 300)
  --config FILE     JSON config (CLI flags override)
";

fn load_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    cfg.apply_args(args)?;
    if !args.has("run-dir") && !args.has("config") {
        cfg.run_dir = format!("runs/{}", cfg.variant);
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Arc::new(Runtime::new(&cfg.artifact_dir)?);
    let mut trainer = Trainer::new(rt, cfg)?;
    let out = trainer.run()?;
    println!(
        "{}: steps={} train_loss={:.4} best_val_ppl={:.3} final_val_ppl={:.3} wall={:.1}s",
        out.variant, out.steps, out.final_train_loss, out.best_val_ppl,
        out.final_val.perplexity, out.wall_secs
    );
    let test = trainer.evaluate_test()?;
    println!("test_ppl={:.3}", test.perplexity);
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let variants = ["baseline", "pkm", "lram_small", "lram_medium", "lram_large"];
    let rt = Arc::new(Runtime::new(&args.str("artifacts", "artifacts"))?);
    let mut table = Table::new(&[
        "Model", "Total parameters (M)", "Validation perplexity", "Test perplexity",
    ]);
    for v in variants {
        let mut cfg = load_config(args)?;
        cfg.variant = v.to_string();
        cfg.run_dir = format!("runs/table2_{v}");
        let mut trainer = match Trainer::new(rt.clone(), cfg) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("skipping {v}: {e:#} (artifact not exported?)");
                continue;
            }
        };
        let out = trainer.run()?;
        let test = trainer.evaluate_test()?;
        let params = rt
            .load(&format!("train_step_{v}"))?
            .manifest
            .n_params
            .unwrap_or(0);
        table.row(&[
            v.to_string(),
            format!("{:.1}", params as f64 / 1e6),
            format!("{:.2}", out.final_val.perplexity),
            format!("{:.2}", test.perplexity),
        ]);
    }
    println!("\nTable 2 (reproduction; see EXPERIMENTS.md for scale notes)");
    table.print();
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let samples = args.u64("samples", 200_000)?;
    println!("Table 1: lattice comparison (MC samples = {samples}; paper used 1e7+)\n");
    let e8 = support::e8_support_stats(samples, 1);
    let z8 = support::z8_support_stats((samples / 20).max(1000), 2);
    let infos = [exotic::Z8, exotic::E8, exotic::K12, exotic::BW16, exotic::LEECH];
    let mut t = Table::new(&["Lattice", "Dim", "Det", "Packing", "Covering", "Min", "Avg", "Max"]);
    for info in infos {
        let (min, max) = match info.name {
            "Z8" => (format!("{} (m.c.)", z8.min), format!("{} (m.c.)", z8.max)),
            "E8" => (format!("{} (m.c.)", e8.min), format!("{} (m.c.)", e8.max)),
            _ => ("-".into(), "-".into()),
        };
        t.row(&[
            info.name.to_string(),
            info.dim.to_string(),
            "1".to_string(),
            format!("{:.3}", info.packing_radius),
            format!("{:.3}", info.covering_radius),
            min,
            format!("{:.2}", info.avg_kernel_support()),
            max,
        ]);
    }
    t.print();
    let (avg_frac, min_frac) = support::topk_weight_fraction(samples.min(100_000), 32, 3);
    println!(
        "\ntop-32 weight capture: avg {:.2}% min {:.2}%  (paper: 99.5% / 90%)",
        avg_frac * 100.0,
        min_frac * 100.0
    );
    println!(
        "measured E8 MC mean {:.2} vs analytic {:.2}",
        e8.mean,
        exotic::E8.avg_kernel_support()
    );
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let w = args.u64("width", 512)?;
    let r = 4u64;
    let m = 64u64;
    println!("Table 3: asymptotic scaling at w = {w}, r = {r}\n");
    let mut t = Table::new(&["Method", "Parameters", "Approx op count"]);
    for n_exp in [16u32, 20, 24] {
        let n = 1u64 << n_exp;
        t.row(&[
            format!("PKM (N=2^{n_exp})"),
            cost::pkm_params(w, n, 512).to_string(),
            cost::pkm_ops(w, n).to_string(),
        ]);
        t.row(&[
            format!("LRAM (N=2^{n_exp})"),
            cost::lram_params(w, r, n, m).to_string(),
            cost::lram_ops(w, r).to_string(),
        ]);
    }
    t.row(&[
        "Dense 2-layer".into(),
        cost::dense_params(w, r).to_string(),
        cost::dense_ops(w, r).to_string(),
    ]);
    t.print();
    println!("\nLRAM op count is independent of N (O(1) lookup); PKM grows as sqrt(N).");
    Ok(())
}

fn cmd_table5(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Arc::new(Runtime::new(&cfg.artifact_dir)?);
    let mut trainer = Trainer::new(rt, cfg)?;
    if let Some(ckpt) = args.flags.get("checkpoint") {
        trainer.load_checkpoint(std::path::Path::new(ckpt))?;
        log::info!("loaded checkpoint {ckpt}");
    }
    // warm the model so accesses reflect trained queries
    let warm = args.u64("warm-steps", 50)?;
    for _ in 0..warm {
        trainer.train_step()?;
    }
    let report = trainer.evaluate_val()?;
    println!("Table 5 row for variant ({} eval batches):", report.batches);
    println!("  val_ppl        = {:.3}", report.perplexity);
    match (report.utilization, report.kl_divergence) {
        (Some(u), Some(kl)) => {
            println!("  memory usage % = {:.2}", u * 100.0);
            println!("  KL divergence  = {:.3}", kl);
        }
        _ => println!("  (variant has no memory layer: baseline)"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let addr = args.str("addr", "127.0.0.1:8077");
    let backend = args.str("backend", "auto");
    let checkpoint = match args.flags.get("checkpoint") {
        Some(ckpt) => {
            log::info!("restoring checkpoint {ckpt}");
            Some(std::fs::read(ckpt)?)
        }
        None => None,
    };
    // the tokenizer must match the training pipeline: rebuild it from the
    // same corpus spec
    let spec = CorpusSpec { seed: cfg.corpus_seed, ..CorpusSpec::default() };
    let pipeline = DataPipeline::new(spec, cfg.vocab_size, 8, 1, 0.15)?;
    let bpe = Arc::new(pipeline.bpe);
    let batcher = Batcher::spawn_for_flag(
        &backend,
        ArtifactInit {
            artifact_dir: cfg.artifact_dir.clone(),
            artifact_name: format!("infer_logits_{}", cfg.variant),
            checkpoint,
        },
        EngineConfig::default(),
        bpe.clone(),
        BatcherConfig::default(),
    )?;
    serve(&addr, batcher, bpe)
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "artifacts");
    let rt = Runtime::new(&dir)?;
    let names = rt.available()?;
    if names.is_empty() {
        bail!("no artifacts in {dir}; run `make artifacts` first");
    }
    let mut t = Table::new(&["artifact", "kind", "state", "inputs", "outputs"]);
    for n in names {
        let m = lram::runtime::Manifest::load(std::path::Path::new(&dir), &n)?;
        t.row(&[
            n,
            m.kind.clone(),
            m.state.len().to_string(),
            m.inputs.len().to_string(),
            m.outputs.len().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_corpus(args: &Args) -> Result<()> {
    let seed = args.u64("seed", 1234)?;
    let n = args.u64("n", 3)?;
    let corpus = lram::data::synth::SynthCorpus::new(CorpusSpec { seed, ..Default::default() });
    for i in 0..n {
        println!("--- paragraph {i} ---\n{}\n", corpus.paragraph(i));
    }
    Ok(())
}
