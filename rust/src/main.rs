//! `lram` — the LRAM coordinator CLI.
//!
//! Subcommands map onto the paper's experiments (see DESIGN.md):
//!
//! ```text
//! lram train   --variant lram_small --steps 300      # Table 2 / Figure 2
//! lram table1  [--samples 1000000]                   # lattice comparison
//! lram table2  --steps 300                           # all five variants
//! lram table3  [--width 512]                         # scaling formulas
//! lram table5  --variant lram_small                  # memory utilisation
//! lram serve   --variant lram_small --addr 0.0.0.0:8077
//! lram artifacts                                     # list compiled units
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use lram::checkpoint::Checkpoint;
use lram::config::TrainConfig;
use lram::coordinator::{EngineTrainConfig, EngineTrainer, Trainer};
use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::lattice::{exotic, support};
use lram::pkm::cost;
use lram::runtime::Runtime;
use lram::server::{
    serve_until_signaled, ArtifactInit, Batcher, BatcherConfig, EngineConfig, HttpConfig,
    NumericPath,
};
use lram::util::cli::Args;
use lram::util::timing::Table;

fn main() -> Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "table1" => cmd_table1(&args),
        "table2" => cmd_table2(&args),
        "table3" => cmd_table3(&args),
        "table5" => cmd_table5(&args),
        "serve" => cmd_serve(&args),
        "checkpoint" => cmd_checkpoint(&args),
        "artifacts" => cmd_artifacts(&args),
        "corpus" => cmd_corpus(&args),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "lram — lattice-based differentiable RAM (Goucher & Troll 2021)

USAGE: lram <command> [--flags]

COMMANDS:
  train      train one variant (Table 2 / Figure 2 data point)
             --backend artifact | engine | auto (engine is pure rust;
             --save DIR writes a servable checkpoint, --save-every N
             checkpoints periodically, --resume DIR continues a run;
             routing is trained through the lattice kernel by default —
             --freeze-routing keeps wq fixed, --routing-lr X tunes its
             dense-Adam rate (default 1e-3); --fsync makes checkpoint
             commits power-loss durable; --keep-checkpoints N retains
             N-1 predecessor checkpoints next to the live one so serving
             can fall back when the newest is corrupt)
  table1     lattice comparison: packing/covering radii + kernel support
  table2     train all five variants and print the perplexity table
  table3     asymptotic parameter/op counts for dense / PKM / LRAM
  table5     memory utilisation + KL divergence over the validation set
  serve      MLM fill-mask server with dynamic batching
             (--backend artifact | engine | auto; --checkpoint DIR serves
              trained engine weights; --random-init opts into untrained
              seed weights; --numeric-path f64|f32|f32-q8 picks the
              memory-stage implementation — default f32, the SIMD fast
              path; f64 is the bit-exact training-identical reference,
              f32-q8 gathers from int8-quantized value rows (see
              docs/performance.md; LRAM_SIMD=off forces scalar f32);
              --shards N partitions the value table row-wise across N
              in-process shard workers (one thread per shard; f64 output
              stays bit-identical to --shards 1; a checkpoint saved with
              N shards must be served with --shards N or reassembled
              with --shards 1 — see docs/serving.md);
              --http-workers N (event loops), --max-connections N,
              --max-pending N and --keep-alive-timeout SECS tune the
              event-driven keep-alive front door (each loop multiplexes
              its connections with poll(2) — see docs/serving.md);
              --request-timeout-ms N expires queued requests
              with 504 before they reach the backend; SIGTERM/SIGINT
              drain gracefully; a corrupt checkpoint falls back to its
              newest verifying .prev-<step> sibling — see
              docs/serving.md and docs/robustness.md)
  checkpoint inspect a checkpoint directory:
             lram checkpoint inspect DIR [--verify]
  artifacts  list compiled AOT artifacts
  corpus     print sample paragraphs of the synthetic corpus

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: artifacts)
  --variant NAME    baseline | lram_small | lram_medium | lram_large | pkm
  --steps N         training steps (default 300)
  --config FILE     JSON config (CLI flags override)

TRAIN-THEN-SERVE QUICKSTART (no artifacts, no PJRT):
  lram train --backend engine --steps 200 --save ckpt/
  lram serve --checkpoint ckpt/
";

fn load_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    cfg.apply_args(args)?;
    if !args.has("run-dir") && !args.has("config") {
        cfg.run_dir = format!("runs/{}", cfg.variant);
    }
    Ok(cfg)
}

/// Engine model geometry from CLI flags (defaults = `EngineConfig`).
fn engine_model_from_args(args: &Args) -> Result<EngineConfig> {
    let d = EngineConfig::default();
    let tk = args.u64_list("torus", &d.torus_k.map(|k| k as u64))?;
    anyhow::ensure!(tk.len() == 8, "--torus needs 8 comma-separated side lengths");
    let mut torus_k = [0i64; 8];
    for (o, &v) in torus_k.iter_mut().zip(&tk) {
        *o = v as i64;
    }
    Ok(EngineConfig {
        max_batch: args.usize("max-batch", d.max_batch)?,
        seq_len: args.usize("seq-len", d.seq_len)?,
        width: args.usize("width", d.width)?,
        heads: args.usize("heads", d.heads)?,
        m: args.usize("m", d.m)?,
        k_top: args.usize("k-top", d.k_top)?,
        torus_k,
        threads: args.usize("threads", d.threads)?,
        query_scale: args.f64("query-scale", d.query_scale)?,
        shards: args.usize("shards", d.shards)?,
        ..d
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    match args.str("backend", "auto").as_str() {
        "artifact" => cmd_train_artifact(args),
        "engine" => cmd_train_engine(args),
        "auto" => {
            let cfg = load_config(args)?;
            match Runtime::new(&cfg.artifact_dir)
                .and_then(|rt| Trainer::new(Arc::new(rt), cfg.clone()))
            {
                Ok(trainer) => run_artifact_train(trainer),
                Err(e) => {
                    log::warn!(
                        "artifact trainer unavailable ({e:#}); training the pure-rust \
                         engine model instead"
                    );
                    cmd_train_engine(args)
                }
            }
        }
        other => bail!("unknown backend '{other}' (use artifact | engine | auto)"),
    }
}

fn cmd_train_artifact(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Arc::new(Runtime::new(&cfg.artifact_dir)?);
    run_artifact_train(Trainer::new(rt, cfg)?)
}

fn run_artifact_train(mut trainer: Trainer) -> Result<()> {
    let out = trainer.run()?;
    println!(
        "{}: steps={} train_loss={:.4} best_val_ppl={:.3} final_val_ppl={:.3} wall={:.1}s",
        out.variant, out.steps, out.final_train_loss, out.best_val_ppl,
        out.final_val.perplexity, out.wall_secs
    );
    let test = trainer.evaluate_test()?;
    println!("test_ppl={:.3}", test.perplexity);
    Ok(())
}

/// Train the pure-rust engine model; `--save DIR` writes a checkpoint
/// that `lram serve --checkpoint DIR` then serves bit-identically.
fn cmd_train_engine(args: &Args) -> Result<()> {
    // config file + CLI overrides, same precedence as the artifact path
    // (base.steps already folds in --config and --steps)
    let base = load_config(args)?;
    // routing is trained by default (the paper's differentiable-memory
    // premise); --freeze-routing wins over an explicit --train-routing
    let train_routing = if args.bool("freeze-routing", false)? {
        false
    } else {
        args.bool("train-routing", true)?
    };
    let cfg = EngineTrainConfig {
        model: engine_model_from_args(args)?,
        steps: base.steps,
        batch: args.usize("batch", 8)?,
        lr_dense: args.f64("lr", 0.05)? as f32,
        lr_values: args.f64("value-lr", 1e-3)? as f32,
        train_routing,
        lr_routing: args.f64("routing-lr", 1e-3)? as f32,
        corpus_seed: base.corpus_seed,
        vocab_size: base.vocab_size,
        mask_prob: base.mask_prob,
        eval_batches: base.eval_batches,
        save_every: args.u64("save-every", 0)?,
        save_dir: args.flags.get("save").map(std::path::PathBuf::from),
        fsync: args.bool("fsync", false)?,
        keep_checkpoints: args.usize("keep-checkpoints", 1)?.max(1),
    };
    let mut trainer = match args.flags.get("resume") {
        Some(dir) => EngineTrainer::from_checkpoint(cfg, std::path::Path::new(dir))?,
        None => EngineTrainer::new(cfg)?,
    };
    let out = trainer.run()?;
    println!(
        "engine: steps={} first_loss={:.4} final_loss={:.4} val_ppl={:.3}",
        out.steps, out.first_loss, out.final_loss, out.val_ppl
    );
    match out.manifest {
        Some(m) => println!(
            "saved checkpoint {} at step {} ({} tensors)",
            m.checkpoint_id,
            m.step,
            m.tensors.len()
        ),
        None => println!("(no --save DIR given: weights were discarded)"),
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let variants = ["baseline", "pkm", "lram_small", "lram_medium", "lram_large"];
    let rt = Arc::new(Runtime::new(&args.str("artifacts", "artifacts"))?);
    let mut table = Table::new(&[
        "Model", "Total parameters (M)", "Validation perplexity", "Test perplexity",
    ]);
    for v in variants {
        let mut cfg = load_config(args)?;
        cfg.variant = v.to_string();
        cfg.run_dir = format!("runs/table2_{v}");
        let mut trainer = match Trainer::new(rt.clone(), cfg) {
            Ok(t) => t,
            Err(e) => {
                log::warn!("skipping {v}: {e:#} (artifact not exported?)");
                continue;
            }
        };
        let out = trainer.run()?;
        let test = trainer.evaluate_test()?;
        let params = rt
            .load(&format!("train_step_{v}"))?
            .manifest
            .n_params
            .unwrap_or(0);
        table.row(&[
            v.to_string(),
            format!("{:.1}", params as f64 / 1e6),
            format!("{:.2}", out.final_val.perplexity),
            format!("{:.2}", test.perplexity),
        ]);
    }
    println!("\nTable 2 (reproduction; see EXPERIMENTS.md for scale notes)");
    table.print();
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let samples = args.u64("samples", 200_000)?;
    println!("Table 1: lattice comparison (MC samples = {samples}; paper used 1e7+)\n");
    let e8 = support::e8_support_stats(samples, 1);
    let z8 = support::z8_support_stats((samples / 20).max(1000), 2);
    let infos = [exotic::Z8, exotic::E8, exotic::K12, exotic::BW16, exotic::LEECH];
    let mut t = Table::new(&["Lattice", "Dim", "Det", "Packing", "Covering", "Min", "Avg", "Max"]);
    for info in infos {
        let (min, max) = match info.name {
            "Z8" => (format!("{} (m.c.)", z8.min), format!("{} (m.c.)", z8.max)),
            "E8" => (format!("{} (m.c.)", e8.min), format!("{} (m.c.)", e8.max)),
            _ => ("-".into(), "-".into()),
        };
        t.row(&[
            info.name.to_string(),
            info.dim.to_string(),
            "1".to_string(),
            format!("{:.3}", info.packing_radius),
            format!("{:.3}", info.covering_radius),
            min,
            format!("{:.2}", info.avg_kernel_support()),
            max,
        ]);
    }
    t.print();
    let (avg_frac, min_frac) = support::topk_weight_fraction(samples.min(100_000), 32, 3);
    println!(
        "\ntop-32 weight capture: avg {:.2}% min {:.2}%  (paper: 99.5% / 90%)",
        avg_frac * 100.0,
        min_frac * 100.0
    );
    println!(
        "measured E8 MC mean {:.2} vs analytic {:.2}",
        e8.mean,
        exotic::E8.avg_kernel_support()
    );
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let w = args.u64("width", 512)?;
    let r = 4u64;
    let m = 64u64;
    println!("Table 3: asymptotic scaling at w = {w}, r = {r}\n");
    let mut t = Table::new(&["Method", "Parameters", "Approx op count"]);
    for n_exp in [16u32, 20, 24] {
        let n = 1u64 << n_exp;
        t.row(&[
            format!("PKM (N=2^{n_exp})"),
            cost::pkm_params(w, n, 512).to_string(),
            cost::pkm_ops(w, n).to_string(),
        ]);
        t.row(&[
            format!("LRAM (N=2^{n_exp})"),
            cost::lram_params(w, r, n, m).to_string(),
            cost::lram_ops(w, r).to_string(),
        ]);
    }
    t.row(&[
        "Dense 2-layer".into(),
        cost::dense_params(w, r).to_string(),
        cost::dense_ops(w, r).to_string(),
    ]);
    t.print();
    println!("\nLRAM op count is independent of N (O(1) lookup); PKM grows as sqrt(N).");
    Ok(())
}

fn cmd_table5(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let rt = Arc::new(Runtime::new(&cfg.artifact_dir)?);
    let mut trainer = Trainer::new(rt, cfg)?;
    if let Some(ckpt) = args.flags.get("checkpoint") {
        trainer.load_checkpoint(std::path::Path::new(ckpt))?;
        log::info!("loaded checkpoint {ckpt}");
    }
    // warm the model so accesses reflect trained queries
    let warm = args.u64("warm-steps", 50)?;
    for _ in 0..warm {
        trainer.train_step()?;
    }
    let report = trainer.evaluate_val()?;
    println!("Table 5 row for variant ({} eval batches):", report.batches);
    println!("  val_ppl        = {:.3}", report.perplexity);
    match (report.utilization, report.kl_divergence) {
        (Some(u), Some(kl)) => {
            println!("  memory usage % = {:.2}", u * 100.0);
            println!("  KL divergence  = {:.3}", kl);
        }
        _ => println!("  (variant has no memory layer: baseline)"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let addr = args.str("addr", "127.0.0.1:8077");
    let backend = args.str("backend", "auto");
    let random_init = args.bool("random-init", false)?;
    // serving numeric path: f32 SIMD by default; f64 stays available as the
    // bit-exact training-identical reference (see docs/performance.md)
    let numeric_path = NumericPath::parse(&args.str("numeric-path", "f32"))?;
    // value-table sharding: N > 1 partitions the table row-wise across N
    // in-process shard workers (see docs/serving.md)
    let shards = args.usize("shards", 1)?;
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    let (mut engine_ckpt, artifact_ckpt) = match args.flags.get("checkpoint") {
        Some(ckpt) => lram::server::resolve_checkpoint_flag(ckpt, args.usize("threads", 1)?)?,
        None => (None, None),
    };
    if let Some(ck) = engine_ckpt.as_mut() {
        ck.numeric_path = numeric_path;
        ck.shards = shards;
    }
    // the tokenizer must match the training pipeline: rebuild it from the
    // same corpus spec (a checkpoint's recorded fingerprint is validated
    // against this at backend construction)
    let spec = CorpusSpec { seed: cfg.corpus_seed, ..CorpusSpec::default() };
    let pipeline = DataPipeline::new(spec, cfg.vocab_size, 8, 1, 0.15)?;
    let bpe = Arc::new(pipeline.bpe);
    // front-door tunables: event-loop count, the connection ceiling, and
    // the keep-alive idle timeout (see docs/serving.md)
    let http = HttpConfig::default();
    let http = HttpConfig {
        workers: args.usize("http-workers", http.workers)?,
        keep_alive_timeout: std::time::Duration::from_secs_f64(
            args.f64("keep-alive-timeout", http.keep_alive_timeout.as_secs_f64())?,
        ),
        max_connections: args.usize("max-connections", http.max_connections)?,
        ..http
    };
    // per-request deadline: expired requests get 504 without ever
    // touching the backend (0 = no deadline)
    let timeout_ms = args.u64("request-timeout-ms", 0)?;
    let batcher_cfg = BatcherConfig {
        max_pending: args.usize("max-pending", BatcherConfig::default().max_pending)?,
        request_timeout: (timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(timeout_ms)),
        ..BatcherConfig::default()
    };
    let batcher = Batcher::spawn_for_flag(
        &backend,
        ArtifactInit {
            artifact_dir: cfg.artifact_dir.clone(),
            artifact_name: format!("infer_logits_{}", cfg.variant),
            checkpoint: artifact_ckpt,
        },
        EngineConfig {
            threads: args.usize("threads", 1)?,
            numeric_path,
            shards,
            ..EngineConfig::default()
        },
        engine_ckpt,
        random_init,
        bpe.clone(),
        batcher_cfg,
    )?;
    // daemon loop: SIGTERM/SIGINT trigger a graceful drain (in-flight
    // requests complete) instead of killing mid-response
    serve_until_signaled(&addr, batcher, bpe, http)
}

/// `lram checkpoint inspect DIR [--verify]` — print the manifest
/// (id, step, tokenizer hash, geometry, tensor index); `--verify`
/// re-hashes every blob, including ones too large for the eager
/// verification at open.
fn cmd_checkpoint(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    if sub != "inspect" {
        bail!("usage: lram checkpoint inspect DIR [--verify]");
    }
    let dir = args
        .positional
        .get(2)
        .ok_or_else(|| anyhow::anyhow!("usage: lram checkpoint inspect DIR [--verify]"))?;
    let ck = Checkpoint::open(std::path::Path::new(dir))?;
    let m = &ck.manifest;
    println!("checkpoint   {}", m.checkpoint_id);
    println!("format       {} v{}", lram::checkpoint::FORMAT_TAG, m.version);
    println!("step         {}", m.step);
    println!("tokenizer    {}", m.tokenizer_hash);
    let d = &m.model;
    println!(
        "model        vocab={} width={} heads={} m={} k_top={} seq_len={} max_batch={}",
        d.vocab, d.width, d.heads, d.m, d.k_top, d.seq_len, d.max_batch
    );
    // the same validation + formula the loader uses, never a reimplementation
    let locations = match lram::lattice::TorusK::new(d.torus_k) {
        Ok(t) => t.num_locations().to_string(),
        Err(e) => format!("INVALID: {e}"),
    };
    println!("torus        {:?} ({locations} locations)", d.torus_k);
    let mut t = Table::new(&["tensor", "dtype", "shape", "MiB", "checksum"]);
    let mut total_bytes = 0u64;
    for spec in &m.tensors {
        let bytes = spec.byte_len()?;
        total_bytes += bytes;
        t.row(&[
            spec.name.clone(),
            format!("{:?}", spec.dtype).to_lowercase(),
            format!("{:?}", spec.shape),
            format!("{:.2}", bytes as f64 / (1 << 20) as f64),
            spec.checksum.clone(),
        ]);
    }
    t.print();
    println!("total        {:.2} MiB across {} tensors", total_bytes as f64 / (1 << 20) as f64, m.tensors.len());
    if args.bool("verify", false)? {
        ck.verify()?;
        println!("verify       all tensor checksums OK");
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "artifacts");
    let rt = Runtime::new(&dir)?;
    let names = rt.available()?;
    if names.is_empty() {
        bail!("no artifacts in {dir}; run `make artifacts` first");
    }
    let mut t = Table::new(&["artifact", "kind", "state", "inputs", "outputs"]);
    for n in names {
        let m = lram::runtime::Manifest::load(std::path::Path::new(&dir), &n)?;
        t.row(&[
            n,
            m.kind.clone(),
            m.state.len().to_string(),
            m.inputs.len().to_string(),
            m.outputs.len().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_corpus(args: &Args) -> Result<()> {
    let seed = args.u64("seed", 1234)?;
    let n = args.u64("n", 3)?;
    let corpus = lram::data::synth::SynthCorpus::new(CorpusSpec { seed, ..Default::default() });
    for i in 0..n {
        println!("--- paragraph {i} ---\n{}\n", corpus.paragraph(i));
    }
    Ok(())
}
