//! Split-mode layer execution — the paper's *system* contribution made
//! concrete: the dense compute runs as AOT'd HLO while the value-table
//! gather runs against the rust [`crate::memstore`], whose O(1) row
//! access is what lets a single layer scale to billions of parameters
//! with constant compute (Figure 3 / Table 4).
//!
//! ```text
//! x ──HLO prefix──► (idx, w, scale) ──rust gather──► rows ──HLO suffix──► y
//! ```
//!
//! The same structure serves PKM, whose prefix (codebook scoring) is
//! O(sqrt N) — timing both under identical marshalling is what makes the
//! Figure-3 comparison fair.

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::checkpoint::{CheckpointWriter, Manifest as CkptManifest};
use crate::memstore::{AccessStats, ValueTable};
use crate::runtime::{Artifact, ArtifactState, HostTensor, Runtime};

/// An LRAM layer in split mode: HLO prefix/suffix + rust value table.
pub struct SplitLramLayer {
    prefix: Arc<Artifact>,
    suffix: Arc<Artifact>,
    prefix_state: ArtifactState,
    suffix_state: ArtifactState,
    pub table: ValueTable,
    pub width: usize,
    pub heads: usize,
    pub k_top: usize,
    pub m: usize,
    pub batch: usize,
    /// optional access accounting (Table 5 in serving)
    pub stats: Option<AccessStats>,
    gathered: Vec<f32>,
    row_idx: Vec<u64>,
}

impl SplitLramLayer {
    /// Load `micro_lram_prefix_w{w}_n{N}` + `micro_lram_suffix_w{w}` and
    /// build an `N x m` value table.
    pub fn load(rt: &Runtime, width: usize, locations: u64, track_stats: bool) -> Result<Self> {
        let prefix = rt
            .load(&format!("micro_lram_prefix_w{width}_n{locations}"))
            .context("loading prefix artifact")?;
        let suffix = rt.load(&format!("micro_lram_suffix_w{width}"))?;
        let heads = prefix.manifest.heads.ok_or_else(|| anyhow!("prefix manifest: heads"))?;
        let k_top = prefix.manifest.k_top.ok_or_else(|| anyhow!("prefix manifest: k_top"))?;
        let m = prefix.manifest.m.ok_or_else(|| anyhow!("prefix manifest: m"))?;
        let batch = prefix.manifest.batch.b;
        let mut table = ValueTable::zeros(locations, m)?;
        // deterministic non-zero rows for numerically meaningful outputs;
        // capped so billion-parameter tables stay lazily mapped
        table.randomize_rows(0xE8, 0.02, locations.min(1 << 18));
        // non-degenerate query/output projections so lookups spread over
        // the torus (zero weights would collapse every query to one slot)
        let mut prefix_state = prefix.zero_state()?;
        randomize_state(&mut prefix_state, &prefix.manifest)?;
        let mut suffix_state = suffix.zero_state()?;
        randomize_state(&mut suffix_state, &suffix.manifest)?;
        Ok(SplitLramLayer {
            prefix,
            suffix,
            prefix_state,
            suffix_state,
            table,
            width,
            heads,
            k_top,
            m,
            batch,
            stats: track_stats.then(|| AccessStats::new(locations)),
            gathered: vec![0.0; batch * heads * k_top * m],
            row_idx: vec![0; batch * heads * k_top],
        })
    }

    /// Total parameters reachable by this layer (the Figure-3 x-axis).
    pub fn param_count(&self) -> u64 {
        self.table.param_count()
    }

    /// Export the layer's weights — every f32 prefix/suffix state tensor
    /// plus the value table — as a checkpoint directory (tensors named
    /// `prefix/<name>` / `suffix/<name>` / `values`).
    ///
    /// A split layer is geometry-in-the-artifact: the torus lives inside
    /// the compiled prefix, and there is no tokenizer, so the manifest's
    /// MLM-only fields (vocab, seq_len, torus) are recorded as zero /
    /// placeholder — this is a *weight dump* for artifact-based serving
    /// and offline analysis, not an [`crate::model::LramMlm`] checkpoint.
    pub fn export_checkpoint(&self, dir: &std::path::Path, step: u64) -> Result<CkptManifest> {
        let mut w = CheckpointWriter::new(dir)?;
        w.write_f32(
            "values",
            &[self.table.rows(), self.table.dim() as u64],
            self.table.data(),
        )?;
        for (tag, state, artifact) in [
            ("prefix", &self.prefix_state, &self.prefix),
            ("suffix", &self.suffix_state, &self.suffix),
        ] {
            for (lit, spec) in state.tensors.iter().zip(&artifact.manifest.state) {
                if spec.dtype != crate::runtime::Dtype::F32 {
                    continue; // integer side state (e.g. rng keys) is rebuilt, not shipped
                }
                let host = crate::runtime::HostTensor::from_literal(lit)?;
                let shape: Vec<u64> = spec.shape.iter().map(|&d| d as u64).collect();
                let shape = if shape.is_empty() { vec![1] } else { shape };
                w.write_f32(&format!("{tag}/{}", spec.name), &shape, host.as_f32()?)?;
            }
        }
        let desc = crate::checkpoint::ModelDesc {
            vocab: 0,
            width: self.width,
            heads: self.heads,
            m: self.m,
            k_top: self.k_top,
            seq_len: 0,
            max_batch: self.batch,
            torus_k: [4; 8], // placeholder: the torus is baked into the prefix HLO
            query_scale: 0.0,
        };
        w.finish(step, "", desc)
    }

    /// Run the full split pipeline on x (batch x width).
    pub fn run(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch;
        assert_eq!(x.len(), b * self.width);
        let outs = self.prefix.call(
            &mut self.prefix_state,
            &[HostTensor::F32(x.to_vec(), vec![b, self.width])],
        )?;
        let idx = outs[0].as_i32()?;
        let wts = outs[1].as_f32()?.to_vec();
        let scale = outs[2].as_f32()?.to_vec();

        // the O(1) random-access gather — the memstore hot path (rows
        // are software-prefetched inside gather_rows; the full fused
        // index+gather pipeline lives in lattice::batch for the
        // pure-rust path, where the k x m intermediate can be skipped)
        for (i, &ix) in idx.iter().enumerate() {
            self.row_idx[i] = ix as u64;
        }
        self.table.gather_rows(&self.row_idx, &mut self.gathered);
        if let Some(stats) = self.stats.as_mut() {
            stats.record_batch_f32(&self.row_idx, &wts);
        }

        let outs = self.suffix.call(
            &mut self.suffix_state,
            &[
                HostTensor::F32(
                    self.gathered.clone(),
                    vec![b, self.heads, self.k_top, self.m],
                ),
                HostTensor::F32(wts, vec![b, self.heads, self.k_top]),
                HostTensor::F32(scale, vec![b, self.heads]),
            ],
        )?;
        Ok(outs[0].as_f32()?.to_vec())
    }
}

/// A PKM layer in split mode (O(sqrt N) scoring prefix).
pub struct SplitPkmLayer {
    score: Arc<Artifact>,
    combine: Arc<Artifact>,
    score_state: ArtifactState,
    combine_state: ArtifactState,
    pub table: ValueTable,
    pub width: usize,
    pub heads: usize,
    pub k_top: usize,
    pub batch: usize,
    gathered: Vec<f32>,
    row_idx: Vec<u64>,
}

impl SplitPkmLayer {
    pub fn load(rt: &Runtime, width: usize, n_keys: usize) -> Result<Self> {
        let score = rt.load(&format!("micro_pkm_score_w{width}_nk{n_keys}"))?;
        let combine = rt.load(&format!("micro_pkm_combine_w{width}"))?;
        let heads = score.manifest.heads.ok_or_else(|| anyhow!("score manifest: heads"))?;
        let k_top = score.manifest.k_top.ok_or_else(|| anyhow!("score manifest: k_top"))?;
        let batch = score.manifest.batch.b;
        let locations = (n_keys * n_keys) as u64;
        let mut table = ValueTable::zeros(locations, width)?;
        table.randomize_rows(0x93B, 0.02, locations.min(1 << 18));
        let mut score_state = score.zero_state()?;
        // fill the codebooks with deterministic values so scoring is
        // non-degenerate (state layout: bn then p/* per manifest order)
        randomize_state(&mut score_state, &score.manifest)?;
        let combine_state = combine.zero_state()?;
        Ok(SplitPkmLayer {
            score,
            combine,
            score_state,
            combine_state,
            table,
            width,
            heads,
            k_top,
            batch,
            gathered: vec![0.0; batch * heads * k_top * width],
            row_idx: vec![0; batch * heads * k_top],
        })
    }

    pub fn param_count(&self) -> u64 {
        self.table.param_count()
    }

    pub fn run(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let b = self.batch;
        assert_eq!(x.len(), b * self.width, "input must be batch x width");
        let outs = self.score.call(
            &mut self.score_state,
            &[HostTensor::F32(x.to_vec(), vec![b, self.width])],
        )?;
        let idx = outs[0].as_i32()?;
        let wts = outs[1].as_f32()?.to_vec();
        for (i, &ix) in idx.iter().enumerate() {
            self.row_idx[i] = ix as u64;
        }
        self.table.gather_rows(&self.row_idx, &mut self.gathered);
        let outs = self.combine.call(
            &mut self.combine_state,
            &[
                HostTensor::F32(
                    self.gathered.clone(),
                    vec![b, self.heads, self.k_top, self.width],
                ),
                HostTensor::F32(wts, vec![b, self.heads, self.k_top]),
            ],
        )?;
        Ok(outs[0].as_f32()?.to_vec())
    }
}

/// A dense w -> 4w -> w reference layer (the replaced subnetwork).
pub struct DenseLayer {
    art: Arc<Artifact>,
    state: ArtifactState,
    pub width: usize,
    pub batch: usize,
}

impl DenseLayer {
    pub fn load(rt: &Runtime, width: usize) -> Result<Self> {
        let art = rt.load(&format!("micro_dense_w{width}"))?;
        let mut state = art.zero_state()?;
        randomize_state(&mut state, &art.manifest)?;
        let batch = art.manifest.batch.b;
        Ok(DenseLayer { art, state, width, batch })
    }

    pub fn param_count(&self) -> u64 {
        self.art.manifest.n_params.unwrap_or(0)
    }

    pub fn run(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let outs = self.art.call(
            &mut self.state,
            &[HostTensor::F32(x.to_vec(), vec![self.batch, self.width])],
        )?;
        Ok(outs[0].as_f32()?.to_vec())
    }
}

/// Fill the state with semantically sensible deterministic values:
/// weight matrices / codebooks get small gaussians, BatchNorm gains and
/// running variances get 1, everything else stays 0.
fn randomize_state(state: &mut ArtifactState, manifest: &crate::runtime::Manifest) -> Result<()> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(0x57A7E);
    for (lit, spec) in state.tensors.iter_mut().zip(&manifest.state) {
        if spec.dtype != crate::runtime::Dtype::F32 {
            continue;
        }
        let n = spec.element_count();
        let name = spec.name.as_str();
        let v: Vec<f32> = if name.ends_with("/w") || name.contains("keys") {
            (0..n).map(|_| (rng.normal() * 0.05) as f32).collect()
        } else if name.ends_with("/g") || name.contains("var") {
            vec![1.0; n]
        } else {
            vec![0.0; n]
        };
        *lit = crate::runtime::literal_f32(&v, &spec.shape)?;
    }
    Ok(())
}
