//! The pure-rust LRAM masked-language model — one definition of the
//! forward pass shared by *serving* ([`crate::server::EngineBackend`])
//! and *training* ([`crate::coordinator::EngineTrainer`]).
//!
//! That sharing is the point: the checkpoint round-trip guarantee
//! ("served logits are bit-identical to the trainer's forward pass")
//! only holds if there is exactly one forward implementation, so the
//! model lives here and both sides borrow it.
//!
//! Architecture (split-mode shapes, all pure rust):
//!
//! ```text
//! tokens ─embed+pos+neighbour─► h ─wq─► queries ─lattice lookup+gather─► v
//!                               │                                        │
//!                               └────────residual── y = h + wo·v ◄───────┘
//!                                                   y ─w_out─► log-softmax
//! ```

pub mod sharded;

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{Checkpoint, CheckpointWriter, Manifest, ModelDesc};
use crate::lattice::e8::vec8;
use crate::lattice::{
    BackwardCache, BatchLookupEngine, BatchOutput, LatticeLookup, ShardPlan, TorusK,
};
use crate::memstore::{AccessStats, DenseAdam, QuantizedValueTable, SparseAdam, ValueTable};
use crate::util::rng::Rng;

pub use sharded::{ShardedMemory, ValueShard};

/// Numeric implementation of the serving memory stage.
///
/// `F64` is the bit-exact reference path shared with training; `F32`
/// runs the fused lookup+gather through the SIMD f32 kernels
/// ([`crate::lattice::simd`]); `F32Q8` additionally gathers from
/// int8-quantized value rows (per-row scale, dequantized inside the
/// fused gather).  The f32/q8 paths are *serving-only* accelerations:
/// training always runs `F64`, and selection stays a deterministic
/// function of the query on every path (same canonical tie rule).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NumericPath {
    #[default]
    F64,
    F32,
    F32Q8,
}

impl NumericPath {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f64" => Ok(NumericPath::F64),
            "f32" => Ok(NumericPath::F32),
            "f32-q8" => Ok(NumericPath::F32Q8),
            other => bail!("unknown numeric path '{other}' (expected f64, f32 or f32-q8)"),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            NumericPath::F64 => "f64",
            NumericPath::F32 => "f32",
            NumericPath::F32Q8 => "f32-q8",
        }
    }
}

/// Configuration of the pure-rust LRAM MLM.
///
/// The default shapes mirror split-mode's LRAM-small layer: `2^18` torus
/// slots, 32 hits per query, `m = 64`-dim values — small enough to build
/// in milliseconds, structured exactly like the billion-slot case (the
/// value table is lazily mapped, so only touched rows go resident).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub seq_len: usize,
    /// dense model width (split-mode `w`)
    pub width: usize,
    /// independent lattice query heads per position
    pub heads: usize,
    /// value-table row dimension (split-mode `m`)
    pub m: usize,
    /// hits kept per query
    pub k_top: usize,
    /// torus side lengths (each a positive multiple of 4)
    pub torus_k: [i64; 8],
    /// engine worker threads; 0 = all available parallelism
    pub threads: usize,
    /// deterministic weight-init seed
    pub seed: u64,
    /// scale applied to projected queries so they spread over the torus
    pub query_scale: f64,
    /// track per-slot access statistics (Table-5 serving observability)
    pub track_stats: bool,
    /// numeric implementation of the memory stage (serving knob, not
    /// model geometry — defaults to the bit-exact f64 reference)
    pub numeric_path: NumericPath,
    /// value-table shard workers (serving knob, not model geometry):
    /// 1 = the classic fused single-owner path; N > 1 partitions the
    /// table rows across N worker threads ([`ShardedMemory`]),
    /// bit-identical per numeric path
    pub shards: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            seq_len: 32,
            width: 64,
            heads: 2,
            m: 64,
            k_top: 32,
            torus_k: [16, 16, 8, 8, 8, 8, 8, 8],
            threads: 1,
            seed: 0xE85E44E,
            query_scale: 4.0,
            track_stats: true,
            numeric_path: NumericPath::F64,
            shards: 1,
        }
    }
}

impl EngineConfig {
    /// The checkpoint-manifest description of this geometry.
    pub fn to_desc(&self, vocab: usize) -> ModelDesc {
        ModelDesc {
            vocab,
            width: self.width,
            heads: self.heads,
            m: self.m,
            k_top: self.k_top,
            seq_len: self.seq_len,
            max_batch: self.max_batch,
            torus_k: self.torus_k,
            query_scale: self.query_scale,
        }
    }

    /// Rebuild a config from a checkpoint description.  `threads`,
    /// `track_stats` and the init `seed` are runtime knobs, not model
    /// geometry — they come from the caller.
    pub fn from_desc(desc: &ModelDesc, threads: usize, track_stats: bool) -> Self {
        EngineConfig {
            max_batch: desc.max_batch,
            seq_len: desc.seq_len,
            width: desc.width,
            heads: desc.heads,
            m: desc.m,
            k_top: desc.k_top,
            torus_k: desc.torus_k,
            threads,
            seed: 0, // unused: weights come from the checkpoint
            query_scale: desc.query_scale,
            track_stats,
            numeric_path: NumericPath::F64,
            shards: 1,
        }
    }
}

/// Checkpoint tensor names for the MLM weights.
pub mod tensor_names {
    pub const EMBED: &str = "embed";
    pub const POS: &str = "pos";
    pub const WQ: &str = "wq";
    pub const WO: &str = "wo";
    pub const W_OUT: &str = "w_out";
    pub const VALUES: &str = "values";
    pub const ADAM_M: &str = "adam_m";
    pub const ADAM_V: &str = "adam_v";
    pub const ADAM_T: &str = "adam_t";
    /// Routing (dense-Adam over `wq`) optimizer state; present since
    /// checkpoint format version 2 when the routing was trained.
    pub const WQ_ADAM_M: &str = "wq_adam_m";
    pub const WQ_ADAM_V: &str = "wq_adam_v";
    pub const WQ_ADAM_T: &str = "wq_adam_t";
    /// Quantized value table: `i8 [rows, m]` codes plus `f32 [rows]`
    /// per-row scales; written since checkpoint format version 3 so the
    /// f32-q8 serving path can map its table zero-copy.
    pub const VALUES_Q8: &str = "values_q8";
    pub const VALUES_Q8_SCALE: &str = "values_q8_scale";

    /// Per-shard value-table blob (checkpoint format version 4, sharded
    /// saves): shard `k`'s slice of `values`, rows `bounds[k]..bounds[k+1]`
    /// of the manifest's shard plan.
    pub fn values_shard(k: usize) -> String {
        format!("values_shard_{k}")
    }

    /// Per-shard quantized codes (v4 sharded companion of [`VALUES_Q8`]).
    pub fn values_q8_shard(k: usize) -> String {
        format!("values_q8_shard_{k}")
    }

    /// Per-shard quantization scales (v4 sharded companion of
    /// [`VALUES_Q8_SCALE`]).
    pub fn values_q8_scale_shard(k: usize) -> String {
        format!("values_q8_scale_shard_{k}")
    }
}

/// The LRAM MLM: dense prefix → fused lattice lookup+gather → dense
/// suffix, all pure rust.  Construct with deterministic seed weights
/// ([`LramMlm::seeded`]) or from trained weights
/// ([`LramMlm::from_checkpoint`]).
pub struct LramMlm {
    pub cfg: EngineConfig,
    pub vocab: usize,
    /// token embeddings, `vocab x width`
    pub embed: Vec<f32>,
    /// position embeddings, `seq_len x width`
    pub pos: Vec<f32>,
    /// query projection, `(heads * 8) x width`
    pub wq: Vec<f32>,
    /// head-combine projection, `width x (heads * m)`
    pub wo: Vec<f32>,
    /// output projection, `vocab x width`
    pub w_out: Vec<f32>,
    pub engine: BatchLookupEngine,
    pub table: ValueTable,
    /// which numeric implementation the memory stage runs (see
    /// [`NumericPath`]); switch with [`Self::set_numeric_path`]
    path: NumericPath,
    /// int8 companion of `table`, present iff the path is `F32Q8`
    /// (quantized on switch, or injected zero-copy from a checkpoint via
    /// [`Self::set_quantized_table`])
    qtable: Option<QuantizedValueTable>,
    /// sharded memory executor; `Some` iff `cfg.shards > 1`, in which
    /// case the memory stage fans out over its workers instead of the
    /// fused single-owner path
    sharded: Option<ShardedMemory>,
    /// whether `table` holds every logical row.  False only when loaded
    /// from a sharded (v4) checkpoint with compact per-worker slices —
    /// then `table` is a lazily-mapped zero stub the sharded forward
    /// never touches, and the oracle path / re-saving are refused.
    table_full: bool,
    // reusable scratch, allocated once at max-batch size; pub(crate) so
    // the trainer's backward pass can read the forward intermediates
    pub(crate) h: Vec<f32>,
    pub(crate) queries: Vec<f64>,
    pub(crate) lk: BatchOutput,
    pub(crate) gathered: Vec<f32>,
    /// Trainer-only capture of the last f64 fused forward's routing
    /// decisions, so [`Self::backward_queries`] skips the scoring +
    /// top-k recompute.  Filled only by the f64 single-owner memory
    /// stage; every other path (oracle, sharded, f32, q8) invalidates
    /// it and the backward falls back to recomputing — bit-identical
    /// either way.
    bwd_cache: BackwardCache,
}

impl LramMlm {
    fn resolve_threads(cfg: &EngineConfig) -> usize {
        if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        }
    }

    fn validate_shape(cfg: &EngineConfig, vocab: usize) -> Result<()> {
        ensure!(vocab > 0, "vocab must be positive");
        ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
        ensure!(cfg.seq_len >= 2, "seq_len must be at least 2");
        ensure!(cfg.width > 0 && cfg.heads > 0 && cfg.m > 0, "degenerate shape");
        Ok(())
    }

    /// Deterministic seed-weight model (an untrained but well-formed
    /// model — the serving-path contract is shape, determinism and
    /// throughput, not perplexity).
    pub fn seeded(cfg: EngineConfig, vocab: usize) -> Result<Self> {
        Self::validate_shape(&cfg, vocab)?;
        let torus = TorusK::new(cfg.torus_k)?;
        let engine = BatchLookupEngine::with_threads(torus, cfg.k_top, Self::resolve_threads(&cfg));
        let locations = torus.num_locations();
        let mut table = ValueTable::zeros(locations, cfg.m)?;
        // deterministic non-zero values; initialisation capped so huge
        // tori stay lazily mapped (untouched rows read as zero)
        table.randomize_rows(cfg.seed ^ 0xE8, 0.02, locations.min(1 << 15));

        let mut rng = Rng::new(cfg.seed);
        let mut normal = |n: usize, std: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * std) as f32).collect()
        };
        let inv_sqrt_w = 1.0 / (cfg.width as f64).sqrt();
        let embed = normal(vocab * cfg.width, 1.0);
        let pos = normal(cfg.seq_len * cfg.width, 0.5);
        let wq = normal(cfg.heads * 8 * cfg.width, inv_sqrt_w);
        let wo = normal(cfg.width * cfg.heads * cfg.m, 0.05);
        let w_out = normal(vocab * cfg.width, inv_sqrt_w);
        let mut model = Self::assemble(cfg, vocab, embed, pos, wq, wo, w_out, engine, table)?;
        if model.cfg.shards > 1 {
            model.attach_seeded_shards()?;
        }
        Ok(model)
    }

    /// Shard workers for a seed-weight model: every worker re-creates
    /// the full deterministic table from the seed (byte-identical to the
    /// coordinator's, laziness preserved) and quantizes its own codes
    /// when the path needs them.
    fn attach_seeded_shards(&mut self) -> Result<()> {
        let rows = self.table.rows();
        let plan = ShardPlan::new(rows, self.cfg.shards);
        let mut shards = Vec::with_capacity(self.cfg.shards);
        for _ in 0..self.cfg.shards {
            let mut t = ValueTable::zeros(rows, self.cfg.m)?;
            t.randomize_rows(self.cfg.seed ^ 0xE8, 0.02, rows.min(1 << 15));
            let q8 = match self.path {
                NumericPath::F32Q8 => Some(QuantizedValueTable::from_table(&t)?),
                _ => None,
            };
            shards.push(ValueShard { base: 0, table: t, q8 });
        }
        self.sharded = Some(ShardedMemory::new(&self.engine, plan, shards)?);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        cfg: EngineConfig,
        vocab: usize,
        embed: Vec<f32>,
        pos: Vec<f32>,
        wq: Vec<f32>,
        wo: Vec<f32>,
        w_out: Vec<f32>,
        engine: BatchLookupEngine,
        table: ValueTable,
    ) -> Result<Self> {
        let max_positions = cfg.max_batch * cfg.seq_len;
        let path = cfg.numeric_path;
        let mut model = LramMlm {
            vocab,
            embed,
            pos,
            wq,
            wo,
            w_out,
            engine,
            table,
            path: NumericPath::F64,
            qtable: None,
            sharded: None,
            table_full: true,
            h: vec![0.0; max_positions * cfg.width],
            queries: vec![0.0; max_positions * cfg.heads * 8],
            lk: BatchOutput::default(),
            gathered: vec![0.0; max_positions * cfg.heads * cfg.m],
            bwd_cache: BackwardCache::default(),
            cfg,
        };
        model.set_numeric_path(path)?;
        Ok(model)
    }

    /// The numeric path the memory stage currently runs.
    pub fn numeric_path(&self) -> NumericPath {
        self.path
    }

    /// Switch the serving memory stage between the f64 reference and the
    /// f32 / f32-q8 fast paths.  Switching to `F32Q8` quantizes the
    /// value table once (int8 codes + per-row scales) unless a quantized
    /// table was already injected ([`Self::set_quantized_table`]).
    pub fn set_numeric_path(&mut self, path: NumericPath) -> Result<()> {
        if path == NumericPath::F32Q8 {
            if let Some(sh) = &self.sharded {
                // sharded q8 gathers from per-worker quantized slices,
                // never from a coordinator-side table
                ensure!(
                    sh.quantized(),
                    "the sharded memory has no quantized value slices; save a sharded \
                     checkpoint and reload it, or serve with shards = 1"
                );
            } else if self.qtable.is_none() {
                self.qtable = Some(QuantizedValueTable::from_table(&self.table)?);
            }
        }
        self.path = path;
        Ok(())
    }

    /// The shard plan when the memory stage runs sharded (`/stats`
    /// per-shard reporting), `None` on the fused single-owner path.
    pub fn shard_plan(&self) -> Option<&ShardPlan> {
        self.sharded.as_ref().map(ShardedMemory::plan)
    }

    /// Inject a pre-built quantized value table (e.g. mapped zero-copy
    /// from a version-3 checkpoint) instead of re-quantizing at load.
    pub fn set_quantized_table(&mut self, q: QuantizedValueTable) -> Result<()> {
        ensure!(
            q.rows() == self.table.rows() && q.dim() == self.cfg.m,
            "quantized table is {} x {}, value table is {} x {}",
            q.rows(),
            q.dim(),
            self.table.rows(),
            self.cfg.m
        );
        self.qtable = Some(q);
        Ok(())
    }

    /// Load trained weights from an opened checkpoint.  The dense
    /// tensors are read (and checksum-verified) into memory; the value
    /// table is mapped copy-on-write — zero-copy, so a multi-GB table
    /// costs physical memory only for rows actually served.  Every
    /// shape is validated against the manifest geometry; mismatches are
    /// loud errors, never silently misweighted models.
    ///
    /// Sharded (v4) checkpoints are reassembled into one logical table
    /// here; sharded *serving* goes through
    /// [`Self::from_checkpoint_sharded`] instead.
    pub fn from_checkpoint(ck: &Checkpoint, threads: usize) -> Result<Self> {
        Self::from_checkpoint_sharded(ck, threads, 1, NumericPath::F64)
    }

    /// [`Self::from_checkpoint`] with shard-aware table sourcing:
    ///
    /// | checkpoint \ `shards` | 1                      | N > 1                       |
    /// |-----------------------|------------------------|-----------------------------|
    /// | unsharded (v1–v3, v4) | classic zero-copy map  | N full copy-on-write views  |
    /// | sharded v4, N shards  | reassemble (faults all)| compact per-shard maps      |
    /// | sharded v4, M ≠ N     | reassemble (faults all)| loud error naming M         |
    ///
    /// `numeric_path` decides whether per-worker quantized slices are
    /// loaded (mapped from the checkpoint when present, re-quantized
    /// otherwise); the returned model already runs that path.
    pub fn from_checkpoint_sharded(
        ck: &Checkpoint,
        threads: usize,
        shards: usize,
        numeric_path: NumericPath,
    ) -> Result<Self> {
        use tensor_names::*;
        let desc = &ck.manifest.model;
        let cfg = EngineConfig::from_desc(desc, threads, false);
        let vocab = desc.vocab;
        Self::validate_shape(&cfg, vocab)
            .with_context(|| format!("checkpoint {}: bad geometry", ck.manifest.checkpoint_id))?;
        let torus = TorusK::new(cfg.torus_k).context("checkpoint torus geometry")?;
        ensure!(
            cfg.k_top > 0,
            "checkpoint {}: k_top must be positive",
            ck.manifest.checkpoint_id
        );
        let engine = BatchLookupEngine::with_threads(torus, cfg.k_top, Self::resolve_threads(&cfg));

        let expect_2d = |name: &str, rows: u64, cols: u64| -> Result<()> {
            let spec = ck.manifest.tensor(name)?;
            ensure!(
                spec.shape == [rows, cols],
                "tensor '{name}': checkpoint shape {:?} does not match the manifest \
                 geometry [{rows}, {cols}] — config-incompatible checkpoint",
                spec.shape
            );
            Ok(())
        };
        let (w, hd, m) = (cfg.width as u64, cfg.heads as u64, cfg.m as u64);
        expect_2d(EMBED, vocab as u64, w)?;
        expect_2d(POS, cfg.seq_len as u64, w)?;
        expect_2d(WQ, hd * 8, w)?;
        expect_2d(WO, w, hd * m)?;
        expect_2d(W_OUT, vocab as u64, w)?;

        let locations = torus.num_locations();
        let m_usize = cfg.m;
        let n = shards.max(1);
        let want_q8 = numeric_path == NumericPath::F32Q8;
        let dense = (
            ck.read_f32(EMBED)?,
            ck.read_f32(POS)?,
            ck.read_f32(WQ)?,
            ck.read_f32(WO)?,
            ck.read_f32(W_OUT)?,
        );

        let mut model = match &ck.manifest.shards {
            None => {
                // unsharded table blob (v1–v3, or an unsharded v4 save)
                expect_2d(VALUES, locations, m)?;
                let table = ck.map_table(VALUES)?;
                let mut model = Self::assemble(
                    cfg, vocab, dense.0, dense.1, dense.2, dense.3, dense.4, engine, table,
                )?;
                if n > 1 {
                    // each worker gets its own full copy-on-write view;
                    // ownership still partitions the *rows* exactly once
                    let plan = ShardPlan::new(locations, n);
                    let mut worker_shards = Vec::with_capacity(n);
                    for _ in 0..n {
                        let t = ck.map_table(VALUES)?;
                        let q8 = if want_q8 {
                            Some(Self::q8_from_unsharded(ck, &t)?)
                        } else {
                            None
                        };
                        worker_shards.push(ValueShard { base: 0, table: t, q8 });
                    }
                    model.sharded = Some(ShardedMemory::new(&model.engine, plan, worker_shards)?);
                    model.cfg.shards = n;
                }
                model
            }
            Some(bounds) => {
                let plan = ShardPlan::from_bounds(bounds.clone()).with_context(|| {
                    format!("checkpoint {}: bad shard manifest", ck.manifest.checkpoint_id)
                })?;
                ensure!(
                    plan.rows() == locations,
                    "checkpoint {}: shard manifest covers {} rows, torus geometry has {}",
                    ck.manifest.checkpoint_id,
                    plan.rows(),
                    locations
                );
                let saved = plan.n_shards();
                if n == 1 {
                    // reassemble one logical table — faults every row in,
                    // so this is for training/inspection, not huge serving
                    let mut table = ValueTable::zeros(locations, m_usize)?;
                    for k in 0..saved {
                        let r = plan.range(k);
                        if r.start == r.end {
                            continue;
                        }
                        let name = values_shard(k);
                        expect_2d(&name, r.end - r.start, m)?;
                        let data = ck.read_f32(&name)?;
                        for (i, row) in (r.start..r.end).enumerate() {
                            table
                                .row_mut(row)
                                .copy_from_slice(&data[i * m_usize..(i + 1) * m_usize]);
                        }
                    }
                    Self::assemble(
                        cfg, vocab, dense.0, dense.1, dense.2, dense.3, dense.4, engine, table,
                    )?
                } else {
                    ensure!(
                        n == saved,
                        "checkpoint {} was saved with {saved} shards; serve it with \
                         --shards {saved}, or --shards 1 to reassemble the full table",
                        ck.manifest.checkpoint_id
                    );
                    let mut worker_shards = Vec::with_capacity(saved);
                    for k in 0..saved {
                        let r = plan.range(k);
                        let owned = r.end - r.start;
                        let name = values_shard(k);
                        expect_2d(&name, owned, m)?;
                        // empty shards get a 1-row zero table (mmap
                        // rejects zero length); nothing gathers from it
                        let t = if owned == 0 {
                            ValueTable::zeros(1, m_usize)?
                        } else {
                            ck.map_table(&name)?
                        };
                        let q8 = if want_q8 {
                            Some(Self::q8_for_shard(ck, k, &t, owned)?)
                        } else {
                            None
                        };
                        worker_shards.push(ValueShard { base: r.start, table: t, q8 });
                    }
                    // the coordinator's table is a lazily-mapped zero
                    // stub: the sharded forward never reads it, and
                    // table_full = false refuses the paths that would
                    // (scalar oracle, re-save)
                    let stub = ValueTable::zeros(locations, m_usize)?;
                    let mut model = Self::assemble(
                        cfg, vocab, dense.0, dense.1, dense.2, dense.3, dense.4, engine, stub,
                    )?;
                    model.table_full = false;
                    model.sharded = Some(ShardedMemory::new(&model.engine, plan, worker_shards)?);
                    model.cfg.shards = n;
                    model
                }
            }
        };
        model.set_numeric_path(numeric_path)?;
        Ok(model)
    }

    /// Quantized slice for one worker from an *unsharded* checkpoint:
    /// map the monolithic q8 blobs zero-copy when present, else
    /// re-quantize from the worker's own table view.
    fn q8_from_unsharded(ck: &Checkpoint, table: &ValueTable) -> Result<QuantizedValueTable> {
        use tensor_names::*;
        if ck.manifest.has_tensor(VALUES_Q8) && ck.manifest.has_tensor(VALUES_Q8_SCALE) {
            let codes = ck.map_i8(VALUES_Q8)?;
            let scales = ck.read_f32(VALUES_Q8_SCALE)?;
            QuantizedValueTable::from_parts(codes, scales, table.rows(), table.dim())
        } else {
            QuantizedValueTable::from_table(table)
        }
    }

    /// Quantized slice for shard `k` of a sharded (v4) checkpoint.
    fn q8_for_shard(
        ck: &Checkpoint,
        k: usize,
        table: &ValueTable,
        owned: u64,
    ) -> Result<QuantizedValueTable> {
        use tensor_names::*;
        let codes_name = values_q8_shard(k);
        let scale_name = values_q8_scale_shard(k);
        if owned > 0 && ck.manifest.has_tensor(&codes_name) && ck.manifest.has_tensor(&scale_name)
        {
            let codes = ck.map_i8(&codes_name)?;
            let scales = ck.read_f32(&scale_name)?;
            QuantizedValueTable::from_parts(codes, scales, owned, table.dim())
        } else {
            // empty shard (placeholder table) or pre-q8 blobs: quantize
            QuantizedValueTable::from_table(table)
        }
    }

    /// Save the model (and optionally the optimizer state: sparse-Adam
    /// over the value table, dense-Adam over the routing projection) as
    /// a checkpoint directory.  Blobs first, manifest last, so a crashed
    /// save can never be opened.  `fsync` additionally syncs every blob
    /// and the directories on commit, so the checkpoint survives power
    /// loss, not just process crashes (`lram train --fsync`).  `keep`
    /// retains that many checkpoints in total (the live one plus
    /// `keep - 1` `.prev-<step>` predecessors next to it) so serving can
    /// fall back when the newest one is corrupt; `keep <= 1` preserves
    /// the historical replace-in-place behaviour.
    pub fn save_checkpoint(
        &self,
        dir: &Path,
        step: u64,
        tokenizer_hash: &str,
        opt: Option<&SparseAdam>,
        routing_opt: Option<&DenseAdam>,
        fsync: bool,
        keep: usize,
    ) -> Result<Manifest> {
        use tensor_names::*;
        ensure!(
            self.table_full,
            "this model was loaded from a sharded checkpoint with compact table slices; \
             reload it with shards = 1 (reassembles the full table) before re-saving"
        );
        let mut w = CheckpointWriter::new(dir)?.with_fsync(fsync).with_keep(keep);
        let (wd, hd, m) = (self.cfg.width as u64, self.cfg.heads as u64, self.cfg.m as u64);
        w.write_f32(EMBED, &[self.vocab as u64, wd], &self.embed)?;
        w.write_f32(POS, &[self.cfg.seq_len as u64, wd], &self.pos)?;
        w.write_f32(WQ, &[hd * 8, wd], &self.wq)?;
        w.write_f32(WO, &[wd, hd * m], &self.wo)?;
        w.write_f32(W_OUT, &[self.vocab as u64, wd], &self.w_out)?;
        let rows = self.table.rows();
        // always write the quantized companion (format version 3): the
        // f32-q8 serving path maps it zero-copy instead of re-quantizing
        // a multi-GB table at every load.  Quantize fresh from the live
        // table — a cached self.qtable could predate training updates.
        let q = QuantizedValueTable::from_table(&self.table)?;
        if self.cfg.shards > 1 {
            // sharded save (format version 4): the value table and its
            // q8 companions go down as per-shard slices, plus the shard
            // manifest — so serving can map each shard compactly
            let plan = ShardPlan::new(rows, self.cfg.shards);
            w = w.with_shards(plan.bounds().to_vec());
            let mu = self.cfg.m;
            for k in 0..plan.n_shards() {
                let r = plan.range(k);
                let owned = r.end - r.start;
                let (lo, hi) = (r.start as usize * mu, r.end as usize * mu);
                w.write_f32(&values_shard(k), &[owned, m], &self.table.data()[lo..hi])?;
                w.write_i8(&values_q8_shard(k), &[owned, m], &q.data()[lo..hi])?;
                w.write_f32(
                    &values_q8_scale_shard(k),
                    &[owned],
                    &q.scales()[r.start as usize..r.end as usize],
                )?;
            }
        } else {
            w.write_f32(VALUES, &[rows, m], self.table.data())?;
            w.write_i8(VALUES_Q8, &[rows, m], q.data())?;
            w.write_f32(VALUES_Q8_SCALE, &[rows], q.scales())?;
        }
        if let Some(opt) = opt {
            ensure!(
                opt.first_moment().rows() == rows && opt.first_moment().dim() == self.cfg.m,
                "optimizer state shape does not match the value table"
            );
            w.write_f32(ADAM_M, &[rows, m], opt.first_moment().data())?;
            w.write_f32(ADAM_V, &[rows, m], opt.second_moment().data())?;
            w.write_u32(ADAM_T, &[rows], opt.step_counts())?;
        }
        if let Some(r) = routing_opt {
            ensure!(
                r.len() == self.wq.len(),
                "routing optimizer state has {} entries, wq has {}",
                r.len(),
                self.wq.len()
            );
            ensure!(
                r.step_count() <= u32::MAX as u64,
                "routing step count {} overflows the checkpoint field",
                r.step_count()
            );
            w.write_f32(WQ_ADAM_M, &[hd * 8, wd], r.first_moment())?;
            w.write_f32(WQ_ADAM_V, &[hd * 8, wd], r.second_moment())?;
            w.write_u32(WQ_ADAM_T, &[1], &[r.step_count() as u32])?;
        }
        w.finish(step, tokenizer_hash, self.cfg.to_desc(self.vocab))
    }

    /// Total parameters reachable through the value table.
    pub fn param_count(&self) -> u64 {
        self.table.param_count()
    }

    fn clamp_token(&self, t: i32) -> usize {
        if t < 0 || t as usize >= self.vocab {
            (crate::tokenizer::UNK_ID as usize).min(self.vocab - 1)
        } else {
            t as usize
        }
    }

    /// One forward pass: `rows * seq_len` token ids in, `rows * seq_len
    /// * vocab` log-probabilities out (row-major, ragged rows
    /// first-class).  `use_oracle` routes the memory stage through the
    /// scalar [`LatticeLookup`] reference instead of the fused engine —
    /// differential tests demand bit-identical output either way.
    pub fn forward(
        &mut self,
        tokens: &[i32],
        use_oracle: bool,
        mut stats: Option<&mut AccessStats>,
    ) -> Result<Vec<f32>> {
        let (seq_len, width, heads, m) =
            (self.cfg.seq_len, self.cfg.width, self.cfg.heads, self.cfg.m);
        let rows = tokens.len() / seq_len;
        ensure!(
            rows >= 1 && rows <= self.cfg.max_batch && tokens.len() == rows * seq_len,
            "batch of {} tokens does not fit {} x {seq_len}",
            tokens.len(),
            self.cfg.max_batch
        );
        let positions = rows * seq_len;

        // dense prefix 1/2: token + position embeddings with a cheap
        // neighbour mix so mask predictions depend on their context
        for r in 0..rows {
            for c in 0..seq_len {
                let p = r * seq_len + c;
                // resolve neighbour ids before borrowing the h row
                let t = self.clamp_token(tokens[p]);
                let left = (c > 0).then(|| self.clamp_token(tokens[p - 1]));
                let right = (c + 1 < seq_len).then(|| self.clamp_token(tokens[p + 1]));
                let e = &self.embed[t * width..(t + 1) * width];
                let pe = &self.pos[c * width..(c + 1) * width];
                let h = &mut self.h[p * width..(p + 1) * width];
                for w in 0..width {
                    h[w] = e[w] + pe[w];
                }
                if let Some(lt) = left {
                    let le = &self.embed[lt * width..(lt + 1) * width];
                    for w in 0..width {
                        h[w] += 0.5 * le[w];
                    }
                }
                if let Some(rt) = right {
                    let re = &self.embed[rt * width..(rt + 1) * width];
                    for w in 0..width {
                        h[w] += 0.5 * re[w];
                    }
                }
            }
        }

        // dense prefix 2/2: project each position to `heads` 8-d lattice
        // queries (the split-mode prefix shape), f64 for the engine
        for p in 0..positions {
            let h = &self.h[p * width..(p + 1) * width];
            for head in 0..heads {
                for d in 0..8 {
                    let wrow = &self.wq[(head * 8 + d) * width..(head * 8 + d + 1) * width];
                    let mut acc = 0.0f64;
                    for w in 0..width {
                        acc += wrow[w] as f64 * h[w] as f64;
                    }
                    self.queries[(p * heads + head) * 8 + d] = acc * self.cfg.query_scale;
                }
            }
        }

        // the O(1) memory stage: fused lookup+gather (or the scalar
        // oracle, bit-identical, for differential testing)
        let n_queries = positions * heads;
        // every path below overwrites the gathered prefix; only the f64
        // fused path re-validates the backward cache as it runs
        self.bwd_cache.invalidate();
        if use_oracle {
            ensure!(
                self.table_full,
                "the scalar oracle path needs the full value table, which this model \
                 (loaded from a sharded checkpoint) does not hold"
            );
            let k_top = self.engine.k_top;
            let mut oracle = LatticeLookup::new(self.engine.torus, k_top);
            let mut idx_row = vec![0u64; k_top];
            let mut w_row = vec![0.0f32; k_top];
            for qi in 0..n_queries {
                let q = vec8(&self.queries[qi * 8..(qi + 1) * 8]);
                let r = oracle.lookup(q);
                for j in 0..k_top {
                    match r.hits.get(j) {
                        Some(hit) => {
                            idx_row[j] = hit.index;
                            w_row[j] = hit.weight as f32;
                        }
                        None => {
                            idx_row[j] = 0;
                            w_row[j] = 0.0;
                        }
                    }
                }
                self.table.gather_weighted(
                    &idx_row,
                    &w_row,
                    &mut self.gathered[qi * m..(qi + 1) * m],
                );
                if let Some(stats) = stats.as_deref_mut() {
                    stats.record_batch_f32(&idx_row, &w_row);
                }
            }
        } else if let Some(sharded) = self.sharded.as_mut() {
            // fan the batch out across the shard workers; a dead worker
            // is an error (poisoned backend), never a partial answer
            let f32_scoring = self.path != NumericPath::F64;
            let q8 = self.path == NumericPath::F32Q8;
            sharded.lookup_gather(
                &self.queries[..n_queries * 8],
                f32_scoring,
                q8,
                &mut self.lk,
                &mut self.gathered,
            )?;
            if let Some(stats) = stats.as_deref_mut() {
                stats.record_batch_f32(&self.lk.indices, &self.lk.weights);
            }
        } else {
            match (self.path, self.qtable.as_ref()) {
                // the f64 training path also captures each query's
                // selected (d2, candidate) pairs, so the routing
                // backward skips the scoring + top-k recompute; the
                // lookup and gather stay bit-identical to the uncached
                // engine call
                (NumericPath::F64, _) => self.engine.lookup_gather_ragged_cached_into(
                    &self.queries[..n_queries * 8],
                    &self.table,
                    &mut self.lk,
                    &mut self.gathered,
                    &mut self.bwd_cache,
                ),
                (NumericPath::F32Q8, Some(q)) => self.engine.lookup_gather_ragged_q8_into(
                    &self.queries[..n_queries * 8],
                    q,
                    &mut self.lk,
                    &mut self.gathered,
                ),
                // F32, or F32Q8 with no quantized table (unreachable:
                // set_numeric_path quantizes on switch — degrade to the
                // plain f32 gather rather than panic)
                (NumericPath::F32, _) | (NumericPath::F32Q8, None) => {
                    self.engine.lookup_gather_ragged_f32_into(
                        &self.queries[..n_queries * 8],
                        &self.table,
                        &mut self.lk,
                        &mut self.gathered,
                    )
                }
            }
            if let Some(stats) = stats.as_deref_mut() {
                stats.record_batch_f32(&self.lk.indices, &self.lk.weights);
            }
        }

        // dense suffix: head combine + residual, tied output projection,
        // log-softmax per position
        let hm = heads * m;
        let mut out = vec![0.0f32; positions * self.vocab];
        let mut y = vec![0.0f32; width];
        for p in 0..positions {
            let h = &self.h[p * width..(p + 1) * width];
            let v = &self.gathered[p * hm..(p + 1) * hm];
            for (w, yw) in y.iter_mut().enumerate() {
                let wo_row = &self.wo[w * hm..(w + 1) * hm];
                let mut acc = h[w];
                for j in 0..hm {
                    acc += wo_row[j] * v[j];
                }
                *yw = acc;
            }
            let orow = &mut out[p * self.vocab..(p + 1) * self.vocab];
            let mut maxv = f32::NEG_INFINITY;
            for (t, o) in orow.iter_mut().enumerate() {
                let wrow = &self.w_out[t * width..(t + 1) * width];
                let mut acc = 0.0f32;
                for w in 0..width {
                    acc += wrow[w] * y[w];
                }
                *o = acc;
                if acc > maxv {
                    maxv = acc;
                }
            }
            let mut sum = 0.0f64;
            for &o in orow.iter() {
                sum += ((o - maxv) as f64).exp();
            }
            let lse = maxv as f64 + sum.ln();
            for o in orow.iter_mut() {
                *o = (*o as f64 - lse) as f32;
            }
        }
        Ok(out)
    }

    /// Routing backward for the *last* forward pass: d(loss)/d(query)
    /// for the first `n_queries` queries, from the upstream gradient
    /// w.r.t. the gathered value rows (`d_gathered`, `n_queries x m`).
    /// Allocation-free and sharded exactly like the forward lookup —
    /// this is how the trainer flows the loss through the lattice kernel
    /// into `wq`.
    pub(crate) fn backward_queries(
        &self,
        n_queries: usize,
        d_gathered: &[f32],
        d_queries: &mut [f64],
    ) {
        // the f64 fused forward captured each query's selected
        // (d2, candidate) pairs; replaying them skips the candidate
        // scoring and canonical top-k per masked query and is
        // bit-identical to the recompute below (pinned by
        // rust/tests/grad_check.rs)
        if self.bwd_cache.matches(n_queries, self.engine.k_top) {
            self.engine.backward_gather_ragged_cached_into(
                &self.queries[..n_queries * 8],
                &self.table,
                d_gathered,
                &self.bwd_cache,
                d_queries,
            );
            return;
        }
        self.engine.backward_gather_ragged_into(
            &self.queries[..n_queries * 8],
            &self.table,
            d_gathered,
            d_queries,
        );
    }

    /// Recompute `y = h + wo·v` for position `p` of the *last* forward
    /// pass (the trainer's backward pass needs it; recomputing one
    /// width-vector is cheaper than storing `positions x width`).
    pub(crate) fn recompute_y(&self, p: usize, y: &mut [f32]) {
        let (width, hm) = (self.cfg.width, self.cfg.heads * self.cfg.m);
        let h = &self.h[p * width..(p + 1) * width];
        let v = &self.gathered[p * hm..(p + 1) * hm];
        for (w, yw) in y.iter_mut().enumerate() {
            let wo_row = &self.wo[w * hm..(w + 1) * hm];
            let mut acc = h[w];
            for j in 0..hm {
                acc += wo_row[j] * v[j];
            }
            *yw = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EngineConfig {
        EngineConfig {
            max_batch: 2,
            seq_len: 8,
            width: 16,
            m: 8,
            k_top: 8,
            torus_k: [4; 8],
            ..EngineConfig::default()
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "lram_model_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_identical() {
        let dir = tmp_dir("rt");
        let mut a = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        a.save_checkpoint(&dir, 7, "feedbeef00000000", None, None, false, 1).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.manifest.step, 7);
        let mut b = LramMlm::from_checkpoint(&ck, 1).unwrap();
        let tokens: Vec<i32> = (0..16).map(|i| (i * 7) % 60 + 2).collect();
        let la = a.forward(&tokens, false, None).unwrap();
        let lb = b.forward(&tokens, false, None).unwrap();
        assert_eq!(la.len(), lb.len());
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f32_and_q8_paths_track_the_f64_forward() {
        // the serving fast paths are tolerance-equal to the f64
        // reference on real logits (bit-equality is only promised within
        // a path, not across numeric paths)
        let tokens: Vec<i32> = (0..16).map(|i| (i * 11) % 60 + 2).collect();
        let mut f64m = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        let base = f64m.forward(&tokens, false, None).unwrap();
        for path in [NumericPath::F32, NumericPath::F32Q8] {
            let mut m = LramMlm::seeded(tiny_cfg(), 64).unwrap();
            m.set_numeric_path(path).unwrap();
            assert_eq!(m.numeric_path(), path);
            let got = m.forward(&tokens, false, None).unwrap();
            assert_eq!(base.len(), got.len());
            let worst = base
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // log-probs over a 64-token vocab: quantization and f32
            // rounding shift logits by far less than this
            assert!(worst < 2e-2, "{} diverges from f64 by {worst}", path.as_str());
            // the same model answers bit-identically when asked twice
            let again = m.forward(&tokens, false, None).unwrap();
            for (x, y) in got.iter().zip(&again) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn checkpoints_always_carry_the_quantized_companion() {
        let dir = tmp_dir("q8");
        let a = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        a.save_checkpoint(&dir, 2, "feedbeef00000000", None, None, false, 1).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        assert!(ck.manifest.has_tensor(tensor_names::VALUES_Q8));
        assert!(ck.manifest.has_tensor(tensor_names::VALUES_Q8_SCALE));
        let rows = a.table.rows();
        let spec = ck.manifest.tensor(tensor_names::VALUES_Q8).unwrap();
        assert_eq!(spec.shape, vec![rows, 8]);
        let scales = ck.read_f32(tensor_names::VALUES_Q8_SCALE).unwrap();
        assert_eq!(scales.len() as u64, rows);
        // mapping codes + scales reconstructs a working quantized table
        let map = ck.map_i8(tensor_names::VALUES_Q8).unwrap();
        let q = QuantizedValueTable::from_parts(map, scales, rows, 8).unwrap();
        let fresh = QuantizedValueTable::from_table(&a.table).unwrap();
        assert_eq!(q.data(), fresh.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_forward_is_bit_identical_to_unsharded() {
        let tokens: Vec<i32> = (0..16).map(|i| (i * 7) % 60 + 2).collect();
        let mut base = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        let la = base.forward(&tokens, false, None).unwrap();
        for shards in [2usize, 3] {
            let cfg = EngineConfig { shards, ..tiny_cfg() };
            let mut m = LramMlm::seeded(cfg, 64).unwrap();
            assert!(m.shard_plan().is_some());
            let lb = m.forward(&tokens, false, None).unwrap();
            assert_eq!(la.len(), lb.len());
            for (x, y) in la.iter().zip(&lb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{shards} shards");
            }
        }
    }

    #[test]
    fn sharded_checkpoint_roundtrip_across_load_modes() {
        let dir = tmp_dir("shrt");
        let tokens: Vec<i32> = (0..16).map(|i| (i * 5) % 60 + 2).collect();
        let cfg = EngineConfig { shards: 3, ..tiny_cfg() };
        let mut a = LramMlm::seeded(cfg, 64).unwrap();
        let la = a.forward(&tokens, false, None).unwrap();
        a.save_checkpoint(&dir, 1, "feedbeef00000000", None, None, false, 1).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.manifest.shards.as_ref().map(Vec::len), Some(4), "N+1 bounds");
        assert!(ck.manifest.has_tensor(&tensor_names::values_shard(0)));
        assert!(!ck.manifest.has_tensor(tensor_names::VALUES));
        // matching shard count: compact per-shard maps
        let mut b = LramMlm::from_checkpoint_sharded(&ck, 1, 3, NumericPath::F64).unwrap();
        let lb = b.forward(&tokens, false, None).unwrap();
        // shards = 1: reassembled full table, fused path
        let mut c = LramMlm::from_checkpoint(&ck, 1).unwrap();
        let lc = c.forward(&tokens, false, None).unwrap();
        for ((x, y), z) in la.iter().zip(&lb).zip(&lc) {
            assert_eq!(x.to_bits(), y.to_bits());
            assert_eq!(x.to_bits(), z.to_bits());
        }
        // mismatched shard count is refused with guidance
        let err = format!(
            "{:#}",
            LramMlm::from_checkpoint_sharded(&ck, 1, 2, NumericPath::F64).unwrap_err()
        );
        assert!(err.contains("--shards 3"), "{err}");
        // a compact-slice model refuses to re-save (its table is a stub)
        assert!(b.save_checkpoint(&dir, 2, "feedbeef00000000", None, None, false, 1).is_err());
        // ...and refuses the oracle path for the same reason
        assert!(b.forward(&tokens, true, None).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unsharded_checkpoint_serves_sharded_through_full_views() {
        let dir = tmp_dir("uns");
        let tokens: Vec<i32> = (0..16).map(|i| (i * 3) % 60 + 2).collect();
        let mut a = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        let la = a.forward(&tokens, false, None).unwrap();
        a.save_checkpoint(&dir, 1, "feedbeef00000000", None, None, false, 1).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.manifest.shards, None);
        let mut b = LramMlm::from_checkpoint_sharded(&ck, 1, 4, NumericPath::F64).unwrap();
        let lb = b.forward(&tokens, false, None).unwrap();
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_q8_serving_matches_the_fused_q8_path() {
        let dir = tmp_dir("shq8");
        let cfg = EngineConfig { shards: 2, ..tiny_cfg() };
        let a = LramMlm::seeded(cfg, 64).unwrap();
        a.save_checkpoint(&dir, 1, "feedbeef00000000", None, None, false, 1).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        assert!(ck.manifest.has_tensor(&tensor_names::values_q8_shard(1)));
        let tokens: Vec<i32> = (0..16).map(|i| (i * 9) % 60 + 2).collect();
        // sharded: per-shard codes mapped from the checkpoint
        let mut b = LramMlm::from_checkpoint_sharded(&ck, 1, 2, NumericPath::F32Q8).unwrap();
        assert_eq!(b.numeric_path(), NumericPath::F32Q8);
        let lb = b.forward(&tokens, false, None).unwrap();
        // fused: reassembled table, re-quantized — same codes row-wise,
        // and the staged gather replays the fused op order bit-exactly
        let mut c = LramMlm::from_checkpoint_sharded(&ck, 1, 1, NumericPath::F32Q8).unwrap();
        let lc = c.forward(&tokens, false, None).unwrap();
        for (x, y) in lb.iter().zip(&lc) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn geometry_mismatch_is_rejected() {
        let dir = tmp_dir("geom");
        let a = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        a.save_checkpoint(&dir, 0, "feedbeef00000000", None, None, false, 1).unwrap();
        // tamper: claim a different width in the manifest
        let path = dir.join(crate::checkpoint::MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"width\":16", "\"width\":32")).unwrap();
        let ck = Checkpoint::open(&dir).unwrap(); // blobs still self-consistent
        let err = format!("{:#}", LramMlm::from_checkpoint(&ck, 1).unwrap_err());
        assert!(err.contains("config-incompatible"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn optimizer_state_rides_along() {
        let dir = tmp_dir("opt");
        let mut a = LramMlm::seeded(tiny_cfg(), 64).unwrap();
        let rows = a.table.rows();
        let mut opt = SparseAdam::new(rows, 8, 1e-3).unwrap();
        let grad = [0.5f32; 8];
        opt.update_row(&mut a.table, 5, &grad);
        a.save_checkpoint(&dir, 1, "feedbeef00000000", Some(&opt), None, false, 1).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        assert!(ck.manifest.has_tensor(tensor_names::ADAM_M));
        let t = ck.map_u32(tensor_names::ADAM_T).unwrap();
        assert_eq!(t.as_slice()[5], 1);
        assert_eq!(t.as_slice()[4], 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
