//! Sharded value-table serving: one persistent worker thread per shard.
//!
//! [`ShardedMemory`] partitions the logical value table's rows across N
//! in-process shard workers (a [`crate::lattice::ShardPlan`] assigns
//! every torus row to exactly one owner) and serves each batch through
//! the staged [`crate::lattice::BatchLookupEngine`] API in three
//! fan-out/fan-in rounds over plain mpsc channels:
//!
//! ```text
//! round 1  score   workers score disjoint *query* slices (any worker
//!                  can score any query — scoring needs no table rows)
//! round 2  select  every worker sees all scored candidates and keeps
//!                  the per-query top-k among the rows *it owns*;
//!                  the coordinator merges the partial top-ks with the
//!                  same canonical order as the fused path
//! round 3  gather  each worker stages its owned surviving rows from
//!                  its table slice; the coordinator combines them in
//!                  canonical slot order
//! ```
//!
//! The protocol is designed for bit-identity with the single-shard fused
//! path on every numeric path (f64 / f32 / f32-q8): selection merges
//! with the exact canonical tie rule, and the combine step replays the
//! fused gather's floating-point operation sequence (see
//! `BatchLookupEngine::combine_gather`).  Differential tests pin this.
//!
//! Workers hold their table slice for the life of the model (NUMA- and
//! cache-friendly: a row is only ever touched by its owner's thread) and
//! die by channel disconnect.  A dead worker surfaces as an `Err` from
//! [`ShardedMemory::lookup_gather`], which serving treats like any other
//! poisoned-backend error (supervised rebuild), never a wrong answer.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::lattice::{
    BatchLookupEngine, BatchOutput, GatherStage, ScoredBatch, ShardPlan, ShardSelection,
};
use crate::memstore::{QuantizedValueTable, ValueTable};

/// One shard's slice of the logical value table, owned by its worker.
pub struct ValueShard {
    /// Logical row id of `table`'s first row.  A *compact* per-shard
    /// table (loaded from a v4 sharded checkpoint) sets this to the
    /// shard's first owned row; a *full* table view (random init, or an
    /// unsharded checkpoint mapped copy-on-write per worker) sets 0.
    pub base: u64,
    pub table: ValueTable,
    /// Quantized companion for the f32-q8 path; sharded q8 serving
    /// requires every shard to carry one.
    pub q8: Option<QuantizedValueTable>,
}

/// Fan-out work items.  `Arc` payloads are shared read-only across all
/// workers; each round's reply must arrive before the next round is
/// sent, so a worker never holds two jobs.
enum Job {
    Score { queries: Arc<Vec<f64>>, lo: usize, hi: usize, f32_scoring: bool },
    SelectF64 { scored: Arc<Vec<ScoredBatch<f64>>> },
    SelectF32 { scored: Arc<Vec<ScoredBatch<f32>>> },
    Gather { merged: Arc<BatchOutput>, q8: bool },
}

enum Reply {
    ScoredF64(ScoredBatch<f64>),
    ScoredF32(ScoredBatch<f32>),
    SelectedF64(ShardSelection<f64>),
    SelectedF32(ShardSelection<f32>),
    Gathered(GatherStage),
}

struct Worker {
    /// `None` only during shutdown (dropping the sender is the stop
    /// signal — no raw locks, no poison state).
    jobs: Option<mpsc::Sender<Job>>,
    replies: mpsc::Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    fn send(&self, shard: usize, job: Job) -> Result<()> {
        self.jobs
            .as_ref()
            .and_then(|tx| tx.send(job).ok())
            .ok_or_else(|| anyhow!("shard worker {shard} died (send)"))
    }

    fn recv(&self, shard: usize) -> Result<Reply> {
        self.replies.recv().map_err(|_| anyhow!("shard worker {shard} died (recv)"))
    }
}

fn worker_loop(
    engine: BatchLookupEngine,
    plan: ShardPlan,
    shard: usize,
    data: ValueShard,
    jobs: mpsc::Receiver<Job>,
    replies: mpsc::Sender<Reply>,
) {
    while let Ok(job) = jobs.recv() {
        let reply = match job {
            Job::Score { queries, lo, hi, f32_scoring } => {
                let slice = &queries[lo * 8..hi * 8];
                if f32_scoring {
                    let mut out = ScoredBatch::default();
                    engine.score_f32_into(slice, &mut out);
                    Reply::ScoredF32(out)
                } else {
                    let mut out = ScoredBatch::default();
                    engine.score_into(slice, &mut out);
                    Reply::ScoredF64(out)
                }
            }
            Job::SelectF64 { scored } => {
                let mut out = ShardSelection::default();
                engine.select_owned(&scored, &plan, shard, &mut out);
                Reply::SelectedF64(out)
            }
            Job::SelectF32 { scored } => {
                let mut out = ShardSelection::default();
                engine.select_owned(&scored, &plan, shard, &mut out);
                Reply::SelectedF32(out)
            }
            Job::Gather { merged, q8 } => {
                let mut out = GatherStage::default();
                match (q8, data.q8.as_ref()) {
                    (true, Some(q)) => {
                        engine.stage_gather_q8(&merged, &plan, shard, data.base, q, &mut out)
                    }
                    // the coordinator only requests q8 when every shard
                    // carries a quantized slice; degrade rather than die
                    _ => {
                        engine.stage_gather(&merged, &plan, shard, data.base, &data.table, &mut out)
                    }
                }
                Reply::Gathered(out)
            }
        };
        if replies.send(reply).is_err() {
            return; // coordinator gone: shut down
        }
    }
}

/// The sharded memory stage: a [`ShardPlan`] plus one persistent worker
/// thread per shard, driven through the staged lookup API.
pub struct ShardedMemory {
    /// Coordinator-side engine for the merge + combine steps (pure
    /// compute on already-collected data; no table access).
    engine: BatchLookupEngine,
    plan: ShardPlan,
    workers: Vec<Worker>,
    /// Every shard carries a quantized slice, so the f32-q8 path may
    /// run sharded.
    has_q8: bool,
}

impl ShardedMemory {
    /// Spawn one worker per shard.  `shards[s]` must cover the rows
    /// `plan.range(s)` — either a compact slice (`base == range.start`)
    /// or a view of the full table (`base == 0`, enough rows).
    pub fn new(
        engine: &BatchLookupEngine,
        plan: ShardPlan,
        shards: Vec<ValueShard>,
    ) -> Result<Self> {
        ensure!(
            shards.len() == plan.n_shards(),
            "shard plan has {} shards, got {} value shards",
            plan.n_shards(),
            shards.len()
        );
        for (s, shard) in shards.iter().enumerate() {
            let range = plan.range(s);
            if range.is_empty() {
                continue; // nothing will ever be gathered from it
            }
            ensure!(
                shard.base <= range.start && shard.base + shard.table.rows() >= range.end,
                "shard {s}: table rows [{}, {}) do not cover owned rows [{}, {})",
                shard.base,
                shard.base + shard.table.rows(),
                range.start,
                range.end
            );
            if let Some(q) = &shard.q8 {
                ensure!(
                    q.rows() == shard.table.rows() && q.dim() == shard.table.dim(),
                    "shard {s}: quantized slice is {} x {}, table slice is {} x {}",
                    q.rows(),
                    q.dim(),
                    shard.table.rows(),
                    shard.table.dim()
                );
            }
        }
        let has_q8 = shards.iter().all(|s| s.q8.is_some());
        let mut workers = Vec::with_capacity(shards.len());
        for (s, data) in shards.into_iter().enumerate() {
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
            // workers score serially; batch-level parallelism comes from
            // the one-thread-per-shard fan-out itself
            let worker_engine = BatchLookupEngine::with_threads(engine.torus, engine.k_top, 1);
            let worker_plan = plan.clone();
            let handle = std::thread::Builder::new()
                .name(format!("lram-shard-{s}"))
                .spawn(move || worker_loop(worker_engine, worker_plan, s, data, job_rx, reply_tx))
                .with_context(|| format!("spawning shard worker {s}"))?;
            workers.push(Worker { jobs: Some(job_tx), replies: reply_rx, handle: Some(handle) });
        }
        Ok(ShardedMemory { engine: engine.clone(), plan, workers, has_q8 })
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn n_shards(&self) -> usize {
        self.workers.len()
    }

    /// Whether the f32-q8 path can run sharded (every shard has codes).
    pub fn quantized(&self) -> bool {
        self.has_q8
    }

    /// One sharded memory stage: queries (`N x 8` row-major f64) in,
    /// merged canonical top-k in `lookup` and weighted value rows in
    /// `gathered` out — bit-identical to the fused single-owner path of
    /// the same numeric path.  `Err` means a shard worker died; the
    /// caller treats the backend as poisoned (it is rebuilt, results
    /// are never partial).
    pub fn lookup_gather(
        &mut self,
        queries: &[f64],
        f32_scoring: bool,
        q8: bool,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) -> Result<()> {
        ensure!(queries.len() % 8 == 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let n_shards = self.workers.len();
        let q8 = q8 && self.has_q8;

        // round 1: score disjoint, contiguous query slices
        let queries = Arc::new(queries.to_vec());
        let qb: Vec<usize> = (0..=n_shards).map(|s| n * s / n_shards).collect();
        for (s, w) in self.workers.iter().enumerate() {
            w.send(
                s,
                Job::Score { queries: Arc::clone(&queries), lo: qb[s], hi: qb[s + 1], f32_scoring },
            )?;
        }

        // rounds 2 (select owned + merge) — monomorphic per score type
        if f32_scoring {
            let mut scored = Vec::with_capacity(n_shards);
            for (s, w) in self.workers.iter().enumerate() {
                match w.recv(s)? {
                    Reply::ScoredF32(b) => scored.push(b),
                    _ => bail!("shard worker {s}: protocol violation (expected f32 scores)"),
                }
            }
            let scored = Arc::new(scored);
            for (s, w) in self.workers.iter().enumerate() {
                w.send(s, Job::SelectF32 { scored: Arc::clone(&scored) })?;
            }
            let mut selections = Vec::with_capacity(n_shards);
            for (s, w) in self.workers.iter().enumerate() {
                match w.recv(s)? {
                    Reply::SelectedF32(sel) => selections.push(sel),
                    _ => bail!("shard worker {s}: protocol violation (expected f32 selection)"),
                }
            }
            self.engine.merge_into(scored.as_slice(), &selections, lookup);
        } else {
            let mut scored = Vec::with_capacity(n_shards);
            for (s, w) in self.workers.iter().enumerate() {
                match w.recv(s)? {
                    Reply::ScoredF64(b) => scored.push(b),
                    _ => bail!("shard worker {s}: protocol violation (expected f64 scores)"),
                }
            }
            let scored = Arc::new(scored);
            for (s, w) in self.workers.iter().enumerate() {
                w.send(s, Job::SelectF64 { scored: Arc::clone(&scored) })?;
            }
            let mut selections = Vec::with_capacity(n_shards);
            for (s, w) in self.workers.iter().enumerate() {
                match w.recv(s)? {
                    Reply::SelectedF64(sel) => selections.push(sel),
                    _ => bail!("shard worker {s}: protocol violation (expected f64 selection)"),
                }
            }
            self.engine.merge_into(scored.as_slice(), &selections, lookup);
        }

        // round 3: gather owned rows, combine in canonical slot order
        let merged = Arc::new(lookup.clone());
        for (s, w) in self.workers.iter().enumerate() {
            w.send(s, Job::Gather { merged: Arc::clone(&merged), q8 })?;
        }
        let mut stages = Vec::with_capacity(n_shards);
        for (s, w) in self.workers.iter().enumerate() {
            match w.recv(s)? {
                Reply::Gathered(st) => stages.push(st),
                _ => bail!("shard worker {s}: protocol violation (expected gather stage)"),
            }
        }
        self.engine.combine_gather(&merged, &self.plan, &stages, gathered);
        Ok(())
    }
}

impl Drop for ShardedMemory {
    fn drop(&mut self) {
        for w in &mut self.workers {
            w.jobs = None; // disconnect: the worker's recv() loop ends
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::TorusK;
    use crate::util::rng::Rng;

    fn engine() -> BatchLookupEngine {
        BatchLookupEngine::with_threads(TorusK::new([4; 8]).unwrap(), 8, 1)
    }

    fn table(rows: u64, dim: usize, seed: u64) -> ValueTable {
        let mut t = ValueTable::zeros(rows, dim).unwrap();
        t.randomize(seed, 0.5);
        t
    }

    /// Compact per-shard copies of `full` under `plan`.
    fn compact_shards(full: &ValueTable, plan: &ShardPlan, q8: bool) -> Vec<ValueShard> {
        (0..plan.n_shards())
            .map(|s| {
                let r = plan.range(s);
                let rows = (r.end - r.start).max(1);
                let mut t = ValueTable::zeros(rows, full.dim()).unwrap();
                for row in r.clone() {
                    t.row_mut(row - r.start).copy_from_slice(full.row(row));
                }
                let q8 = q8.then(|| QuantizedValueTable::from_table(&t).unwrap());
                ValueShard { base: r.start, table: t, q8 }
            })
            .collect()
    }

    fn random_queries(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n * 8).map(|_| rng.uniform(-6.0, 6.0)).collect()
    }

    #[test]
    fn sharded_f64_matches_fused_bitwise() {
        let eng = engine();
        let full = table(eng.torus.num_locations(), 4, 0xABC);
        let mut rng = Rng::new(7);
        for n_shards in [1usize, 2, 3, 5] {
            let plan = ShardPlan::new(full.rows(), n_shards);
            let mut mem =
                ShardedMemory::new(&eng, plan.clone(), compact_shards(&full, &plan, false))
                    .unwrap();
            for n in [1usize, 3, 17] {
                let q = random_queries(&mut rng, n);
                let mut fused_lk = BatchOutput::default();
                let mut fused_g = vec![0.0f32; n * 4];
                eng.lookup_gather_ragged_into(&q, &full, &mut fused_lk, &mut fused_g);
                let mut lk = BatchOutput::default();
                let mut g = vec![0.0f32; n * 4];
                mem.lookup_gather(&q, false, false, &mut lk, &mut g).unwrap();
                assert_eq!(lk.indices, fused_lk.indices, "{n_shards} shards, batch {n}");
                for (a, b) in lk.weights.iter().zip(&fused_lk.weights) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                for (a, b) in g.iter().zip(&fused_g) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{n_shards} shards, batch {n}");
                }
            }
        }
    }

    #[test]
    fn sharded_f32_and_q8_match_their_fused_paths_bitwise() {
        let eng = engine();
        let full = table(eng.torus.num_locations(), 4, 0xDEF);
        let qfull = QuantizedValueTable::from_table(&full).unwrap();
        let mut rng = Rng::new(11);
        let q = random_queries(&mut rng, 9);
        let plan = ShardPlan::new(full.rows(), 3);
        let mut mem =
            ShardedMemory::new(&eng, plan.clone(), compact_shards(&full, &plan, true)).unwrap();
        assert!(mem.quantized());

        let mut fused_lk = BatchOutput::default();
        let mut fused_g = vec![0.0f32; 9 * 4];
        eng.lookup_gather_ragged_f32_into(&q, &full, &mut fused_lk, &mut fused_g);
        let mut lk = BatchOutput::default();
        let mut g = vec![0.0f32; 9 * 4];
        mem.lookup_gather(&q, true, false, &mut lk, &mut g).unwrap();
        assert_eq!(lk.indices, fused_lk.indices);
        for (a, b) in g.iter().zip(&fused_g) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        eng.lookup_gather_ragged_q8_into(&q, &qfull, &mut fused_lk, &mut fused_g);
        mem.lookup_gather(&q, true, true, &mut lk, &mut g).unwrap();
        assert_eq!(lk.indices, fused_lk.indices);
        for (a, b) in g.iter().zip(&fused_g) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn full_table_views_work_as_shard_sources() {
        // random-init / unsharded-checkpoint serving hands every worker
        // a view of the whole table (base 0) instead of a compact slice
        let eng = engine();
        let full = table(eng.torus.num_locations(), 4, 0x123);
        let plan = ShardPlan::new(full.rows(), 2);
        let views = (0..2)
            .map(|_| {
                let mut t = ValueTable::zeros(full.rows(), 4).unwrap();
                t.load_from(full.data()).unwrap();
                ValueShard { base: 0, table: t, q8: None }
            })
            .collect();
        let mut mem = ShardedMemory::new(&eng, plan, views).unwrap();
        let mut rng = Rng::new(3);
        let q = random_queries(&mut rng, 5);
        let mut fused_lk = BatchOutput::default();
        let mut fused_g = vec![0.0f32; 5 * 4];
        eng.lookup_gather_ragged_into(&q, &full, &mut fused_lk, &mut fused_g);
        let mut lk = BatchOutput::default();
        let mut g = vec![0.0f32; 5 * 4];
        mem.lookup_gather(&q, false, false, &mut lk, &mut g).unwrap();
        for (a, b) in g.iter().zip(&fused_g) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn mismatched_shard_coverage_is_rejected() {
        let eng = engine();
        let rows = eng.torus.num_locations();
        let plan = ShardPlan::new(rows, 2);
        // shard 1's slice is too small to cover its owned range
        let shards = vec![
            ValueShard { base: 0, table: table(rows / 2, 4, 1), q8: None },
            ValueShard { base: rows / 2, table: table(1, 4, 2), q8: None },
        ];
        assert!(ShardedMemory::new(&eng, plan.clone(), shards).is_err());
        // wrong shard count
        let one = vec![ValueShard { base: 0, table: table(rows, 4, 3), q8: None }];
        assert!(ShardedMemory::new(&eng, plan, one).is_err());
    }

    #[test]
    fn dead_worker_surfaces_as_an_error_not_a_hang() {
        let eng = engine();
        let full = table(eng.torus.num_locations(), 4, 0x77);
        let plan = ShardPlan::new(full.rows(), 2);
        let mut mem =
            ShardedMemory::new(&eng, plan.clone(), compact_shards(&full, &plan, false)).unwrap();
        // kill worker 0 by disconnecting its channels
        mem.workers[0].jobs = None;
        if let Some(h) = mem.workers[0].handle.take() {
            h.join().unwrap();
        }
        let mut rng = Rng::new(5);
        let q = random_queries(&mut rng, 2);
        let mut lk = BatchOutput::default();
        let mut g = vec![0.0f32; 2 * 4];
        let err = mem.lookup_gather(&q, false, false, &mut lk, &mut g).unwrap_err();
        assert!(format!("{err:#}").contains("shard worker 0 died"), "{err:#}");
    }
}
