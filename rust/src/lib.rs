//! # LRAM — Lattice-based differentiable Random Access Memory
//!
//! Production-grade reproduction of *"Differentiable Random Access Memory
//! using Lattices"* (Goucher & Troll, 2021): an `E8`-lattice memory layer
//! with O(1) lookups regardless of memory size, embedded in a BERT-style
//! masked language model.
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the coordinator: training loop, serving router,
//!   the O(1) random-access [`memstore`], the full lattice mathematics in
//!   [`lattice`], tokenizer/data substrates, metrics.
//! * **L2/L1 (python, build-time only)** — JAX model + Pallas lattice
//!   kernel, AOT-lowered once into `artifacts/*.hlo.txt` and executed here
//!   through the PJRT CPU client ([`runtime`]). Python never runs on the
//!   request path.

pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod lattice;
pub mod memstore;
pub mod metrics;
pub mod model;
pub mod pkm;
pub mod runtime;
pub mod server;
pub mod splitmode;
pub mod tokenizer;
pub mod util;
