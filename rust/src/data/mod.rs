//! Data pipeline: synthetic corpus -> BPE -> masked batches.
//!
//! Split discipline follows the paper (§3.3): the stream of paragraph
//! indices is partitioned deterministically into train / validation /
//! test, so no validation paragraph is ever trained on.

pub mod mlm;
pub mod synth;

use anyhow::Result;

use crate::tokenizer::{Bpe, BpeTrainer, CLS_ID, SEP_ID};
use crate::util::rng::Rng;
use mlm::{fit_length, mask_tokens, MaskedExample};
use synth::{CorpusSpec, SynthCorpus};

/// A batch in the exact layout the train/eval artifacts expect.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,  // B * S
    pub targets: Vec<i32>, // B * S
    pub weights: Vec<f32>, // B * S
    pub b: usize,
    pub s: usize,
}

/// End-to-end pipeline: owns the corpus, the tokenizer and the split map.
pub struct DataPipeline {
    pub corpus: SynthCorpus,
    pub bpe: Bpe,
    pub seq_len: usize,
    pub batch_size: usize,
    pub mask_prob: f64,
    vocab_size: i32,
    val_offset: u64,
    test_offset: u64,
}

/// Paragraph-index ranges: validation and test take fixed prefixes of the
/// stream, training takes everything after.
const VAL_BASE: u64 = 0;
const TEST_BASE: u64 = 1 << 20;
const TRAIN_BASE: u64 = 1 << 21;

impl DataPipeline {
    /// Build the pipeline: generate a BPE training sample from the corpus
    /// and train the tokenizer to `vocab_size`.
    pub fn new(
        spec: CorpusSpec,
        vocab_size: usize,
        seq_len: usize,
        batch_size: usize,
        mask_prob: f64,
    ) -> Result<Self> {
        let corpus = SynthCorpus::new(spec);
        let mut trainer = BpeTrainer::new();
        // BPE sample: a deterministic slice of the *training* stream
        for i in 0..400 {
            trainer.add_text(&corpus.paragraph(TRAIN_BASE + i));
        }
        let bpe = trainer.train(vocab_size);
        Ok(DataPipeline {
            corpus,
            bpe,
            seq_len,
            batch_size,
            mask_prob,
            vocab_size: vocab_size as i32,
            val_offset: VAL_BASE,
            test_offset: TEST_BASE,
        })
    }

    /// Encode one paragraph into a fixed-length `[CLS] ... [SEP]` row.
    pub fn encode_paragraph(&self, index: u64) -> Vec<i32> {
        let text = self.corpus.paragraph(index);
        let mut ids = vec![CLS_ID];
        ids.extend(self.bpe.encode(&text));
        ids.truncate(self.seq_len - 1);
        ids.push(SEP_ID);
        fit_length(ids, self.seq_len)
    }

    fn build_batch(&self, base: u64, batch_idx: u64, seed_salt: u64) -> Batch {
        let b = self.batch_size;
        let s = self.seq_len;
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        let mut weights = Vec::with_capacity(b * s);
        for row in 0..b {
            let pidx = base + batch_idx * b as u64 + row as u64;
            let ids = self.encode_paragraph(pidx);
            let mut rng = Rng::new(seed_salt ^ pidx.wrapping_mul(0x2545F4914F6CDD1D));
            let MaskedExample { tokens: t, targets: g, weights: w } =
                mask_tokens(&ids, self.vocab_size, self.mask_prob, &mut rng);
            tokens.extend(t);
            targets.extend(g);
            weights.extend(w);
        }
        Batch { tokens, targets, weights, b, s }
    }

    /// Training batch for a global step (fresh paragraphs every step —
    /// the underfitting regime of the paper).
    pub fn train_batch(&self, step: u64) -> Batch {
        self.build_batch(TRAIN_BASE, step, 0xA11CE)
    }

    /// Deterministic validation batch (masking fixed by the batch index).
    pub fn val_batch(&self, batch_idx: u64) -> Batch {
        self.build_batch(self.val_offset, batch_idx, 0x5A17)
    }

    /// Deterministic test batch.
    pub fn test_batch(&self, batch_idx: u64) -> Batch {
        self.build_batch(self.test_offset, batch_idx, 0x7E57)
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipeline() -> DataPipeline {
        DataPipeline::new(CorpusSpec::default(), 512, 48, 4, 0.15).unwrap()
    }

    #[test]
    fn batch_shapes_and_ranges() {
        let p = pipeline();
        let b = p.train_batch(0);
        assert_eq!(b.tokens.len(), 4 * 48);
        assert_eq!(b.targets.len(), 4 * 48);
        assert_eq!(b.weights.len(), 4 * 48);
        for &t in &b.tokens {
            assert!((0..512).contains(&t), "{t}");
        }
    }

    #[test]
    fn train_batches_differ_by_step() {
        let p = pipeline();
        assert_ne!(p.train_batch(0).tokens, p.train_batch(1).tokens);
    }

    #[test]
    fn val_batches_are_deterministic() {
        let p = pipeline();
        let a = p.val_batch(3);
        let b = p.val_batch(3);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn splits_do_not_overlap() {
        // train stream starts far above the val/test prefixes
        let p = pipeline();
        // 1M steps x batch 4 stays below the next split boundary
        assert!(TRAIN_BASE > TEST_BASE && TEST_BASE > VAL_BASE);
        let _ = p;
    }

    #[test]
    fn rows_start_with_cls() {
        let p = pipeline();
        let b = p.val_batch(0);
        for row in 0..b.b {
            assert_eq!(b.targets[row * b.s], CLS_ID);
        }
    }

    #[test]
    fn some_positions_are_masked() {
        let p = pipeline();
        let b = p.train_batch(5);
        let total: f32 = b.weights.iter().sum();
        assert!(total > 0.0);
    }
}
