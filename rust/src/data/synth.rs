//! Deterministic synthetic corpus (substitute for the paper's 60 GB
//! Wikipedia + BookCorpus + OpenWebText; see DESIGN.md "Substitutions").
//!
//! The generator produces text whose statistics exercise exactly the
//! capacity axis the paper studies:
//!
//! * a Zipfian pseudo-word vocabulary (realistic BPE merge statistics);
//! * latent *topics* — each paragraph samples one topic whose word
//!   distribution is a permuted Zipf, so predicting a masked word
//!   requires inferring the topic from context (moderate capacity);
//! * a long tail of *entity facts* — `entity_i has attribute_j` pairs
//!   fixed once by the seed.  With many more facts than dense-model
//!   capacity, recalling them rewards a large sparse memory: the
//!   mechanism behind the paper's LRAM > baseline result.
//!
//! Every paragraph is a pure function of `(seed, index)`, so the corpus
//! can be streamed without materialisation: 227.4M paragraphs (the
//! paper's count) fit in zero bytes.

use crate::util::rng::Rng;

const SYLLABLES: [&str; 24] = [
    "ka", "to", "ri", "mun", "sel", "va", "pro", "den", "lor", "bi", "shu", "ter",
    "gal", "nor", "pli", "xan", "dro", "mi", "fen", "ur", "sta", "quo", "zem", "lat",
];

const FUNCTION_WORDS: [&str; 12] = [
    "the", "a", "of", "and", "in", "to", "was", "is", "with", "for", "on", "as",
];

/// Corpus parameters.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub seed: u64,
    /// distinct content words
    pub n_words: usize,
    /// latent topics
    pub n_topics: usize,
    /// entity-fact pairs (the memorisation tail)
    pub n_entities: usize,
    /// attributes entities can have
    pub n_attributes: usize,
    pub sentences_per_paragraph: (u64, u64),
    pub words_per_sentence: (u64, u64),
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            seed: 1234,
            n_words: 2000,
            n_topics: 32,
            n_entities: 20_000,
            n_attributes: 512,
            sentences_per_paragraph: (3, 7),
            words_per_sentence: (6, 14),
        }
    }
}

/// The deterministic corpus generator.
pub struct SynthCorpus {
    spec: CorpusSpec,
    words: Vec<String>,
    /// per-topic word permutation bases (word w in topic t has Zipf rank
    /// (perm_base[t] * w + shift) mod n_words)
    topic_perm: Vec<(usize, usize)>,
    /// entity -> attribute fact table, fixed by the seed
    facts: Vec<u32>,
}

impl SynthCorpus {
    pub fn new(spec: CorpusSpec) -> Self {
        let mut rng = Rng::new(spec.seed ^ 0x5EED_C0DE);
        // pseudo-words from syllables; dedup by construction index
        let mut words = Vec::with_capacity(spec.n_words);
        let mut seen = std::collections::HashSet::new();
        while words.len() < spec.n_words {
            let n_syl = 2 + rng.below(3) as usize;
            let mut w = String::new();
            for _ in 0..n_syl {
                w.push_str(SYLLABLES[rng.below(SYLLABLES.len() as u64) as usize]);
            }
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        // coprime multiplicative permutations per topic
        let n = spec.n_words;
        let mut topic_perm = Vec::with_capacity(spec.n_topics);
        for _ in 0..spec.n_topics {
            let mut a = 1 + 2 * rng.below((n / 2) as u64) as usize; // odd -> try for coprime
            while gcd(a, n) != 1 {
                a = (a + 2) % n.max(3);
                if a < 3 {
                    a = 3;
                }
            }
            topic_perm.push((a, rng.below(n as u64) as usize));
        }
        let facts = (0..spec.n_entities)
            .map(|_| rng.below(spec.n_attributes as u64) as u32)
            .collect();
        SynthCorpus { spec, words, topic_perm, facts }
    }

    pub fn spec(&self) -> &CorpusSpec {
        &self.spec
    }

    /// The attribute associated with an entity (ground truth for probes).
    pub fn fact(&self, entity: usize) -> u32 {
        self.facts[entity]
    }

    /// Zipf sample over [0, n) — rank r with weight 1/(r+1).
    fn zipf(rng: &mut Rng, n: usize) -> usize {
        // inverse-CDF approximation for Zipf(1): H(n) ~ ln(n) + gamma
        let h = (n as f64).ln() + 0.5772;
        let u = rng.f64() * h;
        let r = (u.exp() - 1.0).clamp(0.0, (n - 1) as f64);
        r as usize
    }

    fn topic_word(&self, rng: &mut Rng, topic: usize) -> &str {
        let rank = Self::zipf(rng, self.spec.n_words);
        let (a, b) = self.topic_perm[topic];
        let idx = (a.wrapping_mul(rank) + b) % self.spec.n_words;
        &self.words[idx]
    }

    /// Generate paragraph `index` (pure function of seed + index).
    pub fn paragraph(&self, index: u64) -> String {
        let mut rng = Rng::new(self.spec.seed.wrapping_mul(0x9E37_79B9).wrapping_add(index));
        let topic = rng.below(self.spec.n_topics as u64) as usize;
        let (slo, shi) = self.spec.sentences_per_paragraph;
        let n_sent = rng.below(shi - slo + 1) + slo;
        let mut out = String::new();
        for s in 0..n_sent {
            if s > 0 {
                out.push(' ');
            }
            // ~25% of sentences are entity facts (the memorisation signal)
            if rng.bool(0.25) {
                let e = rng.below(self.spec.n_entities as u64) as usize;
                out.push_str(&format!("entity{e} has trait{} .", self.facts[e]));
                continue;
            }
            let (wlo, whi) = self.spec.words_per_sentence;
            let n_words = rng.below(whi - wlo + 1) + wlo;
            for w in 0..n_words {
                if w > 0 {
                    out.push(' ');
                }
                if rng.bool(0.35) {
                    out.push_str(FUNCTION_WORDS[rng.below(12) as usize]);
                } else {
                    out.push_str(self.topic_word(&mut rng, topic));
                }
            }
            out.push_str(" .");
        }
        out
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paragraphs_are_deterministic() {
        let a = SynthCorpus::new(CorpusSpec::default());
        let b = SynthCorpus::new(CorpusSpec::default());
        for i in [0u64, 5, 123_456_789] {
            assert_eq!(a.paragraph(i), b.paragraph(i));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = SynthCorpus::new(CorpusSpec::default());
        let b = SynthCorpus::new(CorpusSpec { seed: 999, ..CorpusSpec::default() });
        assert_ne!(a.paragraph(0), b.paragraph(0));
    }

    #[test]
    fn facts_are_consistent_across_paragraphs() {
        let c = SynthCorpus::new(CorpusSpec::default());
        // scan many paragraphs; every "entityX has traitY" must match the
        // fact table
        let mut found = 0;
        for i in 0..500 {
            let p = c.paragraph(i);
            for sent in p.split(" .") {
                let sent = sent.trim();
                if let Some(rest) = sent.strip_prefix("entity") {
                    if let Some((e, tr)) = rest.split_once(" has trait") {
                        let e: usize = e.trim().parse().unwrap();
                        let t: u32 = tr.trim().parse().unwrap();
                        assert_eq!(c.fact(e), t, "paragraph {i}: {sent}");
                        found += 1;
                    }
                }
            }
        }
        assert!(found > 100, "only {found} facts in 500 paragraphs");
    }

    #[test]
    fn words_have_zipfian_spread() {
        let c = SynthCorpus::new(CorpusSpec::default());
        let mut counts: std::collections::HashMap<String, u32> = Default::default();
        for i in 0..300 {
            for w in c.paragraph(i).split_whitespace() {
                *counts.entry(w.to_string()).or_insert(0) += 1;
            }
        }
        let mut freqs: Vec<u32> = counts.values().copied().collect();
        freqs.sort_by(|a, b| b.cmp(a));
        // head much heavier than tail
        assert!(freqs[0] > 20 * freqs[freqs.len() / 2].max(1));
        assert!(counts.len() > 500, "vocabulary too small: {}", counts.len());
    }
}
