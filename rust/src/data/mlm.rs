//! BERT-style masked-language-model corruption (paper §3: MLM objective).
//!
//! 15% of content positions are selected; of those 80% become `[MASK]`,
//! 10% a random token, 10% stay unchanged (the standard 80/10/10 recipe).
//! `weights` marks the selected positions for the loss.

use crate::tokenizer::{MASK_ID, PAD_ID};
use crate::util::rng::Rng;

/// One masked training example.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskedExample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub weights: Vec<f32>,
}

/// Number of reserved special ids that must never be predicted targets or
/// random replacements.
const N_SPECIALS: i32 = 5;

/// Apply MLM corruption to a token sequence.
pub fn mask_tokens(
    ids: &[i32],
    vocab_size: i32,
    mask_prob: f64,
    rng: &mut Rng,
) -> MaskedExample {
    let mut tokens = ids.to_vec();
    let targets = ids.to_vec();
    let mut weights = vec![0.0f32; ids.len()];
    for i in 0..ids.len() {
        if ids[i] < N_SPECIALS {
            continue; // never mask specials (incl. padding)
        }
        if rng.f64() >= mask_prob {
            continue;
        }
        weights[i] = 1.0;
        let r = rng.f64();
        if r < 0.8 {
            tokens[i] = MASK_ID;
        } else if r < 0.9 {
            tokens[i] = N_SPECIALS + rng.below((vocab_size - N_SPECIALS) as u64) as i32;
        } // else: keep original
    }
    MaskedExample { tokens, targets, weights }
}

/// Pad/truncate a token sequence to exactly `seq_len`.
pub fn fit_length(mut ids: Vec<i32>, seq_len: usize) -> Vec<i32> {
    ids.truncate(seq_len);
    while ids.len() < seq_len {
        ids.push(PAD_ID);
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn masking_statistics() {
        let mut rng = Rng::new(1);
        let ids: Vec<i32> = (0..20000).map(|i| 5 + (i % 100)).collect();
        let ex = mask_tokens(&ids, 4096, 0.15, &mut rng);
        let n_sel = ex.weights.iter().filter(|&&w| w > 0.0).count();
        let frac = n_sel as f64 / ids.len() as f64;
        assert!((frac - 0.15).abs() < 0.01, "selected {frac}");
        // among selected: ~80% MASK
        let n_mask = ex
            .tokens
            .iter()
            .zip(&ex.weights)
            .filter(|(&t, &w)| w > 0.0 && t == MASK_ID)
            .count();
        let mask_frac = n_mask as f64 / n_sel as f64;
        assert!((mask_frac - 0.8).abs() < 0.03, "mask frac {mask_frac}");
    }

    #[test]
    fn targets_always_keep_originals() {
        forall(50, |rng| {
            let ids: Vec<i32> = (0..64).map(|_| rng.range(5, 500) as i32).collect();
            let ex = mask_tokens(&ids, 512, 0.3, rng);
            assert_eq!(ex.targets, ids);
            // unselected positions are unchanged
            for i in 0..ids.len() {
                if ex.weights[i] == 0.0 {
                    assert_eq!(ex.tokens[i], ids[i]);
                }
            }
        });
    }

    #[test]
    fn specials_never_masked() {
        let mut rng = Rng::new(3);
        let ids = vec![0, 1, 2, 3, 4, 0, 0, 0];
        let ex = mask_tokens(&ids, 512, 0.99, &mut rng);
        assert!(ex.weights.iter().all(|&w| w == 0.0));
        assert_eq!(ex.tokens, ids);
    }

    #[test]
    fn random_replacements_are_valid_tokens() {
        let mut rng = Rng::new(7);
        let ids: Vec<i32> = vec![100; 5000];
        let ex = mask_tokens(&ids, 512, 0.5, &mut rng);
        for &t in &ex.tokens {
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn fit_length_pads_and_truncates() {
        assert_eq!(fit_length(vec![9, 9, 9], 5), vec![9, 9, 9, 0, 0]);
        assert_eq!(fit_length(vec![1, 2, 3, 4], 2), vec![1, 2]);
    }
}
