//! Product-Key Memory baseline (Lample et al. 2019) — the O(sqrt(N))
//! comparator the paper evaluates against.
//!
//! The training-path PKM lives in the L2 JAX model; this module provides
//! the rust-side scoring used by the split-mode Figure-3/Table-4 benches
//! (so LRAM and PKM are timed under identical conditions) plus the
//! analytic cost model of Table 3.

use crate::util::rng::Rng;

/// A product-key scorer: two codebooks of `n_keys` half-keys of dim
/// `dk/2`; the induced key set has `N = n_keys^2` entries.
pub struct PkmScorer {
    pub n_keys: usize,
    pub dk: usize,
    pub k_top: usize,
    keys1: Vec<f32>, // n_keys x dk/2
    keys2: Vec<f32>,
}

impl PkmScorer {
    pub fn new(n_keys: usize, dk: usize, k_top: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let half = dk / 2;
        let scale = 1.0 / (half as f64).sqrt();
        let mut mk = |n: usize| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        PkmScorer { n_keys, dk, k_top, keys1: mk(n_keys * half), keys2: mk(n_keys * half) }
    }

    pub fn n_locations(&self) -> u64 {
        (self.n_keys * self.n_keys) as u64
    }

    /// Score one query of dim `dk`: returns `k_top` (index, softmax weight)
    /// pairs over the product key set.  Cost: O(n_keys * dk) = O(sqrt(N)).
    pub fn score(&self, q: &[f32]) -> Vec<(u64, f32)> {
        debug_assert_eq!(q.len(), self.dk);
        let half = self.dk / 2;
        let (q1, q2) = q.split_at(half);
        let s1 = self.half_scores(q1, &self.keys1);
        let s2 = self.half_scores(q2, &self.keys2);
        let t1 = top_k(&s1, self.k_top);
        let t2 = top_k(&s2, self.k_top);
        // Cartesian product of the two top-k lists -> global top-k
        // (partial quickselect over the k^2 merge, shared tie rule with
        // the lattice top-k: score desc, index asc)
        let mut cand: Vec<(f64, u64)> = Vec::with_capacity(self.k_top * self.k_top);
        for &(i1, v1) in &t1 {
            for &(i2, v2) in &t2 {
                cand.push(((v1 + v2) as f64, (i1 * self.n_keys + i2) as u64));
            }
        }
        let kept = crate::util::topk::partial_top_k_desc(&mut cand, self.k_top);
        let cand: Vec<(f32, u64)> = kept.iter().map(|&(s, i)| (s as f32, i)).collect();
        // softmax over the kept scores
        let mx = cand.iter().map(|c| c.0).fold(f32::MIN, f32::max);
        let mut z = 0.0f32;
        for c in &cand {
            z += (c.0 - mx).exp();
        }
        cand.into_iter().map(|(s, i)| (i, (s - mx).exp() / z)).collect()
    }

    fn half_scores(&self, q: &[f32], keys: &[f32]) -> Vec<f32> {
        let half = self.dk / 2;
        let mut out = Vec::with_capacity(self.n_keys);
        for r in 0..self.n_keys {
            let row = &keys[r * half..(r + 1) * half];
            out.push(row.iter().zip(q).map(|(a, b)| a * b).sum());
        }
        out
    }
}

fn top_k(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    crate::util::topk::top_k_indices_f32(scores, k)
        .into_iter()
        .map(|i| (i, scores[i]))
        .collect()
}

/// Table 3 cost model: approximate multiply counts per query vector.
pub mod cost {
    /// Dense 2-layer (w -> rw -> w): 2 r w^2.
    pub fn dense_ops(w: u64, r: u64) -> u64 {
        2 * r * w * w
    }

    /// PKM: 2 w sqrt(N) scoring + w^2 query net (per Lample et al.).
    pub fn pkm_ops(w: u64, n: u64) -> u64 {
        let sqrt_n = (n as f64).sqrt().round() as u64;
        2 * w * sqrt_n + w * w
    }

    /// LRAM: (5/4) r w^2 (the two dense layers; the lattice lookup itself
    /// is O(1) in N with a fixed 232-candidate constant).
    pub fn lram_ops(w: u64, r: u64) -> u64 {
        5 * r * w * w / 4
    }

    /// Parameter counts (Table 3 "Parameters" column).
    pub fn dense_params(w: u64, r: u64) -> u64 {
        2 * r * w * w
    }

    pub fn pkm_params(w: u64, n: u64, m: u64) -> u64 {
        let sqrt_n = (n as f64).sqrt().round() as u64;
        m * n + 2 * w * sqrt_n + w * w
    }

    pub fn lram_params(w: u64, r: u64, n: u64, m: u64) -> u64 {
        m * n + 5 * r * w * w / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_are_softmax_normalised() {
        let s = PkmScorer::new(32, 16, 8, 1);
        let q: Vec<f32> = (0..16).map(|i| (i as f32) / 8.0 - 1.0).collect();
        let hits = s.score(&q);
        assert_eq!(hits.len(), 8);
        let total: f32 = hits.iter().map(|h| h.1).sum();
        assert!((total - 1.0).abs() < 1e-5);
        for h in &hits {
            assert!(h.0 < s.n_locations());
        }
    }

    #[test]
    fn best_product_key_is_found() {
        // brute-force the full N = n_keys^2 scores and compare the argmax
        let s = PkmScorer::new(16, 8, 4, 2);
        let q: Vec<f32> = vec![0.3, -1.0, 0.7, 0.2, -0.4, 1.1, 0.0, 0.9];
        let hits = s.score(&q);
        let mut best = (0u64, f32::MIN);
        for i1 in 0..16usize {
            for i2 in 0..16usize {
                let mut v = 0.0f32;
                for d in 0..4 {
                    v += s.keys1[i1 * 4 + d] * q[d];
                    v += s.keys2[i2 * 4 + d] * q[4 + d];
                }
                if v > best.1 {
                    best = ((i1 * 16 + i2) as u64, v);
                }
            }
        }
        assert_eq!(hits[0].0, best.0);
    }

    #[test]
    fn table3_asymptotics() {
        use cost::*;
        // doubling w quadruples dense cost, but only doubles the PKM
        // scoring term; LRAM ops are independent of N entirely
        assert_eq!(dense_ops(1024, 4), 4 * dense_ops(512, 4));
        assert_eq!(lram_ops(512, 4), lram_ops(512, 4));
        let grow = pkm_ops(512, 1 << 24) - pkm_ops(512, 1 << 20);
        assert!(grow > 0);
        // paper: LRAM ops = (5/8) of dense ops at r = 4
        assert_eq!(8 * lram_ops(512, 4), 5 * dense_ops(512, 4));
    }
}
