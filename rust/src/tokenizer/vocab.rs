//! Token vocabulary with reserved special ids.

use std::collections::HashMap;

pub const PAD_ID: i32 = 0;
pub const UNK_ID: i32 = 1;
pub const MASK_ID: i32 = 2;
pub const CLS_ID: i32 = 3;
pub const SEP_ID: i32 = 4;
#[allow(dead_code)]
pub const N_SPECIALS: i32 = 5;

pub const SPECIALS: [&str; 5] = ["[PAD]", "[UNK]", "[MASK]", "[CLS]", "[SEP]"];

/// Bidirectional token <-> id map.
#[derive(Debug, Clone, Default)]
pub struct Vocab {
    pub tokens: Vec<String>,
    pub ids: HashMap<String, i32>,
}

impl Vocab {
    pub fn with_specials() -> Self {
        let mut v = Vocab::default();
        for s in SPECIALS {
            v.push(s.to_string());
        }
        v
    }

    pub fn push(&mut self, token: String) -> i32 {
        if let Some(&id) = self.ids.get(&token) {
            return id;
        }
        let id = self.tokens.len() as i32;
        self.ids.insert(token.clone(), id);
        self.tokens.push(token);
        id
    }

    pub fn id(&self, token: &str) -> i32 {
        self.ids.get(token).copied().unwrap_or(UNK_ID)
    }

    pub fn token(&self, id: i32) -> &str {
        self.tokens
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("[UNK]")
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specials_have_fixed_ids() {
        let v = Vocab::with_specials();
        assert_eq!(v.id("[PAD]"), PAD_ID);
        assert_eq!(v.id("[MASK]"), MASK_ID);
        assert_eq!(v.token(SEP_ID), "[SEP]");
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = Vocab::with_specials();
        assert_eq!(v.id("zzz"), UNK_ID);
    }

    #[test]
    fn push_is_idempotent() {
        let mut v = Vocab::with_specials();
        let a = v.push("ab".into());
        let b = v.push("ab".into());
        assert_eq!(a, b);
        assert_eq!(v.len(), 6);
    }
}
