//! Byte-pair-encoding tokenizer (from scratch — the paper preprocesses
//! with the XLM pipeline: lowercasing + BPE with a 30k dictionary; we
//! reproduce the same structure at a scaled-down vocabulary).

mod bpe;
mod vocab;

pub use bpe::{Bpe, BpeTrainer};
pub use vocab::{Vocab, CLS_ID, MASK_ID, PAD_ID, SEP_ID, UNK_ID};
