//! Byte-pair encoding: training (greedy most-frequent-pair merges over a
//! word-frequency table) and encoding (rank-ordered merge application),
//! XLM-style: input is lowercased, whitespace-pretokenized, and every
//! word carries a `</w>` end-of-word marker so merges never cross word
//! boundaries.

use std::collections::HashMap;

use super::vocab::{Vocab, UNK_ID};

/// A trained BPE model: merge ranks + vocabulary.
#[derive(Debug, Clone)]
pub struct Bpe {
    pub vocab: Vocab,
    /// (left, right) -> rank; lower rank merges first.
    merges: HashMap<(String, String), usize>,
}

/// Trainer: accumulates word counts, then learns merges.
#[derive(Debug, Default)]
pub struct BpeTrainer {
    word_counts: HashMap<String, u64>,
}

pub const EOW: &str = "</w>";

/// XLM-style pretokenization: lowercase, strip non-alphanumeric except
/// basic punctuation (kept as standalone words), split on whitespace.
pub fn pretokenize(text: &str) -> Vec<String> {
    let mut words = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        let c = c.to_ascii_lowercase();
        if c.is_alphanumeric() {
            cur.push(c);
        } else {
            if !cur.is_empty() {
                words.push(std::mem::take(&mut cur));
            }
            if !c.is_whitespace() && c.is_ascii_punctuation() {
                words.push(c.to_string());
            }
        }
    }
    if !cur.is_empty() {
        words.push(cur);
    }
    words
}

impl BpeTrainer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed a document into the frequency table.
    pub fn add_text(&mut self, text: &str) {
        for w in pretokenize(text) {
            *self.word_counts.entry(w).or_insert(0) += 1;
        }
    }

    /// Learn merges until the vocabulary reaches `vocab_size`.
    ///
    /// Uses incremental pair counting: a merge only revisits the words
    /// that actually contain the merged pair, so training a few-thousand
    /// token vocabulary over tens of thousands of distinct words stays
    /// sub-second.
    pub fn train(&self, vocab_size: usize) -> Bpe {
        // represent each distinct word as a symbol sequence ending in </w>
        let mut words: Vec<(Vec<String>, u64)> = self
            .word_counts
            .iter()
            .map(|(w, &c)| {
                let mut syms: Vec<String> = w.chars().map(|ch| ch.to_string()).collect();
                if let Some(last) = syms.last_mut() {
                    last.push_str(EOW);
                } else {
                    syms.push(EOW.to_string());
                }
                (syms, c)
            })
            .collect();
        words.sort(); // determinism independent of hash order

        let mut vocab = Vocab::with_specials();
        // base symbols
        let mut base: Vec<String> = words
            .iter()
            .flat_map(|(syms, _)| syms.iter().cloned())
            .collect();
        base.sort();
        base.dedup();
        for s in base {
            vocab.push(s);
        }

        // pair -> (count, set of word indices currently containing it)
        type Pair = (String, String);
        let mut pair_counts: HashMap<Pair, u64> = HashMap::new();
        let mut pair_words: HashMap<Pair, std::collections::BTreeSet<usize>> = HashMap::new();
        for (wi, (syms, c)) in words.iter().enumerate() {
            for win in syms.windows(2) {
                let p = (win[0].clone(), win[1].clone());
                *pair_counts.entry(p.clone()).or_insert(0) += c;
                pair_words.entry(p).or_default().insert(wi);
            }
        }

        let mut merges: HashMap<Pair, usize> = HashMap::new();
        while vocab.len() < vocab_size {
            // deterministic argmax: by count, then lexicographically
            // smallest pair (ties are rare but must not depend on hash
            // iteration order)
            let best = pair_counts
                .iter()
                .filter(|(_, &c)| c >= 2)
                .max_by(|a, b| a.1.cmp(b.1).then_with(|| b.0.cmp(a.0)))
                .map(|(p, &c)| (p.clone(), c));
            let Some(((l, r), _)) = best else { break };
            let merged = format!("{l}{r}");
            merges.insert((l.clone(), r.clone()), merges.len());
            vocab.push(merged.clone());
            // revisit only the words containing this pair
            let touched = pair_words.remove(&(l.clone(), r.clone())).unwrap_or_default();
            pair_counts.remove(&(l.clone(), r.clone()));
            for wi in touched {
                let (syms, c) = &mut words[wi];
                let c = *c;
                // retract this word's old pair contributions
                for win in syms.windows(2) {
                    let p = (win[0].clone(), win[1].clone());
                    if let Some(cnt) = pair_counts.get_mut(&p) {
                        *cnt = cnt.saturating_sub(c);
                        if *cnt == 0 {
                            pair_counts.remove(&p);
                        }
                    }
                    if let Some(set) = pair_words.get_mut(&p) {
                        set.remove(&wi);
                    }
                }
                // apply the merge
                let mut i = 0;
                while i + 1 < syms.len() {
                    if syms[i] == l && syms[i + 1] == r {
                        syms[i] = merged.clone();
                        syms.remove(i + 1);
                    } else {
                        i += 1;
                    }
                }
                // add the new contributions back
                for win in syms.windows(2) {
                    let p = (win[0].clone(), win[1].clone());
                    *pair_counts.entry(p.clone()).or_insert(0) += c;
                    pair_words.entry(p).or_default().insert(wi);
                }
            }
        }
        Bpe { vocab, merges }
    }
}

impl Bpe {
    /// Encode text to token ids (no specials added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for w in pretokenize(text) {
            self.encode_word(&w, &mut out);
        }
        out
    }

    fn encode_word(&self, word: &str, out: &mut Vec<i32>) {
        let mut syms: Vec<String> = word.chars().map(|c| c.to_string()).collect();
        if let Some(last) = syms.last_mut() {
            last.push_str(EOW);
        } else {
            return;
        }
        // iteratively apply the lowest-rank merge present
        loop {
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for i in 0..syms.len().saturating_sub(1) {
                if let Some(&rank) =
                    self.merges.get(&(syms[i].clone(), syms[i + 1].clone()))
                {
                    if best.map(|(r, _)| rank < r).unwrap_or(true) {
                        best = Some((rank, i));
                    }
                }
            }
            let Some((_, i)) = best else { break };
            let merged = format!("{}{}", syms[i], syms[i + 1]);
            syms[i] = merged;
            syms.remove(i + 1);
        }
        for s in &syms {
            let id = self.vocab.id(s);
            out.push(if id >= 0 { id } else { UNK_ID });
        }
    }

    /// Decode ids back to a string (lossy w.r.t. whitespace).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            let tok = self.vocab.token(id);
            if tok.starts_with('[') && tok.ends_with(']') {
                continue; // specials
            }
            if let Some(stripped) = tok.strip_suffix(EOW) {
                s.push_str(stripped);
                s.push(' ');
            } else {
                s.push_str(tok);
            }
        }
        s.trim_end().to_string()
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Content fingerprint of the trained tokenizer (FNV-1a 64 over the
    /// canonical serialisation, hex).  A checkpoint stores this so a
    /// server can refuse to pair trained weights with a tokenizer whose
    /// id↔token mapping has drifted — same vocabulary *size* is not
    /// enough, the merges and ordering must match too.
    pub fn fingerprint(&self) -> String {
        format!("{:016x}", crate::util::fnv1a64(self.to_text().as_bytes()))
    }

    /// Serialize: one token per line, then merges.
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        s.push_str("#version lram-bpe-1\n");
        s.push_str(&format!("#tokens {}\n", self.vocab.len()));
        for t in &self.vocab.tokens {
            s.push_str(t);
            s.push('\n');
        }
        let mut ordered: Vec<(&(String, String), &usize)> = self.merges.iter().collect();
        ordered.sort_by_key(|(_, &r)| r);
        s.push_str(&format!("#merges {}\n", ordered.len()));
        for ((l, r), _) in ordered {
            s.push_str(&format!("{l} {r}\n"));
        }
        s
    }

    pub fn from_text(text: &str) -> anyhow::Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        anyhow::ensure!(header == "#version lram-bpe-1", "bad BPE file header");
        let ntok: usize = lines
            .next()
            .and_then(|l| l.strip_prefix("#tokens "))
            .and_then(|n| n.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad #tokens line"))?;
        let mut vocab = Vocab::default();
        for _ in 0..ntok {
            let t = lines.next().ok_or_else(|| anyhow::anyhow!("truncated tokens"))?;
            vocab.push(t.to_string());
        }
        let nmerge: usize = lines
            .next()
            .and_then(|l| l.strip_prefix("#merges "))
            .and_then(|n| n.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad #merges line"))?;
        let mut merges = HashMap::new();
        for rank in 0..nmerge {
            let line = lines.next().ok_or_else(|| anyhow::anyhow!("truncated merges"))?;
            let (l, r) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("bad merge line '{line}'"))?;
            merges.insert((l.to_string(), r.to_string()), rank);
        }
        Ok(Bpe { vocab, merges })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn train_on(texts: &[&str], vocab: usize) -> Bpe {
        let mut tr = BpeTrainer::new();
        for t in texts {
            tr.add_text(t);
        }
        tr.train(vocab)
    }

    #[test]
    fn pretokenize_lowercases_and_splits() {
        assert_eq!(
            pretokenize("Hello, World! x2"),
            vec!["hello", ",", "world", "!", "x2"]
        );
    }

    #[test]
    fn frequent_words_become_single_tokens() {
        let texts = vec!["the cat sat on the mat "; 50];
        let bpe = train_on(&texts, 300);
        let ids = bpe.encode("the cat");
        // "the" appears often enough to merge into one token
        assert!(ids.len() <= 3, "{ids:?}");
        assert_eq!(bpe.decode(&ids), "the cat");
    }

    #[test]
    fn roundtrip_text() {
        let corpus = ["alpha beta gamma delta", "beta gamma alpha", "delta delta beta"];
        let bpe = train_on(&corpus, 100);
        for t in corpus {
            let ids = bpe.encode(t);
            assert_eq!(bpe.decode(&ids), t);
        }
    }

    #[test]
    fn unseen_chars_do_not_panic() {
        let bpe = train_on(&["abc abc abc"], 50);
        let ids = bpe.encode("xyz");
        assert!(!ids.is_empty());
        // all ids valid
        for &i in &ids {
            assert!((i as usize) < bpe.vocab_size() || i == UNK_ID);
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let bpe = train_on(&["the quick brown fox ", "the slow brown dog "], 120);
        let text = bpe.to_text();
        let back = Bpe::from_text(&text).unwrap();
        assert_eq!(back.vocab_size(), bpe.vocab_size());
        assert_eq!(back.encode("the quick dog"), bpe.encode("the quick dog"));
    }

    #[test]
    fn determinism() {
        let a = train_on(&["x y z w x y z", "w w x y"], 60).to_text();
        let b = train_on(&["x y z w x y z", "w w x y"], 60).to_text();
        assert_eq!(a, b);
    }
}
