//! Tiny property-testing harness (the `proptest` crate is unavailable in
//! the offline build).  `forall` runs a closure over `n` random cases and
//! reports the seed of the first failing case so it can be replayed.
//!
//! ```no_run
//! use lram::util::check::forall;
//! forall(200, |rng| {
//!     let x = rng.uniform(-10.0, 10.0);
//!     assert!(x.abs() <= 10.0);
//! });
//! ```

use super::rng::Rng;

/// Run `f` on `n` independently-seeded RNGs; panic with the failing seed.
pub fn forall(n: u32, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    let base = std::env::var("LRAM_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..n as u64 {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed on case {case} (replay with LRAM_CHECK_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff| = {}, tol = {tol})",
            (x - y).abs()
        );
    }
}

/// Compare an analytic gradient against a (central-finite-difference)
/// numeric one, coordinate by coordinate: each must satisfy
/// `|a - n| <= atol + rtol * max(|a|, |n|)`.  Reports the worst
/// offending coordinate with both values, so a failed check names the
/// exact derivative that is wrong.  `rtol = 1e-3` is the repo contract
/// for f32-computed analytic gradients checked against an f64 forward
/// (`rust/tests/grad_check.rs`).
#[track_caller]
pub fn assert_grad_close(name: &str, analytic: &[f64], numeric: &[f64], rtol: f64, atol: f64) {
    assert_eq!(
        analytic.len(),
        numeric.len(),
        "{name}: {} analytic vs {} numeric coordinates",
        analytic.len(),
        numeric.len()
    );
    assert!(!analytic.is_empty(), "{name}: nothing to check");
    let mut worst = 0usize;
    let mut worst_ratio = 0.0f64;
    for (i, (&a, &n)) in analytic.iter().zip(numeric).enumerate() {
        assert!(a.is_finite() && n.is_finite(), "{name}[{i}]: {a} vs {n}");
        let tol = atol + rtol * a.abs().max(n.abs());
        let ratio = (a - n).abs() / tol;
        if ratio > worst_ratio {
            worst_ratio = ratio;
            worst = i;
        }
    }
    assert!(
        worst_ratio <= 1.0,
        "{name}: gradient mismatch at [{worst}]: analytic {} vs numeric {} \
         (|diff| {} exceeds atol {atol} + rtol {rtol})",
        analytic[worst],
        numeric[worst],
        (analytic[worst] - numeric[worst]).abs()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "LRAM_CHECK_SEED")]
    fn forall_reports_seed_on_failure() {
        forall(50, |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 0.0);
    }

    #[test]
    #[should_panic]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5);
    }

    #[test]
    fn grad_close_accepts_within_tolerance() {
        assert_grad_close("ok", &[1.0, -2.0, 0.0], &[1.0005, -2.001, 1e-6], 1e-3, 1e-5);
    }

    #[test]
    #[should_panic(expected = "gradient mismatch at [1]")]
    fn grad_close_names_the_worst_coordinate() {
        assert_grad_close("bad", &[1.0, 1.0], &[1.0, 1.5], 1e-3, 1e-5);
    }
}
