//! Tiny property-testing harness (the `proptest` crate is unavailable in
//! the offline build).  `forall` runs a closure over `n` random cases and
//! reports the seed of the first failing case so it can be replayed.
//!
//! ```no_run
//! use lram::util::check::forall;
//! forall(200, |rng| {
//!     let x = rng.uniform(-10.0, 10.0);
//!     assert!(x.abs() <= 10.0);
//! });
//! ```

use super::rng::Rng;

/// Run `f` on `n` independently-seeded RNGs; panic with the failing seed.
pub fn forall(n: u32, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    let base = std::env::var("LRAM_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..n as u64 {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed on case {case} (replay with LRAM_CHECK_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (|diff| = {}, tol = {tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "LRAM_CHECK_SEED")]
    fn forall_reports_seed_on_failure() {
        forall(50, |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 0.0);
    }

    #[test]
    #[should_panic]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5);
    }
}
