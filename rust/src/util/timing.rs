//! Benchmark timing helpers (criterion is unavailable offline).
//!
//! `bench` runs warmups then samples, reporting median / p10 / p90 —
//! matching the paper's "median of 15 successive runs" protocol for
//! Figure 3 and Table 4.  [`BenchReport`] is the machine-readable side:
//! benches append named entries of numeric fields and emit a
//! `BENCH_<name>.json` file that later PRs diff to track the perf
//! trajectory.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
    pub samples: usize,
}

impl BenchStats {
    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Time `f` (`samples` runs after `warmup` runs); returns stats over runs.
pub fn bench(warmup: usize, samples: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| {
        let idx = ((times.len() - 1) as f64 * p).round() as usize;
        times[idx]
    };
    BenchStats {
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
        mean_ns: times.iter().sum::<f64>() / times.len() as f64,
        samples,
    }
}

/// Coarse host fingerprint for bench reports: CPU model plus core count.
/// Good enough to detect "this baseline was recorded on different iron",
/// which is all `bench_gate --report` needs.
pub fn host_fingerprint() -> String {
    let cpu = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|text| {
            text.lines()
                .find(|l| l.starts_with("model name") || l.starts_with("Model"))
                .and_then(|l| l.split(':').nth(1))
                .map(|s| s.trim().to_string())
        })
        .unwrap_or_else(|| "unknown-cpu".to_string());
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    format!("{cpu} x{cores}")
}

/// Fixed-width table printer for the bench binaries.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(w + 2)).collect::<String>());
        for r in &self.rows {
            line(r);
        }
    }
}

/// Machine-readable benchmark output: a named list of entries, each a
/// flat map of numeric fields, serialised with `util::json` so the
/// format stays parseable by the same code that reads manifests.
pub struct BenchReport {
    name: String,
    host: Option<String>,
    entries: Vec<(String, Vec<(String, f64)>)>,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        BenchReport { name: name.to_string(), host: None, entries: Vec::new() }
    }

    /// Record the host fingerprint the numbers were measured on.
    /// `bench_gate --report` compares it against the baseline's and warns
    /// loudly on mismatch: absolute fields (qps, median_us) are not
    /// comparable across hosts, only same-run ratios are.
    pub fn set_host(&mut self, host: &str) {
        self.host = Some(host.to_string());
    }

    /// Append one entry (e.g. one bench row) of numeric fields.
    pub fn entry(&mut self, name: &str, fields: &[(&str, f64)]) {
        self.entries.push((
            name.to_string(),
            fields.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        ));
    }

    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|(name, fields)| {
                let mut pairs = vec![("name", Json::Str(name.clone()))];
                pairs.extend(fields.iter().map(|(k, v)| (k.as_str(), Json::Num(*v))));
                Json::obj(pairs)
            })
            .collect();
        let mut top = vec![("bench", Json::Str(self.name.clone()))];
        if let Some(host) = &self.host {
            top.push(("host", Json::Str(host.clone())));
        }
        top.push(("entries", Json::Arr(entries)));
        Json::obj(top)
    }

    /// Write `<path>` as pretty-enough single-line JSON.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench(1, 9, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert_eq!(s.samples, 9);
    }

    #[test]
    fn bench_report_roundtrips_through_util_json() {
        // the exact shape benches/lattice_hot_path.rs writes to
        // BENCH_lattice.json must stay parseable by util::json
        let mut r = BenchReport::new("lattice_hot_path");
        r.entry(
            "engine_lookup_gather_b256_t1",
            &[("batch", 256.0), ("threads", 1.0), ("median_us", 37.5), ("qps", 6.8e6)],
        );
        r.entry("scalar_lookup_gather_b256", &[("batch", 256.0), ("median_us", 140.0)]);
        let text = r.to_json().to_string();
        let v = crate::util::json::parse(&text).expect("report parses");
        assert_eq!(v.req("bench").unwrap().as_str().unwrap(), "lattice_hot_path");
        let entries = v.req("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(
            entries[0].req("name").unwrap().as_str().unwrap(),
            "engine_lookup_gather_b256_t1"
        );
        assert_eq!(entries[0].req("batch").unwrap().as_f64().unwrap(), 256.0);
        assert_eq!(entries[1].req("median_us").unwrap().as_f64().unwrap(), 140.0);
    }

    #[test]
    fn host_fingerprint_lands_in_the_report() {
        let fp = host_fingerprint();
        assert!(fp.contains(" x"), "fingerprint has a core-count suffix: {fp}");
        let mut r = BenchReport::new("x");
        r.set_host(&fp);
        let v = crate::util::json::parse(&r.to_json().to_string()).expect("parses");
        assert_eq!(v.req("host").unwrap().as_str().unwrap(), fp);
        // a report without a host stays host-free (old baselines parse as-is)
        let bare = BenchReport::new("y").to_json().to_string();
        let v = crate::util::json::parse(&bare).expect("parses");
        assert!(v.get("host").is_none());
    }

    #[test]
    fn committed_bench_baseline_has_the_gate_entry() {
        // CI gates on engine_lookup_gather_b256_t1.qps from the committed
        // baseline (see rust/src/bin/bench_gate.rs); keep it parseable
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("benches/BENCH_lattice.baseline.json");
        let text = std::fs::read_to_string(path).expect("baseline file exists");
        let v = crate::util::json::parse(&text).expect("baseline parses");
        let entries = v.req("entries").unwrap().as_arr().unwrap();
        let e = entries
            .iter()
            .find(|e| {
                e.get("name").and_then(|n| n.as_str()) == Some("engine_lookup_gather_b256_t1")
            })
            .expect("gate entry present");
        assert!(e.req("qps").unwrap().as_f64().unwrap() > 0.0);
    }
}
