//! Deterministic PRNG (xoshiro256++) — the `rand` crate is not available
//! in the offline build, and determinism from a seed is required anyway
//! for reproducible corpora and benchmarks.

/// xoshiro256++ with splitmix64 seeding.  Passes BigCrush; more than
/// adequate for workload generation and Monte Carlo estimation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        Rng {
            s: [
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
                splitmix64(&mut st),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal (Box–Muller, one value per call for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a decorrelated child stream (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA3EC4F1D8B2C9E57)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10000 {
            let x = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(1);
        let mut counts = [0u32; 7];
        for _ in 0..70000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((8500..11500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0u32; 3];
        for _ in 0..10000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 8 * c[0] / 2, "{c:?}");
    }
}
