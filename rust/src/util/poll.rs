//! Thin safe layer over `poll(2)` — the readiness primitive behind the
//! event-driven HTTP front door (`server::http`) and `loadgen`'s
//! high-connection client.
//!
//! Three pieces:
//!
//! * [`poll`] — wait for readiness on a set of fds with EINTR retry;
//! * [`Waker`] — a self-pipe that other threads write one byte into to
//!   interrupt a blocked `poll` (connection handoff, batcher completion
//!   notifications, shutdown);
//! * [`raise_nofile_limit`] — lift `RLIMIT_NOFILE` toward a target so a
//!   single process can hold thousands of sockets (the 5–10k-connection
//!   load scenario).
//!
//! Everything here is plain fd arithmetic; no locks, no allocation on
//! the wake path.

use std::io;
use std::os::fd::RawFd;
use std::time::Duration;

pub use libc::pollfd;
pub use libc::{POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};

/// Build one `poll(2)` registration.
#[inline]
pub fn entry(fd: RawFd, events: i16) -> pollfd {
    pollfd { fd, events, revents: 0 }
}

/// Wait until at least one registered fd is ready, `timeout` elapses
/// (`None` blocks indefinitely), or a wakeup arrives.  Returns how many
/// entries have nonzero `revents`; `Ok(0)` means the timeout fired.
/// EINTR retries transparently (the remaining timeout is re-armed in
/// full — callers here all re-derive deadlines per iteration anyway).
pub fn poll(fds: &mut [pollfd], timeout: Option<Duration>) -> io::Result<usize> {
    let timeout_ms: i32 = match timeout {
        // poll's c_int timeout is milliseconds; saturate long waits
        Some(t) => t.as_millis().min(i32::MAX as u128) as i32,
        None => -1,
    };
    loop {
        // SAFETY: `fds` is a valid, initialised slice of `pollfd` for
        // the duration of the call, and the length is passed alongside.
        let rc = unsafe { libc::poll(fds.as_mut_ptr(), fds.len() as libc::nfds_t, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            continue;
        }
        return Err(err);
    }
}

/// A self-pipe wakeup: `wake()` from any thread makes the owning event
/// loop's [`poll`] (which registers [`Waker::read_fd`] for `POLLIN`)
/// return immediately.  Wakes coalesce — the pipe holds at most its
/// buffer of pending bytes and `wake()` treats a full pipe as "wakeup
/// already pending" — so the loop must re-check all its wake sources
/// after [`Waker::drain`], never count bytes.
pub struct Waker {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let mut fds = [0 as libc::c_int; 2];
        // SAFETY: `fds` is a valid out-array of two ints; pipe2 fills
        // both ends or returns -1 without touching them.
        let rc = unsafe { libc::pipe2(fds.as_mut_ptr(), libc::O_NONBLOCK | libc::O_CLOEXEC) };
        if rc != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Waker { read_fd: fds[0], write_fd: fds[1] })
    }

    /// The fd an event loop registers for `POLLIN`.
    pub fn read_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Interrupt the owning loop's `poll`.  Cheap, lock-free,
    /// signal-safe; a full pipe means a wakeup is already pending and
    /// counts as success.
    pub fn wake(&self) {
        let byte = [1u8];
        loop {
            // SAFETY: one-byte write from a live stack buffer into our
            // own open pipe fd.
            let rc = unsafe { libc::write(self.write_fd, byte.as_ptr().cast(), 1) };
            if rc >= 0 {
                return;
            }
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            // WouldBlock: the pipe already holds unread wakeup bytes —
            // the loop is guaranteed to wake; nothing more to do.
            return;
        }
    }

    /// Discard all pending wakeup bytes (called by the event loop once
    /// `poll` reports the read end readable).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: bounded read into a live stack buffer from our
            // own open pipe fd.
            let rc = unsafe { libc::read(self.read_fd, buf.as_mut_ptr().cast(), buf.len()) };
            if rc > 0 {
                continue;
            }
            if rc < 0 && io::Error::last_os_error().kind() == io::ErrorKind::Interrupted {
                continue;
            }
            // 0 (impossible while we hold the write end) or EAGAIN:
            // the pipe is empty
            return;
        }
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        // SAFETY: closing the two pipe fds this struct owns exactly
        // once; nothing else holds them.
        unsafe {
            libc::close(self.read_fd);
            libc::close(self.write_fd);
        }
    }
}

/// Raise the process's soft `RLIMIT_NOFILE` toward `want` (clamped to
/// the hard cap).  Returns the resulting soft limit; never lowers it.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = libc::rlimit { rlim_cur: 0, rlim_max: 0 };
    // SAFETY: plain out-parameter read of the process fd limit.
    if unsafe { libc::getrlimit(libc::RLIMIT_NOFILE, &mut lim) } != 0 {
        return Err(io::Error::last_os_error());
    }
    if lim.rlim_cur >= want {
        return Ok(lim.rlim_cur);
    }
    let new = libc::rlimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
    // SAFETY: writing a well-formed rlimit no larger than the hard cap.
    if unsafe { libc::setrlimit(libc::RLIMIT_NOFILE, &new) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(new.rlim_cur)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waker_wakes_a_poll_and_drains_clean() {
        let w = Waker::new().expect("pipe");
        // no wake pending: a zero-timeout poll reports nothing
        let mut fds = [entry(w.read_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, Some(Duration::ZERO)).expect("poll"), 0);

        w.wake();
        w.wake(); // coalesces, never errors
        let mut fds = [entry(w.read_fd(), POLLIN)];
        let n = poll(&mut fds, Some(Duration::from_secs(5))).expect("poll");
        assert_eq!(n, 1);
        assert_ne!(fds[0].revents & POLLIN, 0);

        w.drain();
        let mut fds = [entry(w.read_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, Some(Duration::ZERO)).expect("poll"), 0);
    }

    #[test]
    fn wake_from_another_thread_interrupts_a_blocked_poll() {
        let w = std::sync::Arc::new(Waker::new().expect("pipe"));
        let w2 = w.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            w2.wake();
        });
        let mut fds = [entry(w.read_fd(), POLLIN)];
        let n = poll(&mut fds, Some(Duration::from_secs(10))).expect("poll");
        assert_eq!(n, 1, "the cross-thread wake must end the poll");
        t.join().expect("waker thread");
    }

    #[test]
    fn raise_nofile_limit_never_lowers() {
        let before = raise_nofile_limit(0).expect("read limit");
        let after = raise_nofile_limit(before).expect("no-op raise");
        assert!(after >= before);
    }
}
