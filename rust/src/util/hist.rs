//! Log-bucketed latency histogram for serving observability.
//!
//! Fixed memory (one `u64` per bucket), O(1) record, and percentile
//! queries with bounded relative error: bucket edges grow geometrically
//! by [`GROWTH`], so any reported quantile is within one bucket —
//! ≤ 15% — of the true value.  That trade is deliberate: the serving
//! hot path records one sample per request under the stats mutex, and a
//! fixed array clones cheaply into `/stats` snapshots, where an exact
//! reservoir would not.
//!
//! Values are milliseconds.  Everything below [`LOW_MS`] lands in the
//! first bucket (sub-50µs requests are all "instant" for serving
//! purposes); everything above the last edge (~5 minutes) is counted in
//! an overflow bucket and reported as the exact observed maximum.

/// Lower edge of the first bucket (ms): 50µs.
pub const LOW_MS: f64 = 0.05;
/// Geometric growth factor between bucket edges.
pub const GROWTH: f64 = 1.15;
/// Bucket count: `LOW_MS * GROWTH^112` ≈ 316s caps the tracked range.
pub const BUCKETS: usize = 112;

/// Streaming latency histogram (milliseconds, log-spaced buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    overflow: u64,
    total: u64,
    sum_ms: f64,
    max_ms: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: vec![0; BUCKETS], overflow: 0, total: 0, sum_ms: 0.0, max_ms: 0.0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample; negative / non-finite values are dropped (a
    /// clock that stepped backwards must not poison the distribution).
    pub fn record(&mut self, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        self.total += 1;
        self.sum_ms += ms;
        if ms > self.max_ms {
            self.max_ms = ms;
        }
        let idx = if ms <= LOW_MS {
            0
        } else {
            ((ms / LOW_MS).ln() / GROWTH.ln()).ceil() as usize
        };
        if idx < BUCKETS {
            self.counts[idx] += 1;
        } else {
            self.overflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ms(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ms / self.total as f64
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_ms
    }

    /// The `p`-quantile (`p` in `[0, 1]`), as the upper edge of the
    /// bucket holding the rank-`ceil(p * count)` sample — an
    /// overestimate by at most one bucket width (≤ 15% relative).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 1.0);
        let target = ((p * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return LOW_MS * GROWTH.powi(i as i32);
            }
        }
        // rank fell in the overflow bucket: the exact max is the best
        // bound we have
        self.max_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile_ms(0.5), 0.0);
        assert_eq!(h.percentile_ms(0.99), 0.0);
    }

    #[test]
    fn single_value_is_within_one_bucket() {
        let mut h = Histogram::new();
        h.record(12.0);
        for p in [0.0, 0.5, 0.95, 0.99, 1.0] {
            let got = h.percentile_ms(p);
            assert!(got >= 12.0 && got <= 12.0 * GROWTH * 1.001, "p{p}: {got}");
        }
        assert_eq!(h.mean_ms(), 12.0);
        assert_eq!(h.max_ms(), 12.0);
    }

    #[test]
    fn garbage_samples_are_dropped() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-3.0);
        assert_eq!(h.count(), 0);
        h.record(1.0);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn overflow_reports_the_exact_max() {
        let mut h = Histogram::new();
        h.record(1e9); // far beyond the last edge
        assert_eq!(h.percentile_ms(0.99), 1e9);
    }

    #[test]
    fn percentiles_track_exact_ranks_within_bucket_error() {
        // property: against an exact sorted-rank oracle, every reported
        // quantile is within one geometric bucket of the true sample
        forall(32, |rng| {
            let n = 50 + rng.below(500) as usize;
            let mut samples: Vec<f64> = (0..n)
                .map(|_| {
                    // span sub-bucket to multi-second latencies
                    let exp = rng.uniform(-1.0, 4.0);
                    10f64.powf(exp)
                })
                .collect();
            let mut h = Histogram::new();
            for &s in &samples {
                h.record(s);
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for p in [0.5, 0.9, 0.95, 0.99] {
                let rank = ((p * n as f64).ceil() as usize).max(1) - 1;
                let exact = samples[rank];
                let got = h.percentile_ms(p);
                assert!(
                    got >= exact * 0.999 && got <= exact * GROWTH * 1.001,
                    "p{p}: exact {exact} vs histogram {got}"
                );
            }
            assert_eq!(h.count(), n as u64);
            let mean: f64 = samples.iter().sum::<f64>() / n as f64;
            assert!((h.mean_ms() - mean).abs() < 1e-9 * mean.max(1.0));
        });
    }
}
