//! Process-termination signals as a pollable flag.
//!
//! `lram serve` must drain gracefully when the operator (or an init
//! system / k8s) sends SIGTERM: stop accepting, let in-flight requests
//! complete, then exit.  The handler installed here — via the vendored
//! libc's `sigaction` — does the only async-signal-safe thing possible:
//! it sets a static atomic.  A watcher thread (see
//! [`crate::server::Server::drain_on_termination`]) turns the flag into
//! the actual drain.
//!
//! The flag is process-global and one-shot by design: termination is
//! not an event a process recovers from, so nothing ever clears it.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

static TERMINATION: AtomicBool = AtomicBool::new(false);
static INSTALL: Once = Once::new();

/// The signal handler: a relaxed-atomic store, then restore the
/// default (fatal) disposition for *both* termination signals — both
/// operations are async-signal-safe (`sigaction` is on the POSIX
/// async-signal-safe list).  Restoring both, not just the delivered
/// one, keeps the escalation path honest across signal kinds: Ctrl-C
/// (SIGINT) to drain, then `kill` (SIGTERM) on a wedged drain, must
/// kill — not be absorbed by the still-installed sibling handler.
extern "C" fn mark_termination(_sig: libc::c_int) {
    // ORDERING: the flag is a one-shot boolean polled by a watcher
    // thread; no other memory is published alongside it, so relaxed is
    // enough (and the handler must stay minimal/async-signal-safe)
    TERMINATION.store(true, Ordering::Relaxed);
    let dfl = libc::sigaction {
        sa_handler: 0, // SIG_DFL
        sa_mask: [0; 16],
        sa_flags: 0,
        sa_restorer: 0,
    };
    // SAFETY: valid sigaction structs; called from a signal handler,
    // where sigaction() is explicitly async-signal-safe.
    unsafe {
        libc::sigaction(libc::SIGTERM, &dfl, std::ptr::null_mut());
        libc::sigaction(libc::SIGINT, &dfl, std::ptr::null_mut());
    }
}

/// Install handlers for SIGTERM and SIGINT (idempotent) and return the
/// flag they set.  `SA_RESTART` keeps blocking syscalls from surfacing
/// spurious EINTRs to code that never expected them.  The handlers are
/// one-shot across *both* signals (see [`mark_termination`]): the
/// first signal of either kind starts the drain, the second — of
/// either kind — kills outright, so a wedged drain never needs
/// SIGKILL.
pub fn termination_flag() -> &'static AtomicBool {
    INSTALL.call_once(|| {
        let handler: extern "C" fn(libc::c_int) = mark_termination;
        let act = libc::sigaction {
            sa_handler: handler as usize,
            sa_mask: [0; 16],
            sa_flags: libc::SA_RESTART,
            sa_restorer: 0,
        };
        for sig in [libc::SIGTERM, libc::SIGINT] {
            // SAFETY: `act` is a valid sigaction whose handler performs
            // only an atomic store; a failed install degrades to the
            // default signal disposition (kill), never to UB.
            let rc = unsafe { libc::sigaction(sig, &act, std::ptr::null_mut()) };
            if rc != 0 {
                log::warn!("could not install the handler for signal {sig}");
            }
        }
    });
    &TERMINATION
}

/// Send SIGTERM to the current process — the integration tests' stand-in
/// for `kill <pid>`, exercising the real handler path in-process.
pub fn raise_sigterm() {
    // SAFETY: raise() is async-signal-safe and has no memory contract.
    unsafe {
        libc::raise(libc::SIGTERM);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handler_sets_the_flag_on_raise() {
        let flag = termination_flag();
        // the flag may already be set if another test raised first —
        // one-shot global state is the documented contract
        raise_sigterm();
        assert!(flag.load(Ordering::Relaxed), "SIGTERM must set the termination flag");
        assert!(
            std::ptr::eq(flag, termination_flag()),
            "repeat installs hand back the same flag"
        );
    }
}
