//! Offline-build substrates: JSON, PRNG, CLI, mmap, logging, timing,
//! property-testing.  These replace serde/rand/clap/memmap2/tracing/
//! criterion/proptest, none of which are available without network access
//! (see DESIGN.md "Substitutions").

pub mod check;
pub mod cli;
pub mod failpoint;
pub mod hist;
pub mod json;
pub mod lockcheck;
pub mod logger;
pub mod mmap;
pub mod poll;
pub mod rng;
pub mod sigbus;
pub mod signal;
pub mod timing;
pub mod topk;

/// FNV-1a 64-bit hash — content fingerprints for checkpoints and
/// tokenizers (not cryptographic; detects corruption and drift, not
/// adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a test vectors
        assert_eq!(super::fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(super::fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(super::fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
