//! Offline-build substrates: JSON, PRNG, CLI, mmap, logging, timing,
//! property-testing.  These replace serde/rand/clap/memmap2/tracing/
//! criterion/proptest, none of which are available without network access
//! (see DESIGN.md "Substitutions").

pub mod check;
pub mod cli;
pub mod json;
pub mod logger;
pub mod mmap;
pub mod rng;
pub mod timing;
pub mod topk;
