//! Named fault-injection sites (`failpoint::inject("checkpoint.read_blob")`)
//! — the test- and chaos-harness seam that makes failure a first-class
//! code path.  A site does nothing until a policy is armed for it, either
//! programmatically ([`set`]) or via the `LRAM_FAILPOINTS` environment
//! variable; the inactive path is a single relaxed atomic load, cheap
//! enough to leave in release builds on the request hot path.
//!
//! Spec grammar (env var and [`set`] share it):
//!
//! ```text
//! LRAM_FAILPOINTS="site=action[:prob[:times]][,site=...]"
//!   action  error | panic | delay-MS
//!   prob    0.0..=1.0 firing probability       (default 1.0)
//!   times   max number of firings, then disarm (default unlimited)
//! ```
//!
//! e.g. `LRAM_FAILPOINTS="batcher.exec=panic:0.02,checkpoint.read_blob=error:0.05:3"`.
//!
//! Actions:
//! * `error`    — [`inject`] returns `Some(anyhow::Error)`; the call site
//!   propagates it like any real IO/backend failure.
//! * `panic`    — [`inject`] panics; exercises `catch_unwind` supervision.
//! * `delay-MS` — [`inject`] sleeps `MS` milliseconds then returns `None`;
//!   exercises timeout and slow-peer paths.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Duration;

use anyhow::{anyhow, Error, Result};

use super::rng::Rng;

/// Single source of truth for every failpoint site compiled into the
/// binary: `(site name, where it fires)`.
///
/// `tidy` (check 4) cross-checks this table three ways — every
/// `failpoint::inject("…")` call site in production code must be
/// registered here, every entry must appear in `docs/robustness.md`'s
/// site table, and every entry must be exercised by
/// `rust/tests/chaos.rs` — so a site can be neither undocumented nor
/// dead.  Arming a site that is not registered logs a warning (tests
/// arm ad-hoc sites on purpose; production specs should not).
pub const SITES: &[(&str, &str)] = &[
    ("checkpoint.open", "Checkpoint::open — manifest load + eager verify"),
    ("checkpoint.read_blob", "per-tensor blob read/checksum"),
    ("table.gather", "value-table access inside EngineBackend::infer"),
    ("batcher.submit", "admission path, before a request is queued"),
    ("batcher.exec", "executor, with a collected batch in flight"),
    ("http.worker", "request routing inside an HTTP worker"),
];

/// Whether `site` is in the compiled-in [`SITES`] registry.
pub fn is_registered(site: &str) -> bool {
    SITES.iter().any(|&(s, _)| s == site)
}

#[derive(Debug, Clone, PartialEq)]
enum Action {
    Error,
    Panic,
    Delay(u64),
}

#[derive(Debug, Clone)]
struct Policy {
    action: Action,
    prob: f64,
    /// remaining firings before the site disarms itself; `None` = unlimited
    remaining: Option<u64>,
}

struct Registry {
    sites: HashMap<String, Policy>,
    /// total fires per site, kept after disarm (test/diagnostic visibility)
    fired: HashMap<String, u64>,
    rng: Rng,
}

/// Fast-path gate: `false` means no site is armed and [`inject`] is a
/// single relaxed load + branch.  Starts `true` so the very first call
/// pays for the one-time env parse, which then settles the flag.
static ACTIVE: AtomicBool = AtomicBool::new(true);
static ENV_PARSE: Once = Once::new();

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| {
        // non-cryptographic seed: fault *timing* may be arbitrary, only
        // the armed sites and probabilities are the contract under test
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5eed)
            ^ (std::process::id() as u64).rotate_left(32);
        Mutex::new(Registry {
            sites: HashMap::new(),
            fired: HashMap::new(),
            rng: Rng::new(seed),
        })
    })
}

fn parse_env_once() {
    ENV_PARSE.call_once(|| {
        let armed = match std::env::var("LRAM_FAILPOINTS") {
            Ok(spec) if !spec.trim().is_empty() => match arm_from_spec(&spec) {
                Ok(n) => {
                    log::warn!("failpoints ARMED from LRAM_FAILPOINTS ({n} site(s)): {spec}");
                    n > 0
                }
                Err(e) => {
                    log::error!("ignoring malformed LRAM_FAILPOINTS ({e:#}): {spec}");
                    false
                }
            },
            _ => false,
        };
        if !armed {
            settle_active();
        }
    });
}

/// Recompute the fast-path gate from the registry contents.
fn settle_active() {
    let empty = lock().sites.is_empty();
    // ORDERING: advisory gate (see inject); the registry lock is the
    // real synchronisation for site state
    ACTIVE.store(!empty, Ordering::Relaxed);
}

fn lock() -> std::sync::MutexGuard<'static, Registry> {
    // a panic while holding this lock can only come from a `panic`-action
    // site firing, which is exactly the state the next caller wants to see
    registry().lock().unwrap_or_else(|p| p.into_inner())
}

/// Arm every `site=policy` entry in a comma-separated spec; returns how
/// many sites were armed.
pub fn arm_from_spec(spec: &str) -> Result<usize> {
    let mut n = 0;
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (site, policy) = entry
            .split_once('=')
            .ok_or_else(|| anyhow!("'{entry}': expected site=action[:prob[:times]]"))?;
        set(site.trim(), policy.trim())?;
        n += 1;
    }
    Ok(n)
}

/// Arm one site with an `action[:prob[:times]]` policy (see module docs).
pub fn set(site: &str, policy: &str) -> Result<()> {
    if site.is_empty() {
        return Err(anyhow!("empty failpoint site name"));
    }
    let mut parts = policy.split(':');
    let action_s = parts.next().unwrap_or("");
    let action = if action_s == "error" {
        Action::Error
    } else if action_s == "panic" {
        Action::Panic
    } else if let Some(ms) = action_s.strip_prefix("delay-") {
        Action::Delay(ms.parse::<u64>().map_err(|_| {
            anyhow!("'{action_s}': delay wants integer milliseconds (delay-MS)")
        })?)
    } else {
        return Err(anyhow!("'{action_s}': unknown action (error | panic | delay-MS)"));
    };
    let prob = match parts.next() {
        None => 1.0,
        Some(p) => {
            let v: f64 =
                p.parse().map_err(|_| anyhow!("'{p}': probability must be a float"))?;
            if !(0.0..=1.0).contains(&v) {
                return Err(anyhow!("'{p}': probability must be in 0.0..=1.0"));
            }
            v
        }
    };
    let remaining = match parts.next() {
        None => None,
        Some(t) => Some(
            t.parse::<u64>().map_err(|_| anyhow!("'{t}': times must be a non-negative integer"))?,
        ),
    };
    if let Some(extra) = parts.next() {
        return Err(anyhow!("'{extra}': trailing garbage after action:prob:times"));
    }
    if !is_registered(site) {
        log::warn!(
            "arming unregistered failpoint site '{site}' (not in failpoint::SITES); \
             nothing in production code will ever reach it"
        );
    }
    lock().sites.insert(site.to_string(), Policy { action, prob, remaining });
    // ORDERING: the gate is advisory — a stale `false` only delays the
    // first fire until the next inject() re-reads it; the registry lock
    // above already ordered the site insert
    ACTIVE.store(true, Ordering::Relaxed);
    Ok(())
}

/// Disarm one site (its fired-count survives for inspection).
pub fn clear(site: &str) {
    lock().sites.remove(site);
    settle_active();
}

/// Disarm every site and forget fired-counts — test teardown.
pub fn clear_all() {
    {
        let mut r = lock();
        r.sites.clear();
        r.fired.clear();
    }
    settle_active();
}

/// How many times `site` has fired since the last [`clear_all`].
pub fn fired(site: &str) -> u64 {
    lock().fired.get(site).copied().unwrap_or(0)
}

/// The fault site.  Returns `Some(error)` when an `error` policy fires
/// (propagate it as the operation's failure), panics when a `panic`
/// policy fires, sleeps inline for `delay`.  `None` means proceed
/// normally — which is the only outcome when nothing is armed, via a
/// branch cheap enough for per-request hot paths.
#[inline]
pub fn inject(site: &str) -> Option<Error> {
    // ORDERING: the whole point of the gate is to be one relaxed load on
    // the hot path; a stale value only means one extra/missed slow-path
    // trip, and the registry lock decides the truth in inject_slow
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    inject_slow(site)
}

#[cold]
fn inject_slow(site: &str) -> Option<Error> {
    parse_env_once();
    let action = {
        let mut r = lock();
        let prob = match r.sites.get(site) {
            Some(p) => p.prob,
            None => return None,
        };
        if prob < 1.0 && r.rng.f64() >= prob {
            return None;
        }
        let policy = r.sites.get_mut(site).expect("site vanished under lock");
        let action = policy.action.clone();
        let disarm = match policy.remaining.as_mut() {
            Some(left) => {
                if *left == 0 {
                    // exhausted budget left behind: treat as disarmed
                    r.sites.remove(site);
                    settle_active_locked(&r);
                    return None;
                }
                *left -= 1;
                *left == 0
            }
            None => false,
        };
        *r.fired.entry(site.to_string()).or_insert(0) += 1;
        if disarm {
            r.sites.remove(site);
            settle_active_locked(&r);
        }
        action
    };
    match action {
        Action::Error => {
            log::warn!("failpoint '{site}' fired: injecting error");
            Some(anyhow!("failpoint '{site}' injected error"))
        }
        Action::Panic => {
            log::warn!("failpoint '{site}' fired: injecting panic");
            panic!("failpoint '{site}' injected panic");
        }
        Action::Delay(ms) => {
            log::warn!("failpoint '{site}' fired: injecting {ms}ms delay");
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
    }
}

/// [`settle_active`] while the registry lock is already held.
fn settle_active_locked(r: &Registry) {
    // ORDERING: advisory gate (see inject); the caller holds the
    // registry lock that orders the site mutation itself
    ACTIVE.store(!r.sites.is_empty(), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    // every test serialises on this: the registry is process-global and
    // cargo runs #[test]s concurrently
    static GATE: Mutex<()> = Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        let g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        clear_all();
        g
    }

    #[test]
    fn inactive_site_is_a_no_op() {
        let _g = guard();
        assert!(inject("nothing.armed").is_none());
        assert_eq!(fired("nothing.armed"), 0);
    }

    #[test]
    fn error_policy_fires_and_counts() {
        let _g = guard();
        set("t.err", "error").unwrap();
        let e = inject("t.err").expect("armed error site must fire at prob 1");
        assert!(e.to_string().contains("t.err"), "{e}");
        assert_eq!(fired("t.err"), 1);
        clear("t.err");
        assert!(inject("t.err").is_none());
    }

    #[test]
    fn times_budget_disarms_the_site() {
        let _g = guard();
        set("t.budget", "error:1.0:2").unwrap();
        assert!(inject("t.budget").is_some());
        assert!(inject("t.budget").is_some());
        assert!(inject("t.budget").is_none(), "budget of 2 must disarm after 2 fires");
        assert_eq!(fired("t.budget"), 2);
    }

    #[test]
    fn panic_policy_panics() {
        let _g = guard();
        set("t.panic", "panic:1.0:1").unwrap();
        let r = std::panic::catch_unwind(|| inject("t.panic"));
        assert!(r.is_err(), "panic policy must unwind");
        assert!(inject("t.panic").is_none(), "times=1 must disarm after the panic");
    }

    #[test]
    fn delay_policy_sleeps_then_proceeds() {
        let _g = guard();
        set("t.delay", "delay-30").unwrap();
        let t0 = std::time::Instant::now();
        assert!(inject("t.delay").is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25), "{:?}", t0.elapsed());
    }

    #[test]
    fn probability_zero_never_fires() {
        let _g = guard();
        set("t.never", "error:0.0").unwrap();
        for _ in 0..200 {
            assert!(inject("t.never").is_none());
        }
        assert_eq!(fired("t.never"), 0);
    }

    #[test]
    fn spec_parser_rejects_garbage() {
        let _g = guard();
        assert!(set("t.bad", "explode").is_err());
        assert!(set("t.bad", "error:1.5").is_err());
        assert!(set("t.bad", "error:0.5:many").is_err());
        assert!(set("t.bad", "delay-").is_err());
        assert!(set("", "error").is_err());
        assert!(arm_from_spec("a=error,b").is_err());
    }

    #[test]
    fn arm_from_spec_arms_multiple_sites() {
        let _g = guard();
        let n = arm_from_spec(" a.x = error:0.5 , b.y = delay-1:1.0:3 ").unwrap();
        assert_eq!(n, 2);
        set("a.x", "error").unwrap(); // overwrite to deterministic
        assert!(inject("a.x").is_some());
        clear_all();
        assert!(inject("a.x").is_none());
    }
}
