//! Minimal CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments, with typed accessors and a usage dump.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args`.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn req_str(&self, key: &str) -> Result<String> {
        self.flags
            .get(key)
            .cloned()
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => {
                // accept 2^k and 1_000_000 style
                let clean = v.replace('_', "");
                if let Some(exp) = clean.strip_prefix("2^") {
                    let e: u32 = exp.parse().with_context(|| format!("--{key}: bad exponent"))?;
                    return Ok(1u64 << e);
                }
                clean.parse().with_context(|| format!("--{key} must be an integer"))
            }
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow!("--{key}: expected bool, got '{v}'")),
        }
    }

    /// Comma-separated list of integers, with 2^k support.
    pub fn u64_list(&self, key: &str, default: &[u64]) -> Result<Vec<u64>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    let s = s.trim().replace('_', "");
                    if let Some(exp) = s.strip_prefix("2^") {
                        Ok(1u64 << exp.parse::<u32>()?)
                    } else {
                        Ok(s.parse::<u64>()?)
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_kinds() {
        let a = args(&["train", "--steps", "100", "--fast", "--lr=0.5"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
        assert!(a.bool("fast", false).unwrap());
        assert_eq!(a.f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.str("missing", "d"), "d");
    }

    #[test]
    fn power_of_two() {
        let a = args(&["--n", "2^20", "--list", "2^10,1000,2^4"]);
        assert_eq!(a.u64("n", 0).unwrap(), 1 << 20);
        assert_eq!(a.u64_list("list", &[]).unwrap(), vec![1024, 1000, 16]);
    }

    #[test]
    fn required_flag_errors() {
        assert!(args(&[]).req_str("x").is_err());
    }
}
