//! Shared partial top-k selection (quickselect, not full sorts).
//!
//! Three hot paths need "the k largest of n scores" with k << n: the
//! lattice candidate selection (k = 32 of <= 232), the PKM product-key
//! merge (k of k^2), and the serving vocab top-k (k of |V|).  All of
//! them previously paid O(n log n) or O(n*k); these helpers are
//! O(n + k log k) via `select_nth_unstable_by` and share one tie rule —
//! **score descending, then payload/index ascending** — which matches
//! the scan order of the scalar reference implementations exactly, so
//! differential tests can demand bit-identical outputs.
//!
//! NaN scores sort **last**, deterministically (mutual ties broken by
//! payload).  `partial_cmp(..).unwrap_or(Equal)` is *not* a total order
//! under NaN, and `select_nth_unstable_by` is allowed to return garbage
//! (or panic) when the comparator is inconsistent — a single NaN logit
//! from a bad checkpoint must degrade to "ranked below every real
//! score", never to scrambled top-k.

use std::cmp::Ordering;

/// Float scores orderable with an explicit NaN rule.  Public so the
/// selection helpers stay generic over the f64 oracle path and the f32
/// SIMD serving path (`lattice::batch` canonical tie-breaking works on
/// both).
pub trait Score: PartialOrd + Copy {
    fn is_nan(self) -> bool;
}

impl Score for f64 {
    fn is_nan(self) -> bool {
        f64::is_nan(self)
    }
}

impl Score for f32 {
    fn is_nan(self) -> bool {
        f32::is_nan(self)
    }
}

/// Total order on scores: descending, NaN after every real value (NaNs
/// mutually equal — callers break the tie on payload).
#[inline]
pub fn desc_nan_last<F: Score>(a: F, b: F) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (false, false) => b.partial_cmp(&a).unwrap_or(Ordering::Equal),
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
    }
}

/// Total order: score descending (NaN last), payload ascending on ties.
#[inline]
fn cmp_desc<S: Score, P: Copy + Ord>(a: &(S, P), b: &(S, P)) -> Ordering {
    desc_nan_last(a.0, b.0).then_with(|| a.1.cmp(&b.1))
}

/// Partition the `k` largest `(score, payload)` pairs to the front and
/// return them sorted (score descending, payload ascending on ties; NaN
/// scores rank below every real score).
///
/// For distinct scores this is equivalent, element for element, to the
/// reference partial selection sort in
/// [`crate::lattice::kernel::top_k_desc`], at O(n + k log k) instead of
/// O(n*k).  On exact score ties the reference's order depends on its
/// swap history; this helper uses the canonical payload-ascending rule
/// instead, so its output is a deterministic function of the input set.
pub fn partial_top_k_desc<S: Score, P: Copy + Ord>(items: &mut [(S, P)], k: usize) -> &[(S, P)] {
    let k = k.min(items.len());
    if k == 0 {
        return &[];
    }
    if k < items.len() {
        items.select_nth_unstable_by(k - 1, cmp_desc);
    }
    items[..k].sort_unstable_by(cmp_desc);
    &items[..k]
}

/// Indices of the `k` largest scores, score-descending (index ascending
/// on ties, NaN scores ranked last).  O(n + k log k); replaces
/// full-vocab sorts on the serving path and codebook sorts in the PKM
/// scorer.
pub fn top_k_indices_f32(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let cmp = |a: &u32, b: &u32| {
        desc_nan_last(scores[*a as usize], scores[*b as usize]).then_with(|| a.cmp(b))
    };
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx.into_iter().map(|i| i as usize).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn matches_reference_selection_sort_on_distinct_scores() {
        let mut rng = Rng::new(31);
        for _ in 0..200 {
            let n = 1 + rng.below(300) as usize;
            let k = 1 + rng.below(40) as usize;
            // distinct scores: shuffled injective mapping of the index
            let mut scores: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
            rng.shuffle(&mut scores);
            let mut items: Vec<(f64, u32)> =
                scores.into_iter().enumerate().map(|(i, s)| (s, i as u32)).collect();
            let mut reference = items.clone();
            let want =
                crate::lattice::kernel::top_k_desc(&mut reference, k).to_vec();
            let got = partial_top_k_desc(&mut items, k).to_vec();
            assert_eq!(got, want, "n={n} k={k}");
        }
    }

    #[test]
    fn tie_rule_is_canonical() {
        let mut items =
            vec![(5.0, 7u32), (5.0, 1u32), (9.0, 3u32), (5.0, 4u32), (1.0, 0u32)];
        let got = partial_top_k_desc(&mut items, 3).to_vec();
        assert_eq!(got, vec![(9.0, 3), (5.0, 1), (5.0, 4)]);
    }

    #[test]
    fn k_larger_than_n_and_zero() {
        let mut items = vec![(1.0, 0u32), (3.0, 1u32)];
        assert_eq!(partial_top_k_desc(&mut items, 10), &[(3.0, 1), (1.0, 0)]);
        assert!(partial_top_k_desc(&mut items, 0).is_empty());
    }

    #[test]
    fn indices_match_full_sort() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let n = 1 + rng.below(500) as usize;
            let k = 1 + rng.below(25) as usize;
            let scores: Vec<f32> = (0..n).map(|_| (rng.below(50) as f32) * 0.5).collect();
            let mut full: Vec<usize> = (0..n).collect();
            full.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
            });
            full.truncate(k.min(n));
            assert_eq!(top_k_indices_f32(&scores, k), full, "n={n} k={k}");
        }
    }

    #[test]
    fn nan_indices_sort_last_deterministically() {
        // property: with NaNs sprinkled in, top-k equals a full sort
        // under the same NaN-last rule, and no NaN index outranks a real
        // score while real candidates remain
        forall(150, |rng| {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(24) as usize;
            let scores: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.bool(0.2) {
                        f32::NAN
                    } else {
                        (rng.below(30) as f32) * 0.5
                    }
                })
                .collect();
            let got = top_k_indices_f32(&scores, k);
            let mut full: Vec<usize> = (0..n).collect();
            full.sort_by(|&a, &b| desc_nan_last(scores[a], scores[b]).then(a.cmp(&b)));
            full.truncate(k.min(n));
            assert_eq!(got, full, "n={n} k={k}");
            let non_nan = scores.iter().filter(|s| !s.is_nan()).count();
            for (rank, &i) in got.iter().enumerate() {
                if rank < non_nan {
                    assert!(!scores[i].is_nan(), "NaN at rank {rank} of {non_nan} real");
                }
            }
        });
    }

    #[test]
    fn nan_pairs_sort_last_deterministically() {
        // same property for the (score, payload) selection: output is a
        // deterministic function of the input set even under NaN
        forall(150, |rng| {
            let n = 1 + rng.below(150) as usize;
            let k = 1 + rng.below(20) as usize;
            let mut items: Vec<(f64, u32)> = (0..n)
                .map(|i| {
                    let s = if rng.bool(0.25) { f64::NAN } else { rng.below(20) as f64 };
                    (s, i as u32)
                })
                .collect();
            let mut reference = items.clone();
            reference.sort_by(cmp_desc);
            reference.truncate(k.min(n));
            let got = partial_top_k_desc(&mut items, k).to_vec();
            // compare through bits so NaN entries compare equal to themselves
            let key =
                |v: &[(f64, u32)]| v.iter().map(|&(s, p)| (s.to_bits(), p)).collect::<Vec<_>>();
            assert_eq!(key(&got), key(&reference), "n={n} k={k}");
        });
    }

    #[test]
    fn all_nan_input_keeps_payload_order() {
        let mut items = vec![(f64::NAN, 2u32), (f64::NAN, 0u32), (f64::NAN, 1u32)];
        let got: Vec<u32> = partial_top_k_desc(&mut items, 2).iter().map(|&(_, p)| p).collect();
        assert_eq!(got, vec![0, 1]);
        let scores = [f32::NAN, f32::NAN];
        assert_eq!(top_k_indices_f32(&scores, 2), vec![0, 1]);
    }
}
