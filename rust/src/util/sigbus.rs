//! SIGBUS containment for file-backed mappings.
//!
//! PR 7 closed the open→map window (`RawMap` re-validates file length
//! after `mmap`), but a file truncated *while mapped* still raises
//! SIGBUS on the next access to a page past the new EOF — a fault
//! `catch_unwind` cannot contain.  This module closes that remaining
//! half:
//!
//! * every file-backed `RawMap` registers its address range here
//!   (anonymous maps never do, so lib tests under Miri/sanitizers never
//!   touch `sigaction`);
//! * a process-wide `SA_SIGINFO` SIGBUS handler checks the faulting
//!   address against the registry.  Inside a registered range it maps a
//!   fresh anonymous zero page over the faulting page (`MAP_FIXED`),
//!   bumps the global *fault epoch*, and returns — the interrupted load
//!   re-executes against zeros and the thread survives;
//! * `server::backend::EngineBackend` snapshots the epoch at build time
//!   and declares itself poisoned once it moves, which the batcher
//!   supervisor turns into well-formed 503s plus a rebuild from the
//!   last good checkpoint (see `docs/robustness.md`).
//!
//! Faults outside any registered range (a genuine bug) re-install the
//! default disposition and return; the access re-faults and the process
//! dies exactly as it would have without this module.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Once;

/// Fixed-capacity lock-free registry: a handler cannot take locks, so
/// slots are claimed/released with atomics only.
const MAX_REGIONS: usize = 64;

/// The replacement-page size.  4 KiB is the page size on every 64-bit
/// Linux target this repo runs on; on an exotic larger-page kernel the
/// `MAP_FIXED` remap fails (unaligned addr) and the fault stays fatal —
/// no worse than before this module existed.
const REMAP_PAGE: usize = 4096;

struct Region {
    /// Base address of the mapping; 0 marks a free slot (mmap never
    /// returns page 0 for a successful mapping).
    start: AtomicUsize,
    len: AtomicUsize,
}

impl Region {
    const fn empty() -> Region {
        Region { start: AtomicUsize::new(0), len: AtomicUsize::new(0) }
    }
}

#[allow(clippy::declare_interior_mutable_const)] // array-init seed only
const EMPTY_REGION: Region = Region::empty();

static REGIONS: [Region; MAX_REGIONS] = [EMPTY_REGION; MAX_REGIONS];

/// Bumped once per contained fault.  Monotonic across backend rebuilds.
static FAULT_EPOCH: AtomicU64 = AtomicU64::new(0);

static INSTALL: Once = Once::new();

/// The number of SIGBUS faults contained so far.  A backend that
/// snapshots this at build time is *poisoned* once it observes a newer
/// value: some mapped page it may already have read was replaced by
/// zeros.
pub fn fault_epoch() -> u64 {
    // ORDERING: Acquire pairs with the handler's AcqRel bump so a
    // reader that sees the new epoch also sees the remapped page.
    FAULT_EPOCH.load(Ordering::Acquire)
}

/// Register a file-backed mapping `[start, start+len)` for SIGBUS
/// containment; installs the process-wide handler on first use.
/// Returns whether a registry slot was claimed (callers must only
/// `unregister` when it was).
pub(crate) fn register(start: usize, len: usize) -> bool {
    if start == 0 || len == 0 {
        return false;
    }
    install_handler();
    for r in &REGIONS {
        // Claim on `start`; the handler ignores slots whose `len` is
        // still 0, so the two-step publish is benign (no access can
        // fault before `register` returns the mapping to its caller).
        if r.start.compare_exchange(0, start, Ordering::AcqRel, Ordering::Acquire).is_ok() {
            r.len.store(len, Ordering::Release);
            return true;
        }
    }
    log::warn!(
        "sigbus: registry full ({MAX_REGIONS} mappings); faults in this mapping stay fatal"
    );
    false
}

/// Release the slot claimed by [`register`].  Called from `RawMap::drop`
/// just before `munmap`, so the handler can no longer race a fault in
/// this range with the unmap (a fault here would be a use-after-free
/// bug, fatal either way).
pub(crate) fn unregister(start: usize) {
    for r in &REGIONS {
        if r.start.load(Ordering::Acquire) == start {
            r.len.store(0, Ordering::Release);
            r.start.store(0, Ordering::Release);
            return;
        }
    }
}

fn install_handler() {
    INSTALL.call_once(|| {
        let act = libc::sigaction {
            sa_handler: on_sigbus as usize,
            sa_mask: [0; 16],
            sa_flags: libc::SA_RESTART | libc::SA_SIGINFO,
            sa_restorer: 0,
        };
        // SAFETY: installs an async-signal-safe handler (atomics and
        // raw syscalls only, see `on_sigbus`); the struct layout
        // matches glibc/musl `struct sigaction` on 64-bit Linux, same
        // as `util::signal` uses for SIGTERM/SIGINT.
        let rc = unsafe { libc::sigaction(libc::SIGBUS, &act, std::ptr::null_mut()) };
        if rc != 0 {
            log::warn!("sigbus: installing the SIGBUS handler failed; truncated mappings are fatal");
        }
    });
}

/// The SIGBUS handler.  Async-signal-safe by construction: it touches
/// lock-free atomics and issues `mmap`/`sigaction` syscalls — no
/// allocation, no locks, no logging.
extern "C" fn on_sigbus(
    _sig: libc::c_int,
    info: *mut libc::siginfo_t,
    _ctx: *mut libc::c_void,
) {
    // SAFETY: for SA_SIGINFO handlers the kernel passes a valid
    // `siginfo_t`; for SIGBUS its `si_addr` is the faulting address.
    let addr = unsafe { (*info).si_addr };
    if addr != 0 {
        for r in &REGIONS {
            let s = r.start.load(Ordering::Acquire);
            if s == 0 {
                continue;
            }
            let l = r.len.load(Ordering::Acquire);
            if addr < s || addr >= s.saturating_add(l) {
                continue;
            }
            let base = (addr & !(REMAP_PAGE - 1)) as *mut libc::c_void;
            // SAFETY: maps a fresh private zero page exactly over the
            // faulting page, which lies inside a still-registered (so
            // still-mapped) file-backed region; MAP_FIXED replaces only
            // that one page.  Writable so a faulting store also
            // survives (the write lands in the discardable anon page).
            let p = unsafe {
                libc::mmap(
                    base,
                    REMAP_PAGE,
                    libc::PROT_READ | libc::PROT_WRITE,
                    libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_FIXED,
                    -1,
                    0,
                )
            };
            if p != libc::MAP_FAILED {
                // ORDERING: AcqRel publish — pairs with the Acquire in
                // `fault_epoch` so an observer of the new epoch also
                // observes the page replacement.
                FAULT_EPOCH.fetch_add(1, Ordering::AcqRel);
                return;
            }
            break;
        }
    }
    // Not a registered mapping (or the remap failed): restore the
    // default disposition and return.  The interrupted access re-faults
    // and the process dies exactly as it would have without a handler.
    let dfl = libc::sigaction { sa_handler: 0, sa_mask: [0; 16], sa_flags: 0, sa_restorer: 0 };
    // SAFETY: resetting a signal disposition to SIG_DFL (0) is
    // async-signal-safe; layout as above.
    unsafe {
        libc::sigaction(libc::SIGBUS, &dfl, std::ptr::null_mut());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_claims_and_unregister_frees_slots() {
        // use addresses far outside anything mapped so a stray handler
        // lookup can never match real memory; other tests in this
        // binary may hold slots concurrently, so never assume the
        // registry is empty — only that released slots become reusable
        let a = usize::MAX - (1 << 20);
        assert!(register(a, 4096));
        assert!(register(a + 8192, 4096));
        unregister(a);
        unregister(a + 8192);
        let mut claimed = Vec::new();
        for i in 0..MAX_REGIONS {
            let base = usize::MAX - (2 << 20) - i * 8192;
            if register(base, 4096) {
                claimed.push(base);
            } else {
                break;
            }
        }
        assert!(claimed.len() >= 2, "released slots must be reusable");
        for base in claimed {
            unregister(base);
        }
    }

    #[test]
    fn degenerate_registrations_are_refused() {
        assert!(!register(0, 4096));
        assert!(!register(4096, 0));
    }
}
