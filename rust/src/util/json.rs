//! Minimal JSON parser/writer (no serde available in the offline build).
//!
//! Supports the full JSON grammar; numbers are kept as f64 with an i64
//! fast path (enough for manifests, fixtures and config files).  Parsing
//! is a straightforward recursive-descent over bytes; the fixture files
//! are a few MB and parse in milliseconds.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        let arr = self.as_arr().ok_or_else(|| anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("expected number")))
            .collect()
    }

    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        Ok(self.as_f64_vec()?.into_iter().map(|f| f as i64).collect())
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        Ok(self.as_f64_vec()?.into_iter().map(|f| f as usize).collect())
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_i64s(v: &[i64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- serialisation -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad unicode escape"))?);
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: find the sequence length from the
                    // leading byte and copy it through verbatim
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(t).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -4.25e2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), -425.0);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        for t in ["{", "[1,", "tru", "{\"a\"}", "1 2"] {
            assert!(parse(t).is_err(), "{t} should fail");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé 😀");
        // non-ascii passthrough
        let v = parse("\"héllo😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo😀");
    }

    #[test]
    fn writes_escaped() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn large_number_array() {
        let src: Vec<f64> = (0..10000).map(|i| i as f64 * 0.5 - 100.0).collect();
        let j = Json::from_f64s(&src);
        let back = parse(&j.to_string()).unwrap().as_f64_vec().unwrap();
        assert_eq!(back, src);
    }
}
