//! Lock-order race detector: drop-in [`Mutex`]/[`RwLock`] wrappers that
//! enforce a *declared lock hierarchy* in debug builds and compile to
//! zero-cost passthrough in release.
//!
//! Deadlocks are order bugs: thread A takes `L1` then `L2`, thread B
//! takes `L2` then `L1`, and the process wedges only under the exact
//! interleaving nobody reproduces.  The cure is a total order — every
//! lock carries a [`LockRank`] from the hierarchy declared in [`rank`],
//! and a thread may only acquire locks of *strictly increasing* order.
//! Under `debug_assertions` each thread records its acquisition stack;
//! an out-of-order acquisition panics immediately with both ranks and
//! the full held stack, turning a once-a-month production hang into a
//! deterministic test failure on the *first* run that exercises the
//! inverted order (whichever thread interleaving it gets).
//!
//! In release builds the tracking is compiled out entirely: the wrapper
//! structs hold exactly a `std::sync` lock, the guards hold exactly a
//! `std::sync` guard, and `lock()` is an `#[inline]` forward — the
//! serving hot path pays nothing (`tests::release_mutex_is_zero_cost`
//! pins the layout claim).
//!
//! The API mirrors `std::sync` (`lock()`/`read()`/`write()` return
//! `LockResult`), so the repo's poison-recovery idiom
//! (`.lock().unwrap_or_else(|p| p.into_inner())`) ports unchanged.
//! `tidy` check 5 keeps production modules on these wrappers instead of
//! raw `std::sync` locks.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{self, LockResult, PoisonError};

/// A position in the declared lock hierarchy.  Lower `order` = acquired
/// earlier (outermost); a thread holding order `N` may only acquire
/// locks with order `> N`.  Equal orders are also refused — two locks
/// at the same rank could otherwise AB/BA-deadlock each other, and
/// re-acquiring the *same* lock is a self-deadlock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockRank {
    pub name: &'static str,
    pub order: u16,
}

impl LockRank {
    pub const fn new(name: &'static str, order: u16) -> Self {
        LockRank { name, order }
    }
}

/// The declared lock hierarchy — the single place lock order lives.
///
/// Orders are spaced out so new locks slot between existing ones
/// without renumbering.  Outermost (acquired first, other locks may be
/// taken while held) get low orders; leaf locks (nothing else is ever
/// acquired while they are held) get high orders.  Document *why* a
/// lock sits where it does when adding one; `docs/static-analysis.md`
/// carries the operator-facing copy of this table.
pub mod rank {
    use super::LockRank;

    /// Event-loop intake queue (`server::http`): the acceptor pushes
    /// accepted (and shed) connections here for an event loop to adopt.
    /// Held only for a push/drain of the `VecDeque`, before any request
    /// work starts — outermost of the serving locks.
    pub const HTTP_CONN_QUEUE: LockRank = LockRank::new("http.conn_queue", 100);

    /// Event-loop completion queue (`server::http`): batcher reply
    /// notifications push the finished connection's token here to wake
    /// its event loop.  Held only for a push/drain of the token `Vec`;
    /// ranked above the intake queue because an event loop drains
    /// completions while it may still hold nothing else, and the
    /// notifier side (batcher executor) holds no lock at the send site.
    pub const HTTP_LOOP_COMPLETIONS: LockRank = LockRank::new("http.loop_completions", 200);

    /// Batcher rolling statistics (`server::batcher`): a leaf — plain
    /// counters updated under short critical sections on the admission,
    /// executor and `/stats` paths; no other lock is ever taken while
    /// this one is held.
    pub const BATCH_STATS: LockRank = LockRank::new("batcher.stats", 900);
}

#[cfg(debug_assertions)]
mod tracking {
    use super::LockRank;
    use std::cell::RefCell;

    thread_local! {
        /// Ranks currently held by this thread, in acquisition order.
        static HELD: RefCell<Vec<LockRank>> = const { RefCell::new(Vec::new()) };
    }

    /// Proof of a recorded acquisition; popping happens on drop, so a
    /// guard that outlives its scope keeps its rank on the stack.
    pub(super) struct Held(LockRank);

    pub(super) fn acquire(rank: LockRank) -> Held {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(worst) = held.iter().find(|r| r.order >= rank.order) {
                let stack = held
                    .iter()
                    .map(|r| format!("'{}' ({})", r.name, r.order))
                    .collect::<Vec<_>>()
                    .join(" -> ");
                panic!(
                    "lock order inversion: acquiring '{}' (order {}) while holding \
                     '{}' (order {}); this thread's acquisition stack: [{stack}] — \
                     locks must be taken in strictly increasing order, see the \
                     declared hierarchy in util::lockcheck::rank",
                    rank.name, rank.order, worst.name, worst.order
                );
            }
            held.push(rank);
        });
        Held(rank)
    }

    impl Drop for Held {
        fn drop(&mut self) {
            // try_with: a guard dropped during thread teardown (after the
            // TLS slot is gone) must not turn an orderly exit into an abort
            let _ = HELD.try_with(|h| {
                let mut held = h.borrow_mut();
                // guards may drop out of acquisition order; release the
                // most recent matching entry
                if let Some(i) = held.iter().rposition(|r| *r == self.0) {
                    held.remove(i);
                }
            });
        }
    }
}

// -- Mutex -----------------------------------------------------------------

/// Hierarchy-checked `std::sync::Mutex` (see module docs).
pub struct Mutex<T> {
    #[cfg(debug_assertions)]
    rank: LockRank,
    inner: sync::Mutex<T>,
}

impl<T> Mutex<T> {
    #[cfg_attr(not(debug_assertions), allow(unused_variables))]
    pub const fn new(rank: LockRank, value: T) -> Self {
        Mutex {
            #[cfg(debug_assertions)]
            rank,
            inner: sync::Mutex::new(value),
        }
    }

    /// Acquire, panicking (debug builds only) on a hierarchy violation.
    /// Poison semantics are `std::sync`'s: recover with the usual
    /// `.unwrap_or_else(|p| p.into_inner())`.
    #[inline]
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        #[cfg(debug_assertions)]
        let held = tracking::acquire(self.rank);
        match self.inner.lock() {
            Ok(g) => Ok(MutexGuard {
                inner: g,
                #[cfg(debug_assertions)]
                _held: held,
            }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                inner: p.into_inner(),
                #[cfg(debug_assertions)]
                _held: held,
            })),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

/// Guard for [`Mutex`]; releases the lock *and* pops the rank from the
/// thread's acquisition stack on drop.
pub struct MutexGuard<'a, T> {
    inner: sync::MutexGuard<'a, T>,
    #[cfg(debug_assertions)]
    _held: tracking::Held,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

// -- RwLock ----------------------------------------------------------------

/// Hierarchy-checked `std::sync::RwLock`.  Readers and writers share
/// one rank: a same-thread `read()` while already holding this lock is
/// refused too, because a queued writer between two reader acquisitions
/// deadlocks exactly like an order inversion.
pub struct RwLock<T> {
    #[cfg(debug_assertions)]
    rank: LockRank,
    inner: sync::RwLock<T>,
}

impl<T> RwLock<T> {
    #[cfg_attr(not(debug_assertions), allow(unused_variables))]
    pub const fn new(rank: LockRank, value: T) -> Self {
        RwLock {
            #[cfg(debug_assertions)]
            rank,
            inner: sync::RwLock::new(value),
        }
    }

    #[inline]
    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        #[cfg(debug_assertions)]
        let held = tracking::acquire(self.rank);
        match self.inner.read() {
            Ok(g) => Ok(RwLockReadGuard {
                inner: g,
                #[cfg(debug_assertions)]
                _held: held,
            }),
            Err(p) => Err(PoisonError::new(RwLockReadGuard {
                inner: p.into_inner(),
                #[cfg(debug_assertions)]
                _held: held,
            })),
        }
    }

    #[inline]
    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        #[cfg(debug_assertions)]
        let held = tracking::acquire(self.rank);
        match self.inner.write() {
            Ok(g) => Ok(RwLockWriteGuard {
                inner: g,
                #[cfg(debug_assertions)]
                _held: held,
            }),
            Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                inner: p.into_inner(),
                #[cfg(debug_assertions)]
                _held: held,
            })),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

pub struct RwLockReadGuard<'a, T> {
    inner: sync::RwLockReadGuard<'a, T>,
    #[cfg(debug_assertions)]
    _held: tracking::Held,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.inner
    }
}

pub struct RwLockWriteGuard<'a, T> {
    inner: sync::RwLockWriteGuard<'a, T>,
    #[cfg(debug_assertions)]
    _held: tracking::Held,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // a private test hierarchy, far from the production ranks
    const OUTER: LockRank = LockRank::new("test.outer", 10_000);
    const INNER: LockRank = LockRank::new("test.inner", 10_001);

    #[test]
    fn ordered_acquisition_and_reacquisition_after_drop() {
        let a = Mutex::new(OUTER, 1u32);
        let b = Mutex::new(INNER, 2u32);
        {
            let ga = a.lock().unwrap();
            let gb = b.lock().unwrap();
            assert_eq!(*ga + *gb, 3);
        }
        // both released: the stack must be clean enough to start over
        let gb = b.lock().unwrap();
        drop(gb);
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        assert_eq!(*ga + *gb, 3);
    }

    #[test]
    fn out_of_order_drop_keeps_the_stack_consistent() {
        let a = Mutex::new(OUTER, ());
        let b = Mutex::new(INNER, ());
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        drop(ga); // outer released first: inner stays tracked
        drop(gb);
        let _ga = a.lock().unwrap();
        let _gb = b.lock().unwrap();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "tracking is compiled out in release")]
    #[should_panic(expected = "lock order inversion")]
    fn inverted_acquisition_panics_in_debug() {
        let a = Mutex::new(OUTER, ());
        let b = Mutex::new(INNER, ());
        let _gb = b.lock().unwrap();
        let _ga = a.lock().unwrap(); // order 10_000 while holding 10_001
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "tracking is compiled out in release")]
    #[should_panic(expected = "lock order inversion")]
    fn same_rank_reacquisition_panics_in_debug() {
        // self-deadlock: re-locking the same mutex on one thread
        let a = Mutex::new(OUTER, ());
        let _g1 = a.lock().unwrap();
        let _g2 = a.lock().unwrap();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "tracking is compiled out in release")]
    #[should_panic(expected = "lock order inversion")]
    fn rwlock_inversion_panics_in_debug() {
        let a = RwLock::new(OUTER, ());
        let b = Mutex::new(INNER, ());
        let _gb = b.lock().unwrap();
        let _ga = a.read().unwrap();
    }

    #[test]
    fn rwlock_ordered_read_then_inner_write() {
        let a = RwLock::new(OUTER, 5u32);
        let b = RwLock::new(INNER, 0u32);
        let ga = a.read().unwrap();
        {
            let mut gb = b.write().unwrap();
            *gb = *ga;
        }
        drop(ga);
        assert_eq!(*b.read().unwrap(), 5);
    }

    #[test]
    fn hierarchy_is_per_thread() {
        // thread A holds INNER while thread B takes OUTER: no inversion —
        // the order constraint is within one thread's acquisition stack
        let a = std::sync::Arc::new(Mutex::new(OUTER, ()));
        let b = std::sync::Arc::new(Mutex::new(INNER, ()));
        let _gb = b.lock().unwrap();
        let a2 = a.clone();
        std::thread::spawn(move || {
            let _ga = a2.lock().unwrap();
        })
        .join()
        .expect("cross-thread acquisition must not panic");
    }

    #[test]
    fn poisoned_lock_recovers_with_the_std_idiom() {
        let a = std::sync::Arc::new(Mutex::new(OUTER, 7u32));
        let a2 = a.clone();
        let _ = std::thread::spawn(move || {
            let _g = a2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let g = a.lock().unwrap_or_else(|p| p.into_inner());
        assert_eq!(*g, 7);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "passthrough is the release-build contract")]
    fn release_inversion_is_passthrough() {
        // in release the inverted order must NOT panic: tracking is
        // compiled out and the wrapper is a plain std lock
        let a = Mutex::new(OUTER, 1u32);
        let b = Mutex::new(INNER, 2u32);
        let gb = b.lock().unwrap();
        let ga = a.lock().unwrap();
        assert_eq!(*ga + *gb, 3);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "layout claim only holds in release")]
    fn release_mutex_is_zero_cost() {
        use std::mem::size_of;
        assert_eq!(size_of::<Mutex<u64>>(), size_of::<sync::Mutex<u64>>());
        assert_eq!(size_of::<RwLock<u64>>(), size_of::<sync::RwLock<u64>>());
        assert_eq!(
            size_of::<MutexGuard<'static, u64>>(),
            size_of::<sync::MutexGuard<'static, u64>>()
        );
    }
}
