//! Minimal stderr logger backing the `log` crate facade.
//! Level from `LRAM_LOG` (error|warn|info|debug|trace), default info.

use std::sync::Once;

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let now = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0);
            eprintln!(
                "[{:13.3} {:5} {}] {}",
                now,
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;
static INIT: Once = Once::new();

/// Install the logger (idempotent).
pub fn init() {
    INIT.call_once(|| {
        let level = match std::env::var("LRAM_LOG").as_deref() {
            Ok("error") => log::LevelFilter::Error,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("trace") => log::LevelFilter::Trace,
            _ => log::LevelFilter::Info,
        };
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(level);
    });
}
