//! Anonymous/file-backed memory maps via libc (`memmap2` unavailable).
//!
//! The memstore uses lazily-populated anonymous maps so a "billion
//! parameter" value table costs physical memory only for pages actually
//! touched — the honest CPU analogue of allocating a huge HBM tensor and
//! accessing 32 rows per query.

use std::fs::OpenOptions;
use std::os::unix::io::AsRawFd;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// An owned mmap'd region of `f32`s.
pub struct MmapF32 {
    ptr: *mut f32,
    len: usize, // in f32 elements
}

// SAFETY: the region is owned and pages are plain memory; concurrent
// readers are fine, writers must hold external synchronisation (the
// memstore shards guarantee this).
unsafe impl Send for MmapF32 {}
unsafe impl Sync for MmapF32 {}

impl MmapF32 {
    /// Anonymous zero-initialised map of `len` f32 elements.
    pub fn anon(len: usize) -> Result<Self> {
        if len == 0 {
            bail!("mmap of zero length");
        }
        let bytes = len * 4;
        // SAFETY: standard anonymous private mapping.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap({} bytes) failed: {}", bytes, std::io::Error::last_os_error());
        }
        Ok(MmapF32 { ptr: ptr as *mut f32, len })
    }

    /// File-backed map (created/truncated to size) for persistence.
    pub fn file(path: &Path, len: usize) -> Result<Self> {
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        f.set_len((len * 4) as u64)?;
        // SAFETY: shared file mapping of the exact file length.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len * 4,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap file failed: {}", std::io::Error::last_os_error());
        }
        Ok(MmapF32 { ptr: ptr as *mut f32, len })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: region is valid for len elements for the lifetime of self.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    #[allow(clippy::mut_from_ref)]
    #[allow(dead_code)]
    pub(crate) unsafe fn as_mut_slice_unchecked(&self) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: exclusive borrow of self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    /// Resident-set estimate: how many pages of the map are actually
    /// backed by physical memory (Table-5-style utilisation accounting).
    pub fn resident_bytes(&self) -> Result<usize> {
        let page = 4096usize;
        let bytes = self.len * 4;
        let pages = bytes.div_ceil(page);
        let mut vec = vec![0u8; pages];
        // SAFETY: mincore over our own mapping.
        let rc = unsafe {
            libc::mincore(self.ptr as *mut libc::c_void, bytes, vec.as_mut_ptr())
        };
        if rc != 0 {
            bail!("mincore failed: {}", std::io::Error::last_os_error());
        }
        Ok(vec.iter().filter(|&&b| b & 1 != 0).count() * page)
    }
}

impl Drop for MmapF32 {
    fn drop(&mut self) {
        // SAFETY: unmapping the region we mapped.
        unsafe {
            libc::munmap(self.ptr as *mut libc::c_void, self.len * 4);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anon_map_reads_zero_writes_back() {
        let mut m = MmapF32::anon(1 << 20).unwrap();
        assert_eq!(m.as_slice()[12345], 0.0);
        m.as_mut_slice()[12345] = 3.5;
        assert_eq!(m.as_slice()[12345], 3.5);
    }

    #[test]
    fn huge_map_is_lazy() {
        // 4 GB virtual, but only touched pages go resident
        let m = MmapF32::anon(1 << 30).unwrap();
        let before = m.resident_bytes().unwrap();
        // SAFETY: test-only single-threaded write
        unsafe { m.as_mut_slice_unchecked()[1 << 29] = 1.0 };
        let after = m.resident_bytes().unwrap();
        assert!(after >= before);
        assert!(after < (1 << 26), "resident {after} unexpectedly large");
    }

    #[test]
    fn file_map_persists() {
        let dir = std::env::temp_dir().join(format!("lram_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("table.bin");
        {
            let mut m = MmapF32::file(&path, 1024).unwrap();
            m.as_mut_slice()[7] = 2.25;
        }
        let m = MmapF32::file(&path, 1024).unwrap();
        assert_eq!(m.as_slice()[7], 2.25);
        std::fs::remove_dir_all(&dir).ok();
    }
}
