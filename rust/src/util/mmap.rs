//! Anonymous/file-backed memory maps via libc (`memmap2` unavailable).
//!
//! The memstore uses lazily-populated anonymous maps so a "billion
//! parameter" value table costs physical memory only for pages actually
//! touched — the honest CPU analogue of allocating a huge HBM tensor and
//! accessing 32 rows per query.  [`MmapF32`] backs the value tables and
//! optimizer moments; [`MmapU32`] backs per-row integer side tables (the
//! sparse-Adam step counts) with the same lazy semantics.

use std::fs::OpenOptions;
use std::os::unix::io::AsRawFd;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// An owned raw mmap'd byte region.  The typed wrappers below expose it
/// as element slices; this struct owns the mapping and its lifetime.
struct RawMap {
    ptr: *mut libc::c_void,
    bytes: usize,
    /// Whether this (file-backed) range holds a `util::sigbus` registry
    /// slot.  Anonymous maps never register — they cannot SIGBUS — so
    /// lib tests that only touch anon maps never install a handler.
    registered: bool,
}

// SAFETY: the region is owned and pages are plain memory; moving the
// owning struct across threads moves only the pointer, never the pages.
unsafe impl Send for RawMap {}
// SAFETY: concurrent readers of the mapping are fine; writers must hold
// external synchronisation (the memstore shards guarantee this).
unsafe impl Sync for RawMap {}

impl RawMap {
    /// Anonymous zero-initialised lazily-populated map of `bytes` bytes.
    fn anon(bytes: usize) -> Result<Self> {
        if bytes == 0 {
            bail!("mmap of zero length");
        }
        // SAFETY: standard anonymous private mapping.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_NORESERVE,
                -1,
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap({} bytes) failed: {}", bytes, std::io::Error::last_os_error());
        }
        Ok(RawMap { ptr, bytes, registered: false })
    }

    /// Copy-on-write map of an *existing* file: reads come zero-copy from
    /// the page cache, writes land in private anonymous pages and never
    /// reach the file.  This is how checkpoints serve a multi-GB value
    /// table without reading it into RAM — and without any risk of a
    /// serving-path write corrupting the checkpoint on disk.
    ///
    /// The file must be exactly `bytes` long; a shorter file is a
    /// truncated checkpoint and mapping it would turn reads past EOF
    /// into SIGBUS, so the mismatch is an explicit error instead.
    fn file_cow(path: &Path, bytes: usize) -> Result<Self> {
        if bytes == 0 {
            bail!("mmap of zero length");
        }
        let f = OpenOptions::new()
            .read(true)
            .open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let actual = f.metadata()?.len();
        if actual != bytes as u64 {
            bail!(
                "{}: expected {} bytes, file has {} (truncated or corrupt checkpoint?)",
                path.display(),
                bytes,
                actual
            );
        }
        // SAFETY: private (copy-on-write) mapping of the exact file length.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_NORESERVE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap cow failed: {}", std::io::Error::last_os_error());
        }
        let mut map = RawMap { ptr, bytes, registered: false };
        // Re-validate the length against the *mapped* fd (fstat): a file
        // that shrank between the metadata check above and the mmap —
        // concurrent truncation, a checkpoint pruned mid-open — would
        // otherwise SIGBUS on the first page access past EOF, which
        // `catch_unwind` cannot contain.  Refuse loudly at map time
        // instead; the bailed map unmaps itself on drop.
        let now = f.metadata()?.len();
        if now != bytes as u64 {
            bail!(
                "{}: file shrank to {} bytes while mapping {} (concurrent \
                 truncation?); refusing a mapping that would SIGBUS on access",
                path.display(),
                now,
                bytes
            );
        }
        // The length checks above close the open→map window, but a file
        // truncated *after* this point still SIGBUSes on access to a
        // page past the new EOF — register the range so the handler can
        // contain that fault (zeros + fault-epoch bump) instead of
        // letting it kill the process.
        map.registered = crate::util::sigbus::register(ptr as usize, bytes);
        Ok(map)
    }

    /// File-backed map (created/truncated to size) for persistence.
    fn file(path: &Path, bytes: usize) -> Result<Self> {
        if bytes == 0 {
            bail!("mmap of zero length");
        }
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        f.set_len(bytes as u64)?;
        // SAFETY: shared file mapping of the exact file length.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap file failed: {}", std::io::Error::last_os_error());
        }
        let registered = crate::util::sigbus::register(ptr as usize, bytes);
        Ok(RawMap { ptr, bytes, registered })
    }

    /// Resident-set estimate: how many pages of the map are actually
    /// backed by physical memory (Table-5-style utilisation accounting).
    fn resident_bytes(&self) -> Result<usize> {
        let page = 4096usize;
        let pages = self.bytes.div_ceil(page);
        let mut vec = vec![0u8; pages];
        // SAFETY: mincore over our own mapping.
        let rc = unsafe { libc::mincore(self.ptr, self.bytes, vec.as_mut_ptr()) };
        if rc != 0 {
            bail!("mincore failed: {}", std::io::Error::last_os_error());
        }
        Ok(vec.iter().filter(|&&b| b & 1 != 0).count() * page)
    }
}

impl Drop for RawMap {
    fn drop(&mut self) {
        // Unregister before unmapping so the SIGBUS handler never remaps
        // a page of an address range that may be reused by a later map.
        if self.registered {
            crate::util::sigbus::unregister(self.ptr as usize);
        }
        // SAFETY: unmapping the region we mapped.
        unsafe {
            libc::munmap(self.ptr, self.bytes);
        }
    }
}

/// Byte size of `len` 4-byte elements, rejecting address-space overflow.
fn elem_bytes(len: usize) -> Result<usize> {
    len.checked_mul(4).ok_or_else(|| anyhow::anyhow!("mmap size overflow: {len} elements"))
}

/// An owned mmap'd region of `f32`s.
pub struct MmapF32 {
    raw: RawMap,
    len: usize, // in f32 elements
}

impl MmapF32 {
    /// Anonymous zero-initialised map of `len` f32 elements.
    pub fn anon(len: usize) -> Result<Self> {
        Ok(MmapF32 { raw: RawMap::anon(elem_bytes(len)?)?, len })
    }

    /// File-backed map (created/truncated to size) for persistence.
    pub fn file(path: &Path, len: usize) -> Result<Self> {
        Ok(MmapF32 { raw: RawMap::file(path, elem_bytes(len)?)?, len })
    }

    /// Copy-on-write map of an existing file of exactly `len` f32s —
    /// zero-copy checkpoint reads; writes never touch the file.
    pub fn open_cow(path: &Path, len: usize) -> Result<Self> {
        Ok(MmapF32 { raw: RawMap::file_cow(path, elem_bytes(len)?)?, len })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: region is valid for len elements for the lifetime of self.
        unsafe { std::slice::from_raw_parts(self.raw.ptr as *const f32, self.len) }
    }

    /// Mutable view without an exclusive borrow.
    ///
    /// # Safety
    ///
    /// The caller must guarantee no other reference (shared or mutable)
    /// to any element of the mapping is live or created for the
    /// lifetime of the returned slice — the usual `&mut` aliasing rules,
    /// enforced by the caller instead of the borrow checker.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    #[allow(dead_code)]
    pub(crate) unsafe fn as_mut_slice_unchecked(&self) -> &mut [f32] {
        // SAFETY: region is valid for len elements for the lifetime of
        // self; exclusivity is the caller's contract (see above).
        std::slice::from_raw_parts_mut(self.raw.ptr as *mut f32, self.len)
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: exclusive borrow of self.
        unsafe { std::slice::from_raw_parts_mut(self.raw.ptr as *mut f32, self.len) }
    }

    /// Physically-resident bytes of the mapping.
    pub fn resident_bytes(&self) -> Result<usize> {
        self.raw.resident_bytes()
    }
}

/// An owned anonymous mmap'd region of `u32`s — lazily-populated integer
/// side tables (e.g. the sparse-Adam per-row step counts), so a
/// billion-row optimizer costs physical memory only for rows touched.
pub struct MmapU32 {
    raw: RawMap,
    len: usize, // in u32 elements
}

impl MmapU32 {
    /// Anonymous zero-initialised map of `len` u32 elements.
    pub fn anon(len: usize) -> Result<Self> {
        Ok(MmapU32 { raw: RawMap::anon(elem_bytes(len)?)?, len })
    }

    /// Copy-on-write map of an existing file of exactly `len` u32s
    /// (checkpointed optimizer step counts).
    pub fn open_cow(path: &Path, len: usize) -> Result<Self> {
        Ok(MmapU32 { raw: RawMap::file_cow(path, elem_bytes(len)?)?, len })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[u32] {
        // SAFETY: region is valid for len elements for the lifetime of self.
        unsafe { std::slice::from_raw_parts(self.raw.ptr as *const u32, self.len) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [u32] {
        // SAFETY: exclusive borrow of self.
        unsafe { std::slice::from_raw_parts_mut(self.raw.ptr as *mut u32, self.len) }
    }

    /// Physically-resident bytes of the mapping.
    pub fn resident_bytes(&self) -> Result<usize> {
        self.raw.resident_bytes()
    }
}

/// An owned mmap'd region of `i8`s — int8-quantized value rows
/// (per-row-scaled, see `memstore::QuantizedValueTable`), mapped
/// zero-copy from checkpoints exactly like the f32 tables.
pub struct MmapI8 {
    raw: RawMap,
    len: usize, // in i8 elements
}

impl MmapI8 {
    /// Anonymous zero-initialised map of `len` i8 elements.
    pub fn anon(len: usize) -> Result<Self> {
        Ok(MmapI8 { raw: RawMap::anon(len)?, len })
    }

    /// Copy-on-write map of an existing file of exactly `len` i8s —
    /// zero-copy checkpoint reads; writes never touch the file.
    pub fn open_cow(path: &Path, len: usize) -> Result<Self> {
        Ok(MmapI8 { raw: RawMap::file_cow(path, len)?, len })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[i8] {
        // SAFETY: region is valid for len elements for the lifetime of self.
        unsafe { std::slice::from_raw_parts(self.raw.ptr as *const i8, self.len) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [i8] {
        // SAFETY: exclusive borrow of self.
        unsafe { std::slice::from_raw_parts_mut(self.raw.ptr as *mut i8, self.len) }
    }

    /// Physically-resident bytes of the mapping.
    pub fn resident_bytes(&self) -> Result<usize> {
        self.raw.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i8_map_roundtrips_and_cow_rejects_wrong_length() {
        let mut m = MmapI8::anon(1024).unwrap();
        assert_eq!(m.as_slice()[100], 0);
        m.as_mut_slice()[100] = -117;
        assert_eq!(m.as_slice()[100], -117);

        let dir = std::env::temp_dir().join(format!("lram_i8_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("q8.bin");
        std::fs::write(&path, [0x7fu8; 64]).unwrap();
        let c = MmapI8::open_cow(&path, 64).unwrap();
        assert_eq!(c.as_slice()[63], 127);
        assert!(MmapI8::open_cow(&path, 65).is_err(), "short file must be refused");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn anon_map_reads_zero_writes_back() {
        let mut m = MmapF32::anon(1 << 20).unwrap();
        assert_eq!(m.as_slice()[12345], 0.0);
        m.as_mut_slice()[12345] = 3.5;
        assert_eq!(m.as_slice()[12345], 3.5);
    }

    #[test]
    fn huge_map_is_lazy() {
        // 4 GB virtual, but only touched pages go resident
        let m = MmapF32::anon(1 << 30).unwrap();
        let before = m.resident_bytes().unwrap();
        // SAFETY: test-only single-threaded write
        unsafe { m.as_mut_slice_unchecked()[1 << 29] = 1.0 };
        let after = m.resident_bytes().unwrap();
        assert!(after >= before);
        assert!(after < (1 << 26), "resident {after} unexpectedly large");
    }

    #[test]
    fn file_map_persists() {
        let dir = std::env::temp_dir().join(format!("lram_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("table.bin");
        {
            let mut m = MmapF32::file(&path, 1024).unwrap();
            m.as_mut_slice()[7] = 2.25;
        }
        let m = MmapF32::file(&path, 1024).unwrap();
        assert_eq!(m.as_slice()[7], 2.25);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cow_map_reads_file_but_never_writes_it() {
        let dir = std::env::temp_dir().join(format!("lram_cow_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cow.bin");
        {
            let mut m = MmapF32::file(&path, 256).unwrap();
            m.as_mut_slice()[3] = 1.5;
        }
        let mut cow = MmapF32::open_cow(&path, 256).unwrap();
        assert_eq!(cow.as_slice()[3], 1.5);
        cow.as_mut_slice()[3] = 99.0; // private page, not the file
        drop(cow);
        let again = MmapF32::open_cow(&path, 256).unwrap();
        assert_eq!(again.as_slice()[3], 1.5, "cow write leaked into the file");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cow_map_rejects_wrong_length() {
        let dir = std::env::temp_dir().join(format!("lram_cowlen_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("short.bin");
        std::fs::write(&path, [0u8; 100]).unwrap();
        // 100 bytes is not 256 f32s: must error, not SIGBUS later
        let err = MmapF32::open_cow(&path, 256).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        assert!(MmapU32::open_cow(&path, 256).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn u32_map_is_lazy_and_writable() {
        // 1 GB of virtual step counts; only touched pages go resident
        let mut m = MmapU32::anon(1 << 28).unwrap();
        assert_eq!(m.as_slice()[999], 0);
        m.as_mut_slice()[1 << 27] = 42;
        assert_eq!(m.as_slice()[1 << 27], 42);
        let resident = m.resident_bytes().unwrap();
        assert!(resident < (1 << 26), "resident {resident} unexpectedly large");
    }
}
