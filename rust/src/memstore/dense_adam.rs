//! Dense Adam for small per-model tensors — the routing projection `wq`.
//!
//! [`super::SparseAdam`] exists to amortise optimizer state over
//! billion-row value tables; the query projection is a few KB that is
//! touched on every step, so a plain dense Adam with one shared step
//! count is the right tool.  Same contract as the sparse optimizer:
//! state (moments + step count) round-trips through checkpoints so a
//! resumed run is bit-identical to an uninterrupted one.

use anyhow::{ensure, Result};

pub struct DenseAdam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl DenseAdam {
    pub fn new(n: usize, lr: f32) -> Self {
        DenseAdam {
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    /// One update over the full tensor.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), self.m.len());
        debug_assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// Rebuild from checkpointed state (moments + shared step count).
    pub fn from_state(m: Vec<f32>, v: Vec<f32>, t: u64, lr: f32) -> Result<Self> {
        ensure!(
            m.len() == v.len(),
            "moment vectors disagree: {} vs {}",
            m.len(),
            v.len()
        );
        Ok(DenseAdam { m, v, t, lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 })
    }

    /// Checkpoint accessors: moments and the shared step count.
    pub fn first_moment(&self) -> &[f32] {
        &self.m
    }

    pub fn second_moment(&self) -> &[f32] {
        &self.v
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    pub fn len(&self) -> usize {
        self.m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // minimise 0.5 * ||x - target||^2 via its gradient
        let target = [1.0f32, -2.0, 0.5, 3.0];
        let mut x = [0.0f32; 4];
        let mut opt = DenseAdam::new(4, 1e-2);
        for _ in 0..2000 {
            let grad: Vec<f32> = x.iter().zip(&target).map(|(a, t)| a - t).collect();
            opt.step(&mut x, &grad);
        }
        for (a, b) in x.iter().zip(&target) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        assert_eq!(opt.step_count(), 2000);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // Adam's first update has magnitude ~lr regardless of grad scale
        let mut x = [0.0f32; 2];
        let mut opt = DenseAdam::new(2, 1e-3);
        opt.step(&mut x, &[100.0, -0.001]);
        assert!((x[0] + 1e-3).abs() < 1e-5, "{}", x[0]);
        assert!((x[1] - 1e-3).abs() < 1e-5, "{}", x[1]);
    }

    #[test]
    fn from_state_resumes_bias_correction_bit_identically() {
        let mut xa = [0.5f32; 3];
        let mut xb = [0.5f32; 3];
        let mut opt = DenseAdam::new(3, 1e-2);
        for _ in 0..5 {
            opt.step(&mut xa, &[1.0, -1.0, 0.25]);
        }
        xb.copy_from_slice(&xa);
        let mut resumed = DenseAdam::from_state(
            opt.first_moment().to_vec(),
            opt.second_moment().to_vec(),
            opt.step_count(),
            1e-2,
        )
        .unwrap();
        opt.step(&mut xa, &[0.5, 0.5, 0.5]);
        resumed.step(&mut xb, &[0.5, 0.5, 0.5]);
        for (a, b) in xa.iter().zip(&xb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn from_state_rejects_mismatched_shapes() {
        assert!(DenseAdam::from_state(vec![0.0; 4], vec![0.0; 3], 1, 1e-3).is_err());
    }
}
