//! Sparse Adam over the value table (paper §3.2: memory parameters train
//! with lr 1e-3 "to compensate for sparse access").
//!
//! Moments are stored per *row* in two side tables and updated lazily:
//! a row's bias-correction uses its own update count, the standard
//! lazy-sparse-Adam approximation (only touched rows pay any work, so a
//! step costs O(k) regardless of M).
//!
//! Every side table — the two moment tables *and* the per-row step
//! counts — lives in a lazily-populated mmap, so constructing the
//! optimizer for a billion-row value table is as cheap as constructing
//! the table itself: physical memory is only paid for rows that are
//! actually updated.

use anyhow::Result;

use super::table::ValueTable;
use crate::util::mmap::MmapU32;

pub struct SparseAdam {
    m: ValueTable,
    v: ValueTable,
    /// per-row update counts (for lazy bias correction), lazily mapped —
    /// an eager `vec![0; rows]` here would cost 4 GB resident for a
    /// billion-row table and defeat the lazy design
    t: MmapU32,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl SparseAdam {
    pub fn new(rows: u64, dim: usize, lr: f32) -> Result<Self> {
        Ok(SparseAdam {
            m: ValueTable::zeros(rows, dim)?,
            v: ValueTable::zeros(rows, dim)?,
            t: MmapU32::anon(rows as usize)?,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        })
    }

    /// Apply the gradient `grad` to row `idx` of `table`.
    pub fn update_row(&mut self, table: &mut ValueTable, idx: u64, grad: &[f32]) {
        debug_assert_eq!(grad.len(), table.dim());
        let steps = &mut self.t.as_mut_slice()[idx as usize];
        *steps += 1;
        let t = *steps as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let mrow = self.m.row_mut(idx);
        for (mi, &g) in mrow.iter_mut().zip(grad) {
            *mi = self.beta1 * *mi + (1.0 - self.beta1) * g;
        }
        let vrow = self.v.row_mut(idx);
        for (vi, &g) in vrow.iter_mut().zip(grad) {
            *vi = self.beta2 * *vi + (1.0 - self.beta2) * g * g;
        }
        let (m, v) = (self.m.row(idx), self.v.row(idx));
        let prow = table.row_mut(idx);
        for i in 0..prow.len() {
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            prow[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// Rebuild an optimizer from checkpointed state (the moment tables
    /// typically arrive as copy-on-write maps of the checkpoint blobs,
    /// so resuming a billion-row optimizer is as lazy as creating one).
    pub fn from_state(m: ValueTable, v: ValueTable, t: MmapU32, lr: f32) -> Result<Self> {
        anyhow::ensure!(
            m.rows() == v.rows() && m.dim() == v.dim(),
            "moment tables disagree: {}x{} vs {}x{}",
            m.rows(),
            m.dim(),
            v.rows(),
            v.dim()
        );
        anyhow::ensure!(
            t.len() as u64 == m.rows(),
            "step-count table has {} rows, moments have {}",
            t.len(),
            m.rows()
        );
        Ok(SparseAdam { m, v, t, lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 })
    }

    /// Checkpoint accessors: first/second moment tables and step counts.
    pub fn first_moment(&self) -> &ValueTable {
        &self.m
    }

    pub fn second_moment(&self) -> &ValueTable {
        &self.v
    }

    pub fn step_counts(&self) -> &[u32] {
        self.t.as_slice()
    }

    /// Accumulated update count of a row (observability).
    pub fn row_steps(&self, idx: u64) -> u32 {
        self.t.as_slice()[idx as usize]
    }

    /// Physically-resident bytes over all optimizer state (moments +
    /// step counts) — the lazy-allocation regression gauge.
    pub fn resident_bytes(&self) -> Result<usize> {
        Ok(self.m.resident_bytes()?
            + self.v.resident_bytes()?
            + self.t.resident_bytes()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic_on_touched_rows() {
        // minimise 0.5 * ||row - target||^2 for one row via its gradient
        let mut table = ValueTable::zeros(32, 4).unwrap();
        let mut opt = SparseAdam::new(32, 4, 1e-2).unwrap();
        let target = [1.0f32, -2.0, 0.5, 3.0];
        for _ in 0..2000 {
            let row = table.row(5);
            let grad: Vec<f32> = row.iter().zip(&target).map(|(r, t)| r - t).collect();
            opt.update_row(&mut table, 5, &grad);
        }
        for (a, b) in table.row(5).iter().zip(&target) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        // untouched rows stay zero and unpaid
        assert_eq!(table.row(6), &[0.0; 4]);
        assert_eq!(opt.row_steps(6), 0);
        assert_eq!(opt.row_steps(5), 2000);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // Adam's first update has magnitude ~lr regardless of grad scale
        let mut table = ValueTable::zeros(4, 2).unwrap();
        let mut opt = SparseAdam::new(4, 2, 1e-3).unwrap();
        opt.update_row(&mut table, 0, &[100.0, -0.001]);
        let r = table.row(0);
        assert!((r[0] + 1e-3).abs() < 1e-5, "{}", r[0]);
        assert!((r[1] - 1e-3).abs() < 1e-5, "{}", r[1]);
    }

    #[test]
    fn from_state_resumes_bias_correction() {
        // an optimizer rebuilt from its own state must continue exactly
        // where the original would have gone
        let mut table_a = ValueTable::zeros(8, 2).unwrap();
        let mut table_b = ValueTable::zeros(8, 2).unwrap();
        let mut opt = SparseAdam::new(8, 2, 1e-2).unwrap();
        for _ in 0..5 {
            opt.update_row(&mut table_a, 3, &[1.0, -1.0]);
            table_b.row_mut(3).copy_from_slice(table_a.row(3));
        }
        // clone state into a fresh optimizer
        let mut m = ValueTable::zeros(8, 2).unwrap();
        let mut v = ValueTable::zeros(8, 2).unwrap();
        let mut t = MmapU32::anon(8).unwrap();
        for r in 0..8u64 {
            m.row_mut(r).copy_from_slice(opt.first_moment().row(r));
            v.row_mut(r).copy_from_slice(opt.second_moment().row(r));
        }
        t.as_mut_slice().copy_from_slice(opt.step_counts());
        let mut resumed = SparseAdam::from_state(m, v, t, 1e-2).unwrap();
        assert_eq!(resumed.row_steps(3), 5);
        opt.update_row(&mut table_a, 3, &[0.5, 0.5]);
        resumed.update_row(&mut table_b, 3, &[0.5, 0.5]);
        assert_eq!(table_a.row(3), table_b.row(3));
    }

    #[test]
    fn from_state_rejects_mismatched_shapes() {
        let m = ValueTable::zeros(8, 2).unwrap();
        let v = ValueTable::zeros(4, 2).unwrap();
        let t = MmapU32::anon(8).unwrap();
        assert!(SparseAdam::from_state(m, v, t, 1e-3).is_err());
    }

    #[test]
    fn billion_parameter_optimizer_is_cheap_until_touched() {
        // the optimizer-side companion of
        // `billion_parameter_table_is_cheap_until_touched`: 2^24 rows x 64
        // means 2 x 4 GB of virtual moments plus 64 MB of virtual step
        // counts — none of it may be resident before rows are updated
        let mut table = ValueTable::zeros(1 << 24, 64).unwrap();
        let mut opt = SparseAdam::new(1 << 24, 64, 1e-3).unwrap();
        let before = opt.resident_bytes().unwrap();
        assert!(before < 64 << 20, "resident {before} before any update");
        let grad = [1.0f32; 64];
        opt.update_row(&mut table, 12_345_678, &grad);
        assert_eq!(opt.row_steps(12_345_678), 1);
        let after = opt.resident_bytes().unwrap();
        assert!(after < 64 << 20, "resident {after} after one sparse update");
    }
}
