//! Access accounting for Table 5: fraction of memory locations touched
//! and the KL divergence between the weighted access distribution and
//! uniform.

/// Streaming per-slot access statistics.
pub struct AccessStats {
    weighted: Vec<f64>,
    hits: Vec<u64>,
    total_weight: f64,
    total_hits: u64,
    /// distinct slots hit at least once, maintained incrementally so
    /// `utilization()` is O(1) — serving polls it after every batch
    used: u64,
}

impl AccessStats {
    pub fn new(locations: u64) -> Self {
        AccessStats {
            weighted: vec![0.0; locations as usize],
            hits: vec![0; locations as usize],
            total_weight: 0.0,
            total_hits: 0,
            used: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, index: u64, weight: f64) {
        if weight <= 0.0 {
            return; // padded top-k entries are not real accesses
        }
        self.weighted[index as usize] += weight;
        if self.hits[index as usize] == 0 {
            self.used += 1;
        }
        self.hits[index as usize] += 1;
        self.total_weight += weight;
        self.total_hits += 1;
    }

    pub fn record_batch(&mut self, indices: &[u64], weights: &[f64]) {
        for (&i, &w) in indices.iter().zip(weights) {
            self.record(i, w);
        }
    }

    /// Batched accounting straight off the serving-path SoA buffers
    /// (f32 weights, zero = padded hit), avoiding a per-hit call in the
    /// gather loop.
    pub fn record_batch_f32(&mut self, indices: &[u64], weights: &[f32]) {
        for (&i, &w) in indices.iter().zip(weights) {
            self.record(i, w as f64);
        }
    }

    pub fn locations(&self) -> u64 {
        self.weighted.len() as u64
    }

    /// Fraction of memory locations accessed at least once ("Memory
    /// usage %" row of Table 5).  O(1): the distinct-slot count is
    /// maintained incrementally by [`Self::record`].
    pub fn utilization(&self) -> f64 {
        self.used as f64 / self.hits.len() as f64
    }

    /// KL(access || uniform) in nats, over the *weighted* distribution
    /// (Table 5, following Lample et al. 2019).
    pub fn kl_from_uniform(&self) -> f64 {
        if self.total_weight <= 0.0 {
            return 0.0;
        }
        let n = self.weighted.len() as f64;
        let mut kl = 0.0;
        for &w in &self.weighted {
            if w > 0.0 {
                let p = w / self.total_weight;
                kl += p * (p * n).ln();
            }
        }
        kl
    }

    pub fn total_accesses(&self) -> u64 {
        self.total_hits
    }

    /// [`Self::utilization`] restricted to a row range — per-shard
    /// utilization for `/stats` under sharded serving.  O(range len).
    pub fn utilization_in(&self, range: std::ops::Range<u64>) -> f64 {
        let lo = (range.start as usize).min(self.hits.len());
        let hi = (range.end as usize).min(self.hits.len());
        if lo >= hi {
            return 0.0;
        }
        let used = self.hits[lo..hi].iter().filter(|&&h| h > 0).count();
        used as f64 / (hi - lo) as f64
    }

    /// Total accesses landing in a row range (per-shard `/stats`).
    pub fn hits_in(&self, range: std::ops::Range<u64>) -> u64 {
        let lo = (range.start as usize).min(self.hits.len());
        let hi = (range.end as usize).min(self.hits.len());
        if lo >= hi {
            return 0;
        }
        self.hits[lo..hi].iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_access_has_zero_kl_and_full_utilization() {
        let mut s = AccessStats::new(64);
        for i in 0..64 {
            s.record(i, 1.0);
        }
        assert_eq!(s.utilization(), 1.0);
        assert!(s.kl_from_uniform().abs() < 1e-12);
    }

    #[test]
    fn concentrated_access_has_high_kl() {
        let mut s = AccessStats::new(1024);
        for _ in 0..100 {
            s.record(7, 1.0);
        }
        assert!((s.utilization() - 1.0 / 1024.0).abs() < 1e-12);
        // all mass on one of 1024 slots: KL = ln(1024)
        assert!((s.kl_from_uniform() - (1024f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn zero_weight_entries_ignored() {
        let mut s = AccessStats::new(16);
        s.record(3, 0.0);
        assert_eq!(s.total_accesses(), 0);
        assert_eq!(s.utilization(), 0.0);
    }

    #[test]
    fn utilization_counts_distinct_slots_incrementally() {
        let mut s = AccessStats::new(8);
        s.record(2, 1.0);
        s.record(2, 0.5); // repeat hit: still one distinct slot
        s.record(5, 0.25);
        s.record(6, 0.0); // zero weight: not an access
        assert!((s.utilization() - 2.0 / 8.0).abs() < 1e-12);
        assert_eq!(s.total_accesses(), 3);
    }

    #[test]
    fn range_restricted_stats_match_per_shard_expectations() {
        let mut s = AccessStats::new(16);
        s.record(1, 1.0);
        s.record(1, 1.0);
        s.record(3, 0.5);
        s.record(9, 0.25);
        // shard [0, 8): rows 1 and 3 used, 3 accesses
        assert!((s.utilization_in(0..8) - 2.0 / 8.0).abs() < 1e-12);
        assert_eq!(s.hits_in(0..8), 3);
        // shard [8, 16): row 9 used, 1 access
        assert!((s.utilization_in(8..16) - 1.0 / 8.0).abs() < 1e-12);
        assert_eq!(s.hits_in(8..16), 1);
        // empty and out-of-range requests degrade to zero
        assert_eq!(s.utilization_in(4..4), 0.0);
        assert_eq!(s.hits_in(16..32), 0);
    }

    #[test]
    fn kl_is_scale_invariant_in_weights() {
        let mut a = AccessStats::new(32);
        let mut b = AccessStats::new(32);
        for i in 0..32 {
            let w = 1.0 + (i % 5) as f64;
            a.record(i, w);
            b.record(i, 10.0 * w);
        }
        assert!((a.kl_from_uniform() - b.kl_from_uniform()).abs() < 1e-12);
    }
}
