//! The value table: `M x m` f32 rows with O(1) row access.

use std::path::Path;

use anyhow::{bail, Context as _, Result};

use crate::util::mmap::{MmapF32, MmapI8};
use crate::util::rng::Rng;

/// A flat `M x m` table of value vectors backed by a lazily-populated
/// memory map (anonymous by default, file-backed for persistence).
pub struct ValueTable {
    map: MmapF32,
    rows: u64,
    dim: usize,
}

impl ValueTable {
    /// Zero-initialised anonymous table.  Virtual size may exceed RAM;
    /// pages materialise on first touch.
    pub fn zeros(rows: u64, dim: usize) -> Result<Self> {
        let len = (rows as usize).checked_mul(dim).ok_or_else(|| {
            anyhow::anyhow!("table size overflow: {rows} x {dim}")
        })?;
        Ok(ValueTable { map: MmapF32::anon(len)?, rows, dim })
    }

    /// File-backed table (persists across runs).
    pub fn open(path: &Path, rows: u64, dim: usize) -> Result<Self> {
        let len = (rows as usize).checked_mul(dim).ok_or_else(|| {
            anyhow::anyhow!("table size overflow: {rows} x {dim}")
        })?;
        Ok(ValueTable { map: MmapF32::file(path, len)?, rows, dim })
    }

    /// Copy-on-write view of a checkpointed table blob: rows are read
    /// zero-copy from the page cache (a multi-GB table costs physical
    /// memory only for rows actually served); training writes would land
    /// in private pages and never reach the checkpoint.  Rejects
    /// `rows * dim` overflow exactly like [`ValueTable::open`], and the
    /// map layer re-validates the file length against the expected table
    /// size both before and after mapping — a `values.bin` that shrank
    /// (torn checkpoint, concurrent prune) is refused loudly here, at
    /// map time, instead of faulting with SIGBUS on first row access.
    pub fn open_cow(path: &Path, rows: u64, dim: usize) -> Result<Self> {
        let len = (rows as usize).checked_mul(dim).ok_or_else(|| {
            anyhow::anyhow!("table size overflow: {rows} x {dim}")
        })?;
        let map = MmapF32::open_cow(path, len).with_context(|| {
            format!(
                "mapping value table {} ({rows} rows x {dim} dims = {} bytes)",
                path.display(),
                len * 4
            )
        })?;
        Ok(ValueTable { map, rows, dim })
    }

    /// The full `rows * dim` flat storage (checkpoint serialisation).
    pub fn data(&self) -> &[f32] {
        self.map.as_slice()
    }

    /// Gaussian init matching `model.py` (std 0.02), deterministic.
    pub fn randomize(&mut self, seed: u64, std: f32) {
        let rows = self.rows;
        self.randomize_rows(seed, std, rows);
    }

    /// Initialise only the first `n_rows` rows (keeps huge tables lazy:
    /// untouched pages stay virtual — benches cap this at 2^18 rows).
    pub fn randomize_rows(&mut self, seed: u64, std: f32, n_rows: u64) {
        let mut rng = Rng::new(seed);
        let n = (n_rows.min(self.rows) as usize) * self.dim;
        for v in &mut self.map.as_mut_slice()[..n] {
            *v = rng.normal() as f32 * std;
        }
    }

    #[inline]
    pub fn rows(&self) -> u64 {
        self.rows
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn param_count(&self) -> u64 {
        self.rows * self.dim as u64
    }

    #[inline]
    pub fn row(&self, idx: u64) -> &[f32] {
        debug_assert!(idx < self.rows, "row {idx} out of range ({})", self.rows);
        let start = idx as usize * self.dim;
        &self.map.as_slice()[start..start + self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, idx: u64) -> &mut [f32] {
        debug_assert!(idx < self.rows, "row {idx} out of range ({})", self.rows);
        let start = idx as usize * self.dim;
        let dim = self.dim;
        &mut self.map.as_mut_slice()[start..start + dim]
    }

    /// Hint the CPU to pull row `idx` into cache ahead of use (no-op on
    /// non-x86_64).  The gathers below prefetch the next row while the
    /// current one is being consumed, overlapping the random-access
    /// latency that dominates large-table gathers.
    #[inline(always)]
    fn prefetch_row(&self, idx: u64) {
        if idx >= self.rows {
            // out-of-range indices must stay a deterministic panic in the
            // gather itself, never wrapping pointer arithmetic here
            return;
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the pointer stays inside the mapping (idx < rows) and
        // prefetch is only a cache hint, never a dereference.
        unsafe {
            use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            let p = self.map.as_slice().as_ptr().add(idx as usize * self.dim) as *const i8;
            _mm_prefetch::<{ _MM_HINT_T0 }>(p);
            if self.dim > 16 {
                // rows longer than one cache line: grab the second too
                _mm_prefetch::<{ _MM_HINT_T0 }>(p.add(64));
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        let _ = idx;
    }

    /// Gather `k` weighted rows into `out` (the split-mode hot path):
    /// `out = sum_i weights[i] * table[indices[i]]`.
    pub fn gather_weighted(&self, indices: &[u64], weights: &[f32], out: &mut [f32]) {
        debug_assert_eq!(indices.len(), weights.len());
        debug_assert_eq!(out.len(), self.dim);
        out.fill(0.0);
        for (i, (&idx, &w)) in indices.iter().zip(weights).enumerate() {
            if let Some(&next) = indices.get(i + 1) {
                self.prefetch_row(next);
            }
            if w == 0.0 {
                continue; // padded top-k entries carry no weight
            }
            let row = self.row(idx);
            for (o, &v) in out.iter_mut().zip(row) {
                *o += w * v;
            }
        }
    }

    /// Batched weighted gather: `indices`/`weights` hold `k` hits per
    /// query (`n*k` flat, the [`crate::lattice::batch`] SoA layout) and
    /// `out` receives `n x dim` combined rows.
    pub fn gather_weighted_batch(
        &self,
        indices: &[u64],
        weights: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        assert!(k > 0, "k must be positive");
        debug_assert_eq!(indices.len() % k, 0);
        debug_assert_eq!(out.len(), indices.len() / k * self.dim);
        let groups = indices.chunks_exact(k).zip(weights.chunks_exact(k));
        for ((gi, gw), o) in groups.zip(out.chunks_exact_mut(self.dim)) {
            self.gather_weighted(gi, gw, o);
        }
    }

    /// Plain gather of `k` rows into a `k x m` buffer (feeds the suffix
    /// artifact, which applies the weights in-graph).
    pub fn gather_rows(&self, indices: &[u64], out: &mut [f32]) {
        debug_assert_eq!(out.len(), indices.len() * self.dim);
        for (i, &idx) in indices.iter().enumerate() {
            if let Some(&next) = indices.get(i + 1) {
                self.prefetch_row(next);
            }
            out[i * self.dim..(i + 1) * self.dim].copy_from_slice(self.row(idx));
        }
    }

    /// Scatter-add `delta` into row `idx` (training write path).
    pub fn scatter_add(&mut self, idx: u64, delta: &[f32]) {
        let row = self.row_mut(idx);
        for (r, &d) in row.iter_mut().zip(delta) {
            *r += d;
        }
    }

    /// Bulk load from raw f32 slice (checkpoint restore).
    pub fn load_from(&mut self, data: &[f32]) -> Result<()> {
        if data.len() != self.param_count() as usize {
            bail!("load_from: {} floats for {} params", data.len(), self.param_count());
        }
        self.map.as_mut_slice().copy_from_slice(data);
        Ok(())
    }

    /// Physically-resident bytes (lazy-allocation observability).
    pub fn resident_bytes(&self) -> Result<usize> {
        self.map.resident_bytes()
    }
}

/// Quantize one f32 row to i8 codes; returns the per-row scale.
///
/// `scale = max_abs / 127`, `q = clamp(round(v / scale), -127, 127)`,
/// so `v ≈ q * scale` with per-element error at most `scale / 2`.  An
/// all-zero (or non-finite) row gets scale 0 and all-zero codes — the
/// dequantized row is exactly zero, never NaN.
fn quantize_row(row: &[f32], qrow: &mut [i8]) -> f32 {
    let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 || !max_abs.is_finite() {
        qrow.fill(0);
        return 0.0;
    }
    let scale = max_abs / 127.0;
    for (q, &v) in qrow.iter_mut().zip(row) {
        // NaN elements cast to 0 (saturating float->int casts)
        *q = (v / scale).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Int8-quantized view of a value table: each row stores `m` i8 codes
/// plus one f32 scale, quartering the memory traffic of a gathered row.
/// Rows dequantize *inside* the fused gather — the per-row scale folds
/// into the kernel weight, so reconstruction is one fused multiply-add
/// per element (`crate::lattice::simd::axpy_q8`).
///
/// Serving-only: training keeps the f32 [`ValueTable`] (quantized rows
/// cannot absorb sparse-Adam updates).  Built either by quantizing a
/// live table ([`QuantizedValueTable::from_table`]) or zero-copy from
/// the `values_q8` / `values_q8_scale` checkpoint blobs
/// ([`QuantizedValueTable::from_parts`], see `docs/checkpoint-format.md`).
pub struct QuantizedValueTable {
    map: MmapI8,
    scales: Vec<f32>,
    rows: u64,
    dim: usize,
}

impl QuantizedValueTable {
    /// Quantize every row of `table` (anonymous backing memory).
    pub fn from_table(table: &ValueTable) -> Result<Self> {
        let rows = table.rows();
        let dim = table.dim();
        let len = (rows as usize).checked_mul(dim).ok_or_else(|| {
            anyhow::anyhow!("quantized table size overflow: {rows} x {dim}")
        })?;
        let mut map = MmapI8::anon(len)?;
        let mut scales = vec![0.0f32; rows as usize];
        let codes = map.as_mut_slice();
        for (r, scale) in scales.iter_mut().enumerate() {
            let row = table.row(r as u64);
            *scale = quantize_row(row, &mut codes[r * dim..(r + 1) * dim]);
        }
        Ok(QuantizedValueTable { map, scales, rows, dim })
    }

    /// Assemble from pre-existing storage (the checkpoint restore path:
    /// `map` is typically a copy-on-write view of the `values_q8` blob).
    pub fn from_parts(map: MmapI8, scales: Vec<f32>, rows: u64, dim: usize) -> Result<Self> {
        let len = (rows as usize).checked_mul(dim).ok_or_else(|| {
            anyhow::anyhow!("quantized table size overflow: {rows} x {dim}")
        })?;
        if map.len() != len {
            bail!("quantized table codes hold {} bytes, {rows} x {dim} needs {len}", map.len());
        }
        if scales.len() != rows as usize {
            bail!("quantized table has {} scales for {rows} rows", scales.len());
        }
        Ok(QuantizedValueTable { map, scales, rows, dim })
    }

    #[inline]
    pub fn rows(&self) -> u64 {
        self.rows
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The i8 codes of row `idx`.
    #[inline]
    pub fn row(&self, idx: u64) -> &[i8] {
        debug_assert!(idx < self.rows, "row {idx} out of range ({})", self.rows);
        let start = idx as usize * self.dim;
        &self.map.as_slice()[start..start + self.dim]
    }

    /// The dequantisation scale of row `idx`.
    #[inline]
    pub fn scale(&self, idx: u64) -> f32 {
        self.scales[idx as usize]
    }

    /// The flat `rows * dim` code storage (checkpoint serialisation).
    pub fn data(&self) -> &[i8] {
        self.map.as_slice()
    }

    /// The per-row scales (checkpoint serialisation).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Weighted dequantizing gather, same contract as
    /// [`ValueTable::gather_weighted`]:
    /// `out = sum_i weights[i] * scale[indices[i]] * codes[indices[i]]`.
    pub fn gather_weighted(&self, indices: &[u64], weights: &[f32], out: &mut [f32]) {
        debug_assert_eq!(indices.len(), weights.len());
        debug_assert_eq!(out.len(), self.dim);
        out.fill(0.0);
        for (&idx, &w) in indices.iter().zip(weights) {
            if w == 0.0 {
                continue; // padded top-k entries carry no weight
            }
            crate::lattice::simd::axpy_q8(w * self.scale(idx), self.row(idx), out);
        }
    }

    /// Physically-resident bytes of the code storage.
    pub fn resident_bytes(&self) -> Result<usize> {
        self.map.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_weighted_combines_rows() {
        let mut t = ValueTable::zeros(16, 4).unwrap();
        t.row_mut(3).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        t.row_mut(7).copy_from_slice(&[10.0, 20.0, 30.0, 40.0]);
        let mut out = [0.0f32; 4];
        t.gather_weighted(&[3, 7], &[0.5, 0.25], &mut out);
        assert_eq!(out, [3.0, 6.0, 9.0, 12.0]);
    }

    #[test]
    fn zero_weights_skip_rows() {
        let t = ValueTable::zeros(8, 2).unwrap();
        let mut out = [9.0f32; 2];
        t.gather_weighted(&[0, 1], &[0.0, 0.0], &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }

    #[test]
    fn scatter_add_accumulates() {
        let mut t = ValueTable::zeros(4, 3).unwrap();
        t.scatter_add(2, &[1.0, 1.0, 1.0]);
        t.scatter_add(2, &[0.5, 0.0, -1.0]);
        assert_eq!(t.row(2), &[1.5, 1.0, 0.0]);
    }

    #[test]
    fn billion_parameter_table_is_cheap_until_touched() {
        // 2^24 rows x 64 = 2^30 params = 4 GB virtual
        let mut t = ValueTable::zeros(1 << 24, 64).unwrap();
        assert_eq!(t.param_count(), 1 << 30);
        let before = t.resident_bytes().unwrap();
        assert!(before < 64 << 20, "resident {before} before touching");
        t.row_mut(12_345_678)[0] = 1.0;
        assert_eq!(t.row(12_345_678)[0], 1.0);
    }

    #[test]
    fn randomize_is_deterministic() {
        let mut a = ValueTable::zeros(64, 8).unwrap();
        let mut b = ValueTable::zeros(64, 8).unwrap();
        a.randomize(7, 0.02);
        b.randomize(7, 0.02);
        assert_eq!(a.row(20), b.row(20));
        assert!(a.row(20).iter().any(|&v| v != 0.0));
    }

    #[test]
    fn gather_weighted_batch_matches_per_query_gather() {
        let mut t = ValueTable::zeros(32, 4).unwrap();
        t.randomize(11, 0.5);
        let indices = [3u64, 7, 0, 12, 31, 5];
        let weights = [0.5f32, 0.25, 0.0, 1.0, 0.125, 2.0];
        let mut batched = [0.0f32; 8];
        t.gather_weighted_batch(&indices, &weights, 3, &mut batched);
        let mut single = [0.0f32; 4];
        for g in 0..2 {
            t.gather_weighted(&indices[g * 3..(g + 1) * 3], &weights[g * 3..(g + 1) * 3], &mut single);
            assert_eq!(&batched[g * 4..(g + 1) * 4], &single[..]);
        }
    }

    #[test]
    fn open_and_zeros_reject_size_overflow() {
        // rows * dim overflows usize: must bail, not wrap to a tiny map
        let path = std::env::temp_dir()
            .join(format!("lram_overflow_table_{}.bin", std::process::id()));
        assert!(ValueTable::open(&path, u64::MAX, 16).is_err());
        assert!(!path.exists(), "overflowing open must not create the file");
        assert!(ValueTable::zeros(u64::MAX, 16).is_err());
    }

    #[test]
    fn open_cow_refuses_truncated_table_loudly() {
        // a values.bin shorter than rows x dim must refuse at map time
        // (SIGBUS hardening) and the error must name the expected shape
        let dir = std::env::temp_dir()
            .join(format!("lram_cow_table_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("values.bin");
        std::fs::write(&path, [0u8; 64]).unwrap(); // 16 f32s, not 16x4
        let err = format!("{:#}", ValueTable::open_cow(&path, 16, 4).unwrap_err());
        assert!(err.contains("16 rows x 4 dims"), "{err}");
        assert!(err.contains("256 bytes"), "{err}");
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gather_rows_copies() {
        let mut t = ValueTable::zeros(8, 2).unwrap();
        t.row_mut(1).copy_from_slice(&[5.0, 6.0]);
        let mut out = [0.0f32; 4];
        t.gather_rows(&[1, 1], &mut out);
        assert_eq!(out, [5.0, 6.0, 5.0, 6.0]);
    }

    #[test]
    fn quantized_rows_reconstruct_within_half_a_step() {
        let mut t = ValueTable::zeros(64, 16).unwrap();
        t.randomize(5, 0.02);
        let q = QuantizedValueTable::from_table(&t).unwrap();
        assert_eq!(q.rows(), 64);
        assert_eq!(q.dim(), 16);
        for r in 0..64u64 {
            let scale = q.scale(r);
            assert!(scale > 0.0, "randomized rows must quantize with a positive scale");
            for (&code, &v) in q.row(r).iter().zip(t.row(r)) {
                let deq = code as f32 * scale;
                assert!(
                    (deq - v).abs() <= scale * 0.5 + 1e-9,
                    "row {r}: {v} reconstructed as {deq} (scale {scale})"
                );
            }
        }
    }

    #[test]
    fn quantized_gather_matches_dequantized_reference() {
        let mut t = ValueTable::zeros(32, 8).unwrap();
        t.randomize(11, 0.5);
        let q = QuantizedValueTable::from_table(&t).unwrap();
        let indices = [3u64, 7, 0, 12, 31];
        let weights = [0.5f32, 0.25, 0.0, 1.0, 0.125];
        let mut got = [9.0f32; 8];
        q.gather_weighted(&indices, &weights, &mut got);
        let mut want = [0.0f32; 8];
        for (&idx, &w) in indices.iter().zip(&weights) {
            if w == 0.0 {
                continue;
            }
            for (o, &code) in want.iter_mut().zip(q.row(idx)) {
                *o += w * q.scale(idx) * code as f32;
            }
        }
        for (i, (&a, &b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn degenerate_rows_quantize_to_exact_zero() {
        let mut t = ValueTable::zeros(4, 4).unwrap();
        t.row_mut(1).copy_from_slice(&[f32::NAN, f32::INFINITY, 1.0, -1.0]);
        let q = QuantizedValueTable::from_table(&t).unwrap();
        // all-zero row and non-finite row both dequantize to exact zeros
        assert_eq!(q.scale(0), 0.0);
        assert!(q.row(0).iter().all(|&c| c == 0));
        assert_eq!(q.scale(1), 0.0);
        assert!(q.row(1).iter().all(|&c| c == 0));
        let mut out = [5.0f32; 4];
        q.gather_weighted(&[0, 1], &[1.0, 1.0], &mut out);
        assert_eq!(out, [0.0; 4]);
    }

    #[test]
    fn from_parts_validates_shapes() {
        let map = MmapI8::anon(12).unwrap();
        assert!(QuantizedValueTable::from_parts(map, vec![0.0; 3], 3, 4).is_ok());
        let map = MmapI8::anon(12).unwrap();
        assert!(QuantizedValueTable::from_parts(map, vec![0.0; 2], 3, 4).is_err());
        let map = MmapI8::anon(11).unwrap();
        assert!(QuantizedValueTable::from_parts(map, vec![0.0; 3], 3, 4).is_err());
    }
}
