//! The random-access parameter store (the paper's "random access over the
//! parameter storage" assumption, made concrete).
//!
//! A value table of `M` rows times `m` floats lives in a lazily-populated
//! anonymous mmap, so tables with billions of parameters cost physical
//! memory only for rows actually touched.  Reads gather `k = 32` rows per
//! query in O(1) w.r.t. `M`; writes apply the paper's sparse-Adam updates
//! (lr 1e-3 on memory values) to touched rows only.  Access statistics
//! feed the Table-5 utilisation / KL-divergence experiment.

mod dense_adam;
mod sparse_adam;
mod stats;
mod table;

pub use dense_adam::DenseAdam;
pub use sparse_adam::SparseAdam;
pub use stats::AccessStats;
pub use table::{QuantizedValueTable, ValueTable};
