//! Evaluation metrics: masked-LM perplexity accounting and run logs.

/// Streaming perplexity over masked positions: accumulate (sum_nll,
/// sum_weight) pairs from the eval artifact and report exp(mean NLL).
#[derive(Debug, Clone, Copy, Default)]
pub struct Perplexity {
    pub sum_nll: f64,
    pub sum_weight: f64,
}

impl Perplexity {
    pub fn add(&mut self, sum_nll: f64, sum_weight: f64) {
        self.sum_nll += sum_nll;
        self.sum_weight += sum_weight;
    }

    pub fn mean_nll(&self) -> f64 {
        if self.sum_weight > 0.0 {
            self.sum_nll / self.sum_weight
        } else {
            f64::NAN
        }
    }

    pub fn value(&self) -> f64 {
        self.mean_nll().exp()
    }
}

/// Simple CSV run log (Figure 2's validation-perplexity curves).
pub struct RunLog {
    path: std::path::PathBuf,
    rows: Vec<String>,
    header: String,
}

impl RunLog {
    pub fn new(path: impl Into<std::path::PathBuf>, header: &str) -> Self {
        RunLog { path: path.into(), rows: vec![], header: header.to_string() }
    }

    pub fn push(&mut self, row: String) {
        self.rows.push(row);
    }

    pub fn flush(&self) -> anyhow::Result<()> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut text = String::with_capacity(self.rows.len() * 32 + 64);
        text.push_str(&self.header);
        text.push('\n');
        for r in &self.rows {
            text.push_str(r);
            text.push('\n');
        }
        std::fs::write(&self.path, text)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform_model() {
        // NLL = ln(V) per token => ppl = V
        let mut p = Perplexity::default();
        let v: f64 = 1000.0;
        p.add(v.ln() * 50.0, 50.0);
        assert!((p.value() - v).abs() < 1e-6);
    }

    #[test]
    fn perplexity_accumulates_weighted() {
        let mut p = Perplexity::default();
        p.add(2.0, 1.0);
        p.add(4.0, 3.0);
        assert!((p.mean_nll() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_perplexity_is_nan() {
        assert!(Perplexity::default().value().is_nan());
    }

    #[test]
    fn runlog_writes_csv() {
        let dir = std::env::temp_dir().join(format!("lram_log_{}", std::process::id()));
        let path = dir.join("curve.csv");
        let mut log = RunLog::new(&path, "step,ppl");
        log.push("0,100.0".into());
        log.push("10,50.0".into());
        log.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,ppl\n0,100.0\n10,50.0\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
