//! Training loop: data pipeline -> train-step artifact -> metrics.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::data::synth::CorpusSpec;
use crate::data::DataPipeline;
use crate::metrics::RunLog;
use crate::runtime::{Artifact, ArtifactState, HostTensor, Runtime};

use super::eval::{evaluate, EvalReport};

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub variant: String,
    pub steps: u64,
    pub final_train_loss: f64,
    pub best_val_ppl: f64,
    pub final_val: EvalReport,
    pub wall_secs: f64,
    pub run_dir: PathBuf,
}

/// The coordinator's trainer: owns artifact state + pipeline + logs.
pub struct Trainer {
    pub cfg: TrainConfig,
    runtime: std::sync::Arc<Runtime>,
    train_art: std::sync::Arc<Artifact>,
    eval_art: std::sync::Arc<Artifact>,
    state: ArtifactState,
    pipeline: DataPipeline,
    step: u64,
}

impl Trainer {
    pub fn new(runtime: std::sync::Arc<Runtime>, cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let train_art = runtime.load(&format!("train_step_{}", cfg.variant))?;
        let eval_art = runtime.load(&format!("eval_loss_{}", cfg.variant))?;
        let state = train_art.initial_state().context("loading initial state")?;
        let b = train_art.manifest.batch.b;
        let s = train_art.manifest.batch.s;
        let spec = CorpusSpec { seed: cfg.corpus_seed, ..CorpusSpec::default() };
        let pipeline = DataPipeline::new(spec, cfg.vocab_size, s, b, cfg.mask_prob)?;
        Ok(Trainer { cfg, runtime, train_art, eval_art, state, pipeline, step: 0 })
    }

    pub fn pipeline(&self) -> &DataPipeline {
        &self.pipeline
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Run one training step; returns the loss.
    pub fn train_step(&mut self) -> Result<f64> {
        let batch = self.pipeline.train_batch(self.step);
        let b = batch.b;
        let s = batch.s;
        let inputs = vec![
            HostTensor::scalar_i32(self.step as i32),
            HostTensor::I32(batch.tokens, vec![b, s]),
            HostTensor::I32(batch.targets, vec![b, s]),
            HostTensor::F32(batch.weights, vec![b, s]),
        ];
        let results = self.train_art.step(&mut self.state, &inputs)?;
        self.step += 1;
        Ok(results[0].as_f32()?[0] as f64)
    }

    /// Evaluate on the validation split using the shared state.
    pub fn evaluate_val(&mut self) -> Result<EvalReport> {
        evaluate(
            &self.eval_art,
            &mut self.state,
            &self.pipeline,
            self.cfg.eval_batches,
            /* test = */ false,
        )
    }

    pub fn evaluate_test(&mut self) -> Result<EvalReport> {
        evaluate(
            &self.eval_art,
            &mut self.state,
            &self.pipeline,
            self.cfg.eval_batches,
            /* test = */ true,
        )
    }

    /// Save the current state as a checkpoint.  The write is staged to a
    /// temp sibling and `rename`d into place, so a crash mid-save never
    /// truncates an existing `latest.ckpt` in place.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let bytes = self.state.to_bytes(&self.train_art.manifest)?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(format!(".tmp-{}", std::process::id()));
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        self.state = ArtifactState::from_bytes(&self.train_art.manifest, &bytes)?;
        Ok(())
    }

    /// Full training run with periodic validation (Figure 2's curves land
    /// in `<run_dir>/valcurve.csv`).
    pub fn run(&mut self) -> Result<TrainOutcome> {
        let run_dir = PathBuf::from(&self.cfg.run_dir);
        std::fs::create_dir_all(&run_dir)?;
        let mut curve = RunLog::new(run_dir.join("valcurve.csv"), "step,val_ppl,train_loss");
        let mut losses = RunLog::new(run_dir.join("trainloss.csv"), "step,loss");
        let t0 = Instant::now();
        let mut best_ppl = f64::INFINITY;
        let mut last_loss = f64::NAN;
        for i in 0..self.cfg.steps {
            let loss = self.train_step()?;
            last_loss = loss;
            losses.push(format!("{},{:.6}", i, loss));
            if (i + 1) % self.cfg.eval_every == 0 || i + 1 == self.cfg.steps {
                let report = self.evaluate_val()?;
                best_ppl = best_ppl.min(report.perplexity);
                curve.push(format!("{},{:.4},{:.6}", i + 1, report.perplexity, loss));
                log::info!(
                    "[{}] step {}/{} loss {:.4} val_ppl {:.2} ({:.1}s)",
                    self.cfg.variant,
                    i + 1,
                    self.cfg.steps,
                    loss,
                    report.perplexity,
                    t0.elapsed().as_secs_f64()
                );
                curve.flush()?;
                losses.flush()?;
                // rolling periodic checkpoint (crash recovery): the
                // final state additionally lands in final.ckpt below
                self.save_checkpoint(&run_dir.join("latest.ckpt"))?;
            }
        }
        let final_val = self.evaluate_val()?;
        best_ppl = best_ppl.min(final_val.perplexity);
        curve.flush()?;
        losses.flush()?;
        self.save_checkpoint(&run_dir.join("final.ckpt"))?;
        let _ = &self.runtime; // keep the client alive for the whole run
        Ok(TrainOutcome {
            variant: self.cfg.variant.clone(),
            steps: self.cfg.steps,
            final_train_loss: last_loss,
            best_val_ppl: best_ppl,
            final_val,
            wall_secs: t0.elapsed().as_secs_f64(),
            run_dir,
        })
    }
}
