//! Evaluation: perplexity over the validation/test splits, plus the
//! memory-access accounting behind Table 5.

use anyhow::Result;

use crate::data::DataPipeline;
use crate::memstore::AccessStats;
use crate::metrics::Perplexity;
use crate::runtime::{Artifact, ArtifactState, HostTensor};

/// Aggregated evaluation results.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub perplexity: f64,
    pub mean_nll: f64,
    pub batches: u64,
    pub masked_tokens: f64,
    /// Memory utilisation % and KL(access || uniform), when the artifact
    /// exposes accesses (LRAM / PKM variants).
    pub utilization: Option<f64>,
    pub kl_divergence: Option<f64>,
}

/// Run the eval artifact over `n_batches` of the chosen split.
pub fn evaluate(
    eval_art: &Artifact,
    state: &mut ArtifactState,
    pipeline: &DataPipeline,
    n_batches: u64,
    test: bool,
) -> Result<EvalReport> {
    let mut ppl = Perplexity::default();
    let locations = eval_art.manifest.locations;
    let mut stats = locations.map(AccessStats::new);
    for bi in 0..n_batches {
        let batch = if test { pipeline.test_batch(bi) } else { pipeline.val_batch(bi) };
        let (b, s) = (batch.b, batch.s);
        let inputs = vec![
            HostTensor::I32(batch.tokens, vec![b, s]),
            HostTensor::I32(batch.targets, vec![b, s]),
            HostTensor::F32(batch.weights, vec![b, s]),
        ];
        let results = eval_art.call(state, &inputs)?;
        let sum_nll = results[0].as_f32()?[0] as f64;
        let sum_w = results[1].as_f32()?[0] as f64;
        ppl.add(sum_nll, sum_w);
        if eval_art.manifest.access_outputs {
            if let Some(st) = stats.as_mut() {
                let idx = results[2].as_i32()?;
                let wts = results[3].as_f32()?;
                for (&i, &w) in idx.iter().zip(wts) {
                    st.record(i as u64, w as f64);
                }
            }
        }
    }
    Ok(EvalReport {
        perplexity: ppl.value(),
        mean_nll: ppl.mean_nll(),
        batches: n_batches,
        masked_tokens: ppl.sum_weight,
        utilization: stats.as_ref().map(|s| s.utilization()),
        kl_divergence: stats.as_ref().map(|s| s.kl_from_uniform()),
    })
}
