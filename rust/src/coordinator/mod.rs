//! The L3 coordinator: drives training and evaluation, owns checkpoints
//! and run logs.  Python never runs here — two trainers exist: the
//! artifact [`Trainer`] executing AOT'd HLO, and the pure-rust
//! [`EngineTrainer`] over the shared [`crate::model::LramMlm`], whose
//! checkpoints the serving engine restores bit-identically.

mod engine_trainer;
mod eval;
mod trainer;

pub use engine_trainer::{EngineTrainConfig, EngineTrainOutcome, EngineTrainer, GradView};
pub use eval::{evaluate, EvalReport};
pub use trainer::{TrainOutcome, Trainer};
