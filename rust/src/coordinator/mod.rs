//! The L3 coordinator: drives training and evaluation over the AOT
//! artifacts, owns checkpoints and run logs.  Python never runs here —
//! the compiled HLO plus the rust data pipeline is the whole loop.

mod eval;
mod trainer;

pub use eval::{evaluate, EvalReport};
pub use trainer::{TrainOutcome, Trainer};
