//! Pure-rust trainer for the [`LramMlm`] engine model — the training
//! side of "train → save → serve trained weights artifact-free".
//!
//! The forward pass is *the* shared [`crate::model::LramMlm::forward`];
//! the backward pass here is hand-derived for exactly that graph, so the
//! logits a checkpoint serves later are bit-identical to what the
//! trainer computed (the `checkpoint_roundtrip` harness asserts it).
//!
//! Gradient flow (masked cross-entropy over the masked positions):
//!
//! * output projection `w_out`, head-combine `wo`, token/position
//!   embeddings — dense SGD;
//! * value-table rows — [`SparseAdam`] (paper §3.2: memory parameters
//!   use lr 1e-3 to compensate for sparse access), only touched rows
//!   pay any work;
//! * the query projection `wq` — trained **through the lattice kernel**
//!   (the paper's whole premise: the memory is differentiable).  The
//!   gathered value `v = sum_j w_j T[idx_j]` depends on the query via
//!   `w_j = f(d2_j)`, so `dw_j/dq = f'(d2_j) * 2 (q - p_j)` flows the
//!   loss back into the query (`backward_gather_ragged_into` on
//!   [`crate::lattice::BatchLookupEngine`], reusing the forward's SoA
//!   candidate scratch), then through `q = query_scale * wq h` into
//!   `wq` (its own
//!   dense-Adam slot) *and* into `h`, i.e. the embeddings see the
//!   routing path too.  The hit *indices* remain straight-through — the
//!   selected set is treated as constant, which is exact wherever the
//!   top-k set is locally stable (the kernel is C^3 at the support
//!   boundary, so entering/leaving hits carry zero weight and zero
//!   derivative).  `EngineTrainConfig::train_routing = false`
//!   (`--freeze-routing`) restores the PR-3 behavior of a frozen `wq`.
//!
//! Every gradient here is locked against central finite differences of
//! an f64 reference forward by `rust/tests/grad_check.rs`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::checkpoint::{Checkpoint, Manifest};
use crate::data::synth::CorpusSpec;
use crate::data::{Batch, DataPipeline};
use crate::memstore::{DenseAdam, SparseAdam};
use crate::model::{tensor_names, EngineConfig, LramMlm};

/// Configuration for a pure-rust engine training run.
#[derive(Debug, Clone)]
pub struct EngineTrainConfig {
    /// Model geometry (the checkpoint records this).
    pub model: EngineConfig,
    /// Training steps.
    pub steps: u64,
    /// Rows per training batch (`<= model.max_batch`).
    pub batch: usize,
    /// SGD learning rate for the dense parameters.
    pub lr_dense: f32,
    /// SparseAdam learning rate for value-table rows (paper: 1e-3).
    pub lr_values: f32,
    /// Train the routing: flow d(loss)/d(query) through the lattice
    /// kernel into `wq` (default).  `false` freezes `wq` — the straight-
    /// through treatment the trainer had before the routing gradient
    /// existed (`--freeze-routing`).
    pub train_routing: bool,
    /// Dense-Adam learning rate for the routing projection `wq`.
    pub lr_routing: f32,
    /// Synthetic-corpus seed (must match serving so tokenizers agree).
    pub corpus_seed: u64,
    /// BPE vocabulary target (the *trained* size may come out smaller;
    /// the checkpoint stores the actual one).
    pub vocab_size: usize,
    pub mask_prob: f64,
    /// Validation batches for the end-of-run evaluation.
    pub eval_batches: u64,
    /// Save a checkpoint every N steps into `save_dir` (0 = final only).
    pub save_every: u64,
    /// Checkpoint directory; `None` trains without saving.
    pub save_dir: Option<PathBuf>,
    /// fsync checkpoint blobs + directories on commit, so saves survive
    /// power loss and not just process crashes (`lram train --fsync`).
    pub fsync: bool,
    /// Checkpoints retained per save dir: the live one plus
    /// `keep_checkpoints - 1` `.prev-<step>` siblings that serving can
    /// fall back to when the newest is corrupt (`--keep-checkpoints N`;
    /// 1 = replace in place, the historical behaviour).
    pub keep_checkpoints: usize,
}

impl Default for EngineTrainConfig {
    fn default() -> Self {
        EngineTrainConfig {
            model: EngineConfig::default(),
            steps: 200,
            batch: 8,
            lr_dense: 0.05,
            lr_values: 1e-3,
            train_routing: true,
            lr_routing: 1e-3,
            corpus_seed: 1234,
            vocab_size: 4096,
            mask_prob: 0.15,
            eval_batches: 4,
            save_every: 0,
            save_dir: None,
            fsync: false,
            keep_checkpoints: 1,
        }
    }
}

/// Outcome of an engine training run.
#[derive(Debug, Clone)]
pub struct EngineTrainOutcome {
    pub steps: u64,
    pub first_loss: f64,
    pub final_loss: f64,
    pub val_ppl: f64,
    /// Manifest of the final checkpoint, when one was saved.
    pub manifest: Option<Manifest>,
}

/// Read-only view of the gradients computed by the last
/// [`EngineTrainer::forward_backward`] call — the finite-difference
/// harness (`rust/tests/grad_check.rs`) compares these against numeric
/// gradients of an f64 reference forward.
pub struct GradView<'a> {
    pub embed: &'a [f32],
    pub pos: &'a [f32],
    pub wq: &'a [f32],
    pub wo: &'a [f32],
    pub w_out: &'a [f32],
    /// value-table row gradients, keyed by slot (deterministic order)
    pub rows: &'a BTreeMap<u64, Vec<f32>>,
}

/// The pure-rust trainer: owns the model, the sparse optimizer over the
/// value table, the dense-Adam routing slot, and the data pipeline.
pub struct EngineTrainer {
    pub cfg: EngineTrainConfig,
    pub model: LramMlm,
    opt: SparseAdam,
    /// routing slot: dense Adam over `wq` (unused when routing frozen)
    opt_wq: DenseAdam,
    pipeline: DataPipeline,
    step: u64,
    // dense-gradient scratch, zeroed each step
    g_embed: Vec<f32>,
    g_pos: Vec<f32>,
    g_wq: Vec<f32>,
    g_wo: Vec<f32>,
    g_wout: Vec<f32>,
    /// d(loss)/d(gathered value rows), `max_positions x heads*m` — the
    /// upstream gradient of the batched lattice backward
    g_gathered: Vec<f32>,
    /// d(loss)/d(query), `max_positions x heads x 8`
    dq: Vec<f64>,
    // value-row gradient accumulation (BTreeMap: deterministic order)
    row_grads: BTreeMap<u64, Vec<f32>>,
    /// whether the last [`Self::forward_backward`] saw any masked
    /// position; gates [`Self::apply_grads`] so a mask-free batch is a
    /// true no-op (an Adam step on all-zero gradients would still decay
    /// moments and move `wq`)
    had_loss: bool,
}

impl EngineTrainer {
    pub fn new(cfg: EngineTrainConfig) -> Result<Self> {
        ensure!(
            cfg.batch >= 1 && cfg.batch <= cfg.model.max_batch,
            "batch {} must be in [1, max_batch = {}]",
            cfg.batch,
            cfg.model.max_batch
        );
        ensure!(cfg.steps >= 1, "steps must be at least 1");
        let pipeline = Self::build_pipeline(&cfg)?;
        // the *actual* trained vocabulary (BPE may converge below the
        // target); serving uses the same rule, so sizes always agree
        let vocab = pipeline.bpe.vocab_size();
        let model = LramMlm::seeded(cfg.model.clone(), vocab)?;
        let opt = SparseAdam::new(model.table.rows(), cfg.model.m, cfg.lr_values)?;
        let opt_wq = DenseAdam::new(model.wq.len(), cfg.lr_routing);
        Ok(Self::assemble(cfg, model, opt, opt_wq, pipeline, 0))
    }

    /// Resume training from a checkpoint: model weights, value table
    /// *and* the optimizer state (sparse-Adam moments + per-row step
    /// counts, routing dense-Adam moments + step) come back exactly, so
    /// a resumed run is bit-identical to an uninterrupted one —
    /// `checkpoint_roundtrip.rs` asserts that too.  Checkpoints written
    /// before the routing slot existed (format version 1, or saved with
    /// `--freeze-routing`) simply start a fresh routing slot.
    pub fn from_checkpoint(mut cfg: EngineTrainConfig, dir: &Path) -> Result<Self> {
        let ck = Checkpoint::open(dir)?;
        // geometry comes from the checkpoint, not the (possibly default)
        // cfg — resuming must not silently reshape the model, and the
        // data pipeline must be built with the checkpoint's seq_len
        cfg.model = EngineConfig::from_desc(&ck.manifest.model, cfg.model.threads, false);
        ensure!(
            cfg.batch >= 1 && cfg.batch <= cfg.model.max_batch,
            "batch {} must be in [1, max_batch = {}]",
            cfg.batch,
            cfg.model.max_batch
        );
        let pipeline = Self::build_pipeline(&cfg)?;
        let hash = pipeline.bpe.fingerprint();
        if ck.manifest.tokenizer_hash != hash {
            bail!(
                "checkpoint {} was trained with tokenizer {} but this run built {} — \
                 corpus_seed/vocab_size must match to resume",
                ck.manifest.checkpoint_id,
                ck.manifest.tokenizer_hash,
                hash
            );
        }
        let model = LramMlm::from_checkpoint(&ck, cfg.model.threads)?;
        let opt = if ck.manifest.has_tensor(tensor_names::ADAM_M) {
            SparseAdam::from_state(
                ck.map_table(tensor_names::ADAM_M)?,
                ck.map_table(tensor_names::ADAM_V)?,
                ck.map_u32(tensor_names::ADAM_T)?,
                cfg.lr_values,
            )
            .context("restoring sparse-Adam state")?
        } else {
            SparseAdam::new(model.table.rows(), cfg.model.m, cfg.lr_values)?
        };
        let opt_wq = if ck.manifest.has_tensor(tensor_names::WQ_ADAM_M) {
            let m = ck.read_f32(tensor_names::WQ_ADAM_M)?;
            let v = ck.read_f32(tensor_names::WQ_ADAM_V)?;
            let t = ck.read_u32(tensor_names::WQ_ADAM_T)?;
            ensure!(
                m.len() == model.wq.len(),
                "routing optimizer state has {} entries, wq has {}",
                m.len(),
                model.wq.len()
            );
            ensure!(t.len() == 1, "routing step count must be a single entry");
            DenseAdam::from_state(m, v, t[0] as u64, cfg.lr_routing)
                .context("restoring routing (dense-Adam) state")?
        } else {
            // pre-routing checkpoint (or a --freeze-routing run): fresh slot
            DenseAdam::new(model.wq.len(), cfg.lr_routing)
        };
        let step = ck.manifest.step;
        Ok(Self::assemble(cfg, model, opt, opt_wq, pipeline, step))
    }

    fn build_pipeline(cfg: &EngineTrainConfig) -> Result<DataPipeline> {
        let spec = CorpusSpec { seed: cfg.corpus_seed, ..CorpusSpec::default() };
        DataPipeline::new(spec, cfg.vocab_size, cfg.model.seq_len, cfg.batch, cfg.mask_prob)
    }

    fn assemble(
        cfg: EngineTrainConfig,
        model: LramMlm,
        opt: SparseAdam,
        opt_wq: DenseAdam,
        pipeline: DataPipeline,
        step: u64,
    ) -> Self {
        let (vocab, width) = (model.vocab, cfg.model.width);
        let hm = cfg.model.heads * cfg.model.m;
        let max_positions = cfg.model.max_batch * cfg.model.seq_len;
        EngineTrainer {
            g_embed: vec![0.0; vocab * width],
            g_pos: vec![0.0; cfg.model.seq_len * width],
            g_wq: vec![0.0; model.wq.len()],
            g_wo: vec![0.0; width * hm],
            g_wout: vec![0.0; vocab * width],
            g_gathered: vec![0.0; max_positions * hm],
            dq: vec![0.0; max_positions * cfg.model.heads * 8],
            row_grads: BTreeMap::new(),
            had_loss: false,
            cfg,
            model,
            opt,
            opt_wq,
            pipeline,
            step,
        }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    pub fn pipeline(&self) -> &DataPipeline {
        &self.pipeline
    }

    /// The serving-identical forward pass (fused engine path) — exactly
    /// what an [`crate::server::EngineBackend`] restored from this
    /// trainer's checkpoint computes.
    pub fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.model.forward(tokens, false, None)
    }

    /// Read-only view of the gradients the last
    /// [`Self::forward_backward`] call computed (grad-check harness).
    pub fn grads(&self) -> GradView<'_> {
        GradView {
            embed: &self.g_embed,
            pos: &self.g_pos,
            wq: &self.g_wq,
            wo: &self.g_wo,
            w_out: &self.g_wout,
            rows: &self.row_grads,
        }
    }

    /// One training step; returns the masked cross-entropy loss.
    pub fn train_step(&mut self) -> Result<f64> {
        let batch = self.pipeline.train_batch(self.step);
        let total_weight: f64 = batch.weights.iter().map(|&w| w as f64).sum();
        if total_weight == 0.0 {
            // no position was masked (possible at tiny mask_prob): the
            // loss and every gradient are exactly zero; skip the
            // optimizers too so their moments stay untouched
            self.step += 1;
            return Ok(0.0);
        }
        let loss = self.forward_backward(&batch)?;
        self.apply_grads();
        self.step += 1;
        Ok(loss)
    }

    /// Forward + full backward over `batch`, filling the gradient
    /// buffers ([`Self::grads`]) **without** applying any update — the
    /// unit the finite-difference harness checks.  [`Self::train_step`]
    /// is exactly this followed by [`Self::apply_grads`].
    pub fn forward_backward(&mut self, batch: &Batch) -> Result<f64> {
        let (b, s) = (batch.b, batch.s);
        let logp = self.model.forward(&batch.tokens, false, None)?;

        let (width, heads, m) = (self.cfg.model.width, self.cfg.model.heads, self.cfg.model.m);
        let (hm, vocab, k_top) = (heads * m, self.model.vocab, self.model.engine.k_top);
        let positions = b * s;
        let total_weight: f64 = batch.weights.iter().map(|&w| w as f64).sum();

        self.g_embed.fill(0.0);
        self.g_pos.fill(0.0);
        self.g_wq.fill(0.0);
        self.g_wo.fill(0.0);
        self.g_wout.fill(0.0);
        self.g_gathered[..positions * hm].fill(0.0);
        self.row_grads.clear();
        self.had_loss = total_weight != 0.0;
        if !self.had_loss {
            return Ok(0.0);
        }

        let mut loss = 0.0f64;
        let mut y = vec![0.0f32; width];
        let mut coef = vec![0.0f32; vocab];
        let mut dy = vec![0.0f32; width];
        let mut dv = vec![0.0f32; hm];
        for p in 0..positions {
            let w_p = batch.weights[p];
            if w_p == 0.0 {
                continue; // unmasked positions carry no loss
            }
            let target = batch.targets[p];
            ensure!(
                (0..vocab as i32).contains(&target),
                "target {target} out of vocab {vocab}"
            );
            let lrow = &logp[p * vocab..(p + 1) * vocab];
            let scale = (w_p as f64 / total_weight) as f32;
            loss -= lrow[target as usize] as f64 * scale as f64;

            // d loss / d logit = (softmax - onehot) * w_p / W
            for (t, c) in coef.iter_mut().enumerate() {
                *c = ((lrow[t] as f64).exp() as f32) * scale;
            }
            coef[target as usize] -= scale;

            // logits = w_out · y  (y recomputed from stored h, gathered)
            self.model.recompute_y(p, &mut y);
            dy.fill(0.0);
            for (t, &c) in coef.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                let wrow = &self.model.w_out[t * width..(t + 1) * width];
                let grow = &mut self.g_wout[t * width..(t + 1) * width];
                for w in 0..width {
                    grow[w] += c * y[w];
                    dy[w] += c * wrow[w];
                }
            }

            // y = h + wo · v: residual into dh, projection into dv/g_wo
            let v = &self.model.gathered[p * hm..(p + 1) * hm];
            dv.fill(0.0);
            for (w, &dyw) in dy.iter().enumerate() {
                let wo_row = &self.model.wo[w * hm..(w + 1) * hm];
                let go_row = &mut self.g_wo[w * hm..(w + 1) * hm];
                for j in 0..hm {
                    dv[j] += dyw * wo_row[j];
                    go_row[j] += dyw * v[j];
                }
            }
            // the routing backward needs d(loss)/d(gathered) per query
            self.g_gathered[p * hm..(p + 1) * hm].copy_from_slice(&dv);

            // memory stage, value side: v[head] = Σ_j w_j T[idx_j]
            // → value rows get w_j * dv[head]; idx/w_j are constants
            for head in 0..heads {
                let (idx_row, w_row) = self.model.lk.query(p * heads + head);
                let dvh = &dv[head * m..(head + 1) * m];
                for j in 0..k_top {
                    let wgt = w_row[j];
                    if wgt == 0.0 {
                        continue; // padded hit: no access, no gradient
                    }
                    let g = self
                        .row_grads
                        .entry(idx_row[j])
                        .or_insert_with(|| vec![0.0; m]);
                    for (gi, &d) in g.iter_mut().zip(dvh) {
                        *gi += wgt * d;
                    }
                }
            }

            // h = embed[t] + pos[c] + 0.5 embed[left] + 0.5 embed[right];
            // dh = dy via the residual path (the routing path adds its
            // own dh term below, once dq is known)
            accumulate_dh(
                &mut self.g_embed,
                &mut self.g_pos,
                &batch.tokens,
                p,
                s,
                vocab,
                width,
                &dy,
            );
        }

        // memory stage, routing side: flow d(loss)/d(gathered) back
        // through the kernel weights into the queries (batched, sharded,
        // reusing the forward's SoA scratch)...
        if self.cfg.train_routing {
            let n_queries = positions * heads;
            self.model.backward_queries(
                n_queries,
                &self.g_gathered[..n_queries * m],
                &mut self.dq,
            );
            // ...then through q = query_scale * wq h into wq (outer
            // product with h) and into h (and so the embeddings again)
            let qscale = self.cfg.model.query_scale;
            let mut dh_r = vec![0.0f32; width];
            for p in 0..positions {
                if batch.weights[p] == 0.0 {
                    continue; // zero upstream ⇒ zero dq ⇒ nothing to add
                }
                dh_r.fill(0.0);
                for head in 0..heads {
                    for d in 0..8 {
                        let gq = self.dq[(p * heads + head) * 8 + d] * qscale;
                        if gq == 0.0 {
                            continue;
                        }
                        let r = head * 8 + d;
                        let h = &self.model.h[p * width..(p + 1) * width];
                        let wrow = &self.model.wq[r * width..(r + 1) * width];
                        let grow = &mut self.g_wq[r * width..(r + 1) * width];
                        for w in 0..width {
                            grow[w] += (gq * h[w] as f64) as f32;
                            dh_r[w] += (gq * wrow[w] as f64) as f32;
                        }
                    }
                }
                accumulate_dh(
                    &mut self.g_embed,
                    &mut self.g_pos,
                    &batch.tokens,
                    p,
                    s,
                    vocab,
                    width,
                    &dh_r,
                );
            }
        }

        Ok(loss)
    }

    /// Apply the gradients of the last [`Self::forward_backward`]:
    /// SparseAdam on touched value rows, SGD on the dense parameters,
    /// dense Adam on `wq` (when routing is trained).  A mask-free batch
    /// (no loss) applies nothing at all — in particular no dense-Adam
    /// step, whose moment decay would otherwise move `wq` on an
    /// all-zero gradient — keeping this split exactly equivalent to
    /// [`Self::train_step`]'s early return.
    fn apply_grads(&mut self) {
        if !self.had_loss {
            return;
        }
        for (row, grad) in std::mem::take(&mut self.row_grads) {
            self.opt.update_row(&mut self.model.table, row, &grad);
        }
        let lr = self.cfg.lr_dense;
        sgd(&mut self.model.embed, &self.g_embed, lr);
        sgd(&mut self.model.pos, &self.g_pos, lr);
        sgd(&mut self.model.wo, &self.g_wo, lr);
        sgd(&mut self.model.w_out, &self.g_wout, lr);
        if self.cfg.train_routing {
            self.opt_wq.step(&mut self.model.wq, &self.g_wq);
        }
        // with routing frozen, wq stays exactly at its restored/seed bits
    }

    /// Masked cross-entropy perplexity over `n_batches` deterministic
    /// validation batches (no gradients applied).
    pub fn evaluate(&mut self, n_batches: u64) -> Result<f64> {
        let mut total = 0.0f64;
        let mut weight = 0.0f64;
        for bi in 0..n_batches {
            let batch = self.pipeline.val_batch(bi);
            let logp = self.model.forward(&batch.tokens, false, None)?;
            let vocab = self.model.vocab;
            for p in 0..batch.b * batch.s {
                let w = batch.weights[p] as f64;
                if w == 0.0 {
                    continue;
                }
                let t = batch.targets[p];
                if (0..vocab as i32).contains(&t) {
                    total -= logp[p * vocab + t as usize] as f64 * w;
                    weight += w;
                }
            }
        }
        if weight == 0.0 {
            return Ok(f64::NAN);
        }
        Ok((total / weight).exp())
    }

    /// Save a checkpoint (model weights + optimizer state + tokenizer
    /// fingerprint + geometry) at the current step.  The routing slot is
    /// saved only when it is live (`train_routing`), so frozen-routing
    /// checkpoints carry no routing tensors.
    pub fn save_checkpoint(&self, dir: &Path) -> Result<Manifest> {
        self.model.save_checkpoint(
            dir,
            self.step,
            &self.pipeline.bpe.fingerprint(),
            Some(&self.opt),
            self.cfg.train_routing.then_some(&self.opt_wq),
            self.cfg.fsync,
            self.cfg.keep_checkpoints,
        )
    }

    /// Full run: `cfg.steps` training steps with periodic checkpoints
    /// every `cfg.save_every` steps and a final one (when `save_dir` is
    /// set), then a validation pass.
    pub fn run(&mut self) -> Result<EngineTrainOutcome> {
        let mut first_loss = f64::NAN;
        let mut final_loss = f64::NAN;
        let t0 = std::time::Instant::now();
        for i in 0..self.cfg.steps {
            let loss = self.train_step()?;
            if i == 0 {
                first_loss = loss;
            }
            final_loss = loss;
            let periodic = self.cfg.save_every > 0 && (i + 1) % self.cfg.save_every == 0;
            if periodic {
                if let Some(dir) = self.cfg.save_dir.clone() {
                    let m = self.save_checkpoint(&dir)?;
                    log::info!("step {}: saved checkpoint {}", self.step, m.checkpoint_id);
                }
            }
            if (i + 1) % 50 == 0 || i + 1 == self.cfg.steps {
                log::info!(
                    "[engine] step {}/{} loss {:.4} ({:.1}s)",
                    i + 1,
                    self.cfg.steps,
                    loss,
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        let manifest = match self.cfg.save_dir.clone() {
            Some(dir) => Some(self.save_checkpoint(&dir)?),
            None => None,
        };
        let val_ppl = self.evaluate(self.cfg.eval_batches)?;
        Ok(EngineTrainOutcome {
            steps: self.cfg.steps,
            first_loss,
            final_loss,
            val_ppl,
            manifest,
        })
    }
}

/// Accumulate a d(loss)/d(h) contribution for position `p` into the
/// embedding/position gradients — the inverse of the forward's
/// `h = embed[t] + pos[c] + 0.5 embed[left] + 0.5 embed[right]`.
/// Shared by the residual path (`dh = dy`) and the routing path
/// (`dh = query_scale * wq^T dq`).
#[allow(clippy::too_many_arguments)]
#[inline]
fn accumulate_dh(
    g_embed: &mut [f32],
    g_pos: &mut [f32],
    tokens: &[i32],
    p: usize,
    s: usize,
    vocab: usize,
    width: usize,
    dh: &[f32],
) {
    let c = p % s;
    let t = clamp_token(tokens[p], vocab);
    add_scaled(&mut g_embed[t * width..(t + 1) * width], dh, 1.0);
    add_scaled(&mut g_pos[c * width..(c + 1) * width], dh, 1.0);
    if c > 0 {
        let lt = clamp_token(tokens[p - 1], vocab);
        add_scaled(&mut g_embed[lt * width..(lt + 1) * width], dh, 0.5);
    }
    if c + 1 < s {
        let rt = clamp_token(tokens[p + 1], vocab);
        add_scaled(&mut g_embed[rt * width..(rt + 1) * width], dh, 0.5);
    }
}

#[inline]
fn clamp_token(t: i32, vocab: usize) -> usize {
    if t < 0 || t as usize >= vocab {
        (crate::tokenizer::UNK_ID as usize).min(vocab - 1)
    } else {
        t as usize
    }
}

#[inline]
fn add_scaled(dst: &mut [f32], src: &[f32], scale: f32) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += scale * s;
    }
}

#[inline]
fn sgd(params: &mut [f32], grads: &[f32], lr: f32) {
    for (p, &g) in params.iter_mut().zip(grads) {
        *p -= lr * g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EngineTrainConfig {
        EngineTrainConfig {
            model: EngineConfig {
                max_batch: 4,
                seq_len: 12,
                width: 16,
                heads: 2,
                m: 8,
                k_top: 8,
                torus_k: [4; 8],
                ..EngineConfig::default()
            },
            steps: 10,
            batch: 4,
            vocab_size: 256,
            ..EngineTrainConfig::default()
        }
    }

    #[test]
    fn loss_decreases_on_the_synthetic_task() {
        let mut t = EngineTrainer::new(tiny_cfg()).unwrap();
        let mut losses = Vec::new();
        for i in 0..30 {
            let loss = t.train_step().unwrap();
            assert!(loss.is_finite(), "step {i}: loss {loss}");
            losses.push(loss);
        }
        // averaged over 3 steps so one noisy batch can't mask descent
        let head: f64 = losses[..3].iter().sum::<f64>() / 3.0;
        let tail: f64 = losses[27..].iter().sum::<f64>() / 3.0;
        assert!(
            tail < head,
            "training did not reduce the loss: first~{head:.4}, last~{tail:.4}"
        );
    }

    #[test]
    fn training_is_deterministic() {
        let mut a = EngineTrainer::new(tiny_cfg()).unwrap();
        let mut b = EngineTrainer::new(tiny_cfg()).unwrap();
        for _ in 0..3 {
            assert_eq!(
                a.train_step().unwrap().to_bits(),
                b.train_step().unwrap().to_bits()
            );
        }
        let tokens = a.pipeline().val_batch(0).tokens;
        assert_eq!(a.forward(&tokens).unwrap(), b.forward(&tokens).unwrap());
    }

    #[test]
    fn routing_trains_wq_and_freezing_keeps_it_bit_identical() {
        let mut trained = EngineTrainer::new(tiny_cfg()).unwrap();
        let mut frozen =
            EngineTrainer::new(EngineTrainConfig { train_routing: false, ..tiny_cfg() })
                .unwrap();
        let wq0 = frozen.model.wq.clone();
        assert_eq!(trained.model.wq, wq0, "same seed, same init");
        for _ in 0..5 {
            trained.train_step().unwrap();
            frozen.train_step().unwrap();
        }
        let same_bits = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
        };
        assert!(same_bits(&frozen.model.wq, &wq0), "--freeze-routing must not move wq");
        assert!(!same_bits(&trained.model.wq, &wq0), "trained routing must move wq");
    }

    #[test]
    fn forward_backward_then_apply_equals_train_step() {
        // the grad-check harness relies on this split being exactly the
        // training step
        let mut a = EngineTrainer::new(tiny_cfg()).unwrap();
        let mut b = EngineTrainer::new(tiny_cfg()).unwrap();
        let la = a.train_step().unwrap();
        let batch = b.pipeline.train_batch(0);
        let lb = b.forward_backward(&batch).unwrap();
        b.apply_grads();
        b.step += 1;
        assert_eq!(la.to_bits(), lb.to_bits());
        let tokens = a.pipeline().val_batch(0).tokens;
        assert_eq!(a.forward(&tokens).unwrap(), b.forward(&tokens).unwrap());
    }

    #[test]
    fn batch_larger_than_max_batch_is_rejected() {
        let mut cfg = tiny_cfg();
        cfg.batch = 8; // model.max_batch is 4
        assert!(EngineTrainer::new(cfg).is_err());
    }
}
