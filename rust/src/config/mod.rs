//! Run configuration: JSON config files + CLI overrides.
//!
//! (The classic `.toml` config crate is unavailable offline; configs are
//! JSON documents parsed with `util::json` — same shape as the manifests.)

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Training-run configuration for the coordinator.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Variant name: baseline | lram_small | lram_medium | lram_large | pkm.
    pub variant: String,
    pub artifact_dir: String,
    pub run_dir: String,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: u64,
    /// Synthetic-corpus generator settings.
    pub corpus_seed: u64,
    pub vocab_size: usize,
    pub mask_prob: f64,
    /// Paragraphs in each split (train is streamed, val/test materialised).
    pub val_paragraphs: usize,
    pub test_paragraphs: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            variant: "lram_small".into(),
            artifact_dir: "artifacts".into(),
            run_dir: "runs/default".into(),
            steps: 300,
            eval_every: 50,
            eval_batches: 8,
            corpus_seed: 1234,
            vocab_size: 4096,
            mask_prob: 0.15,
            val_paragraphs: 512,
            test_paragraphs: 512,
        }
    }
}

impl TrainConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut c = TrainConfig::default();
        let get_s = |k: &str, d: &str| -> String {
            v.get(k).and_then(Json::as_str).unwrap_or(d).to_string()
        };
        c.variant = get_s("variant", &c.variant);
        c.artifact_dir = get_s("artifact_dir", &c.artifact_dir);
        c.run_dir = get_s("run_dir", &c.run_dir);
        let get_u = |k: &str, d: u64| v.get(k).and_then(Json::as_i64).map(|x| x as u64).unwrap_or(d);
        c.steps = get_u("steps", c.steps);
        c.eval_every = get_u("eval_every", c.eval_every);
        c.eval_batches = get_u("eval_batches", c.eval_batches);
        c.corpus_seed = get_u("corpus_seed", c.corpus_seed);
        c.vocab_size = get_u("vocab_size", c.vocab_size as u64) as usize;
        c.mask_prob = v.get("mask_prob").and_then(Json::as_f64).unwrap_or(c.mask_prob);
        c.val_paragraphs = get_u("val_paragraphs", c.val_paragraphs as u64) as usize;
        c.test_paragraphs = get_u("test_paragraphs", c.test_paragraphs as u64) as usize;
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&json::parse(&text)?)
    }

    /// Apply `--key value` CLI overrides on top of the file config.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.flags.get("variant") {
            self.variant = v.clone();
        }
        if let Some(v) = args.flags.get("artifacts") {
            self.artifact_dir = v.clone();
        }
        if let Some(v) = args.flags.get("run-dir") {
            self.run_dir = v.clone();
        }
        self.steps = args.u64("steps", self.steps)?;
        self.eval_every = args.u64("eval-every", self.eval_every)?;
        self.eval_batches = args.u64("eval-batches", self.eval_batches)?;
        self.corpus_seed = args.u64("corpus-seed", self.corpus_seed)?;
        self.vocab_size = args.usize("vocab-size", self.vocab_size)?;
        self.mask_prob = args.f64("mask-prob", self.mask_prob)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        const VARIANTS: &[&str] = &[
            "baseline", "lram_small", "lram_medium", "lram_large", "pkm",
            "lram_shared", "tiny_lram",
        ];
        if !VARIANTS.contains(&self.variant.as_str()) {
            return Err(anyhow!(
                "unknown variant '{}' (expected one of {VARIANTS:?})",
                self.variant
            ));
        }
        if !(0.0..1.0).contains(&self.mask_prob) {
            return Err(anyhow!("mask_prob must be in [0, 1)"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_json_overrides() {
        let v = json::parse(
            r#"{"variant": "pkm", "steps": 42, "mask_prob": 0.2, "run_dir": "runs/x"}"#,
        )
        .unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.variant, "pkm");
        assert_eq!(c.steps, 42);
        assert_eq!(c.mask_prob, 0.2);
        assert_eq!(c.run_dir, "runs/x");
        assert_eq!(c.eval_every, 50); // default preserved
    }

    #[test]
    fn rejects_unknown_variant() {
        let mut c = TrainConfig::default();
        c.variant = "bogus".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn cli_overrides_apply() {
        let mut c = TrainConfig::default();
        let args = Args::parse_from(
            ["--steps", "7", "--variant", "baseline"].iter().map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.variant, "baseline");
    }
}
