//! `loadgen` — keep-alive load generator for the serving front door.
//!
//! Opens `--connections` persistent HTTP/1.1 connections and fires
//! fill-mask requests back-to-back on each for `--duration-secs`,
//! then reports throughput, exact client-side latency percentiles, and
//! the shed rate.  This is the measurement half of the front-door CI
//! gate (`serve-load-smoke`): the serving path must sustain concurrent
//! keep-alive traffic with zero 5xx, and any load shedding must arrive
//! as a *well-formed* 429 (`Retry-After` header + JSON error body).
//!
//! ```text
//! lram serve --backend engine --random-init --addr 127.0.0.1:8077 &
//! cargo run --release --bin loadgen -- \
//!     --addr 127.0.0.1:8077 --connections 32 --duration-secs 10 \
//!     --fail-on-5xx --out serve-load.json
//! ```
//!
//! Flags: `--addr HOST:PORT` (default `127.0.0.1:8077`),
//! `--connections N` (32), `--duration-secs S` (10), `--top-k K` (3),
//! `--text STR` (must contain `[MASK]`), `--wait-healthz-secs S` (30;
//! polls `GET /healthz` before starting so a just-booted server isn't
//! counted as failure), `--out FILE` (machine-readable JSON report),
//! `--fail-on-5xx` (exit 1 on any 5xx or malformed 429),
//! `--expect-some-5xx` (chaos mode: 503/504 are tolerated outcomes of
//! injected faults, but every error must still be *well-formed* —
//! parseable framing, JSON error body, `Retry-After` on 429 and 503;
//! exit 1 on any malformed response), `--connection-close` (send
//! `Connection: close` and reconnect per request — the seed server's
//! behavior, kept as a measurable baseline for what keep-alive buys),
//! `--multiplex` (event-driven client: every connection multiplexed
//! over `--mux-threads` poll loops instead of one thread each — the
//! only way one generator box holds 5–10k concurrent sockets),
//! `--mux-threads T` (8).
//!
//! `--multiplex` raises `RLIMIT_NOFILE` toward what the connection
//! count needs (`lram::util::poll::raise_nofile_limit`); when the hard
//! cap is still too low the run exits 3 instead of producing a
//! misleading partial measurement.
//!
//! Exit codes: 0 ok; 1 gate failure (`--fail-on-5xx` /
//! `--expect-some-5xx`); 2 the run produced no successful request at
//! all (nothing to measure); 3 the environment cannot hold the
//! requested connection count (fd limit) — CI treats this as a skip,
//! not a gate failure.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::os::fd::AsRawFd;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use lram::util::cli::Args;
use lram::util::json::Json;
use lram::util::poll::{self, POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};
use lram::util::timing::{BenchReport, Table};

struct HttpResponse {
    status: u16,
    /// lowercased header names
    headers: Vec<(String, String)>,
    body: Vec<u8>,
    close: bool,
}

impl HttpResponse {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

fn read_response(r: &mut BufReader<TcpStream>) -> Result<HttpResponse> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        bail!("connection closed before status line");
    }
    let mut parts = line.split_whitespace();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/") {
        bail!("bad status line '{}'", line.trim());
    }
    let status: u16 = parts
        .next()
        .context("status line missing code")?
        .parse()
        .context("non-numeric status code")?;
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let resp = HttpResponse { status, headers, body: Vec::new(), close: false };
    let content_length: usize = resp
        .header("content-length")
        .context("response missing Content-Length")?
        .parse()
        .context("bad Content-Length")?;
    let close = resp
        .header("connection")
        .map(|v| v.to_ascii_lowercase().contains("close"))
        .unwrap_or(false);
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).context("reading response body")?;
    Ok(HttpResponse { body, close, ..resp })
}

#[derive(Default)]
struct ClientReport {
    /// latencies of successful (200) requests, ms
    latencies_ms: Vec<f64>,
    ok: u64,
    shed: u64,
    other_4xx: u64,
    server_5xx: u64,
    /// 429s missing Retry-After or a parseable JSON error body
    malformed_shed: u64,
    /// 5xx responses that are not well-formed: missing JSON error
    /// body, or a 503 without a numeric `Retry-After` header
    malformed_5xx: u64,
    reconnects: u64,
    io_errors: u64,
}

impl ClientReport {
    fn merge(&mut self, other: ClientReport) {
        self.latencies_ms.extend(other.latencies_ms);
        self.ok += other.ok;
        self.shed += other.shed;
        self.other_4xx += other.other_4xx;
        self.server_5xx += other.server_5xx;
        self.malformed_shed += other.malformed_shed;
        self.malformed_5xx += other.malformed_5xx;
        self.reconnects += other.reconnects;
        self.io_errors += other.io_errors;
    }

    fn requests(&self) -> u64 {
        self.ok + self.shed + self.other_4xx + self.server_5xx
    }
}

fn connect(addr: &str) -> Result<(TcpStream, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let reader = BufReader::new(stream.try_clone()?);
    Ok((stream, reader))
}

/// A 429 is only a *well-formed* shed if it carries `Retry-After` and
/// the structured error envelope — clients must be able to act on it.
fn shed_is_well_formed(resp: &HttpResponse) -> bool {
    has_retry_after(resp) && has_json_error_body(resp)
}

fn has_retry_after(resp: &HttpResponse) -> bool {
    resp.header("retry-after")
        .map(|v| v.parse::<u64>().is_ok())
        .unwrap_or(false)
}

/// Validate the error contract from `docs/api.md`: every 4xx/5xx body is
/// `{"error": {"code": STR, "message": STR, "retry_after_s"?: NUM}}`.
/// A body with the old flat shape (`{"error": "..."}`), a missing code,
/// or a non-numeric `retry_after_s` counts as malformed.
fn has_json_error_body(resp: &HttpResponse) -> bool {
    let Some(v) = std::str::from_utf8(&resp.body)
        .ok()
        .and_then(|t| lram::util::json::parse(t).ok())
    else {
        return false;
    };
    let Some(err) = v.get("error") else { return false };
    let code_ok = err.get("code").and_then(|c| c.as_str()).is_some_and(|c| !c.is_empty());
    let message_ok = err.get("message").and_then(|m| m.as_str()).is_some();
    let retry_ok = match err.get("retry_after_s") {
        None => true, // optional: present only on retryable statuses
        Some(r) => r.as_f64().is_some_and(|s| s >= 0.0),
    };
    code_ok && message_ok && retry_ok
}

/// Under fault injection 5xx responses are *expected* — but they must
/// still be something a client can act on: a JSON error body, and for
/// 503 (retryable by contract) a numeric `Retry-After` header.
fn server_error_is_well_formed(resp: &HttpResponse) -> bool {
    let body_ok = has_json_error_body(resp);
    if resp.status == 503 {
        body_ok && has_retry_after(resp)
    } else {
        body_ok
    }
}

/// Tally one complete response into the report (shared by the
/// thread-per-connection and multiplexed clients, so both modes gate on
/// exactly the same well-formedness rules).
fn record(resp: &HttpResponse, t0: Instant, rep: &mut ClientReport) {
    match resp.status {
        200 => {
            rep.ok += 1;
            rep.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        429 => {
            rep.shed += 1;
            if !shed_is_well_formed(resp) {
                rep.malformed_shed += 1;
            }
        }
        s if (400..500).contains(&s) => rep.other_4xx += 1,
        _ => {
            rep.server_5xx += 1;
            if !server_error_is_well_formed(resp) {
                rep.malformed_5xx += 1;
            }
        }
    }
}

fn client_loop(addr: &str, request: &str, deadline: Instant) -> ClientReport {
    let mut rep = ClientReport::default();
    let mut conn: Option<(TcpStream, BufReader<TcpStream>)> = None;
    let mut connected_once = false;
    while Instant::now() < deadline {
        if conn.is_none() {
            match connect(addr) {
                Ok(c) => {
                    if connected_once {
                        rep.reconnects += 1;
                    }
                    connected_once = true;
                    conn = Some(c);
                }
                Err(_) => {
                    rep.io_errors += 1;
                    std::thread::sleep(Duration::from_millis(50));
                    continue;
                }
            }
        }
        let (stream, reader) = conn.as_mut().expect("connection just established");
        let t0 = Instant::now();
        if stream.write_all(request.as_bytes()).is_err() {
            rep.io_errors += 1;
            conn = None;
            continue;
        }
        let resp = match read_response(reader) {
            Ok(r) => r,
            Err(_) => {
                // server closed the socket (keep-alive timeout, drain);
                // reconnect and keep going
                rep.io_errors += 1;
                conn = None;
                continue;
            }
        };
        record(&resp, t0, &mut rep);
        if resp.close {
            conn = None;
        }
    }
    rep
}

// -- multiplexed client ------------------------------------------------------
//
// One poll loop per mux thread, each multiplexing `connections /
// mux_threads` nonblocking keep-alive sockets: write the canned request,
// accumulate the response, classify it, repeat until the deadline.  The
// thread-per-connection mode above cannot reach 5-10k concurrent
// sockets (10k stacks and 10k blocked reads); this one holds them all
// with `mux_threads` stacks, mirroring the server's own event loops.

/// Where a multiplexed connection is in its request/response cycle.
enum MuxState {
    /// Sending the canned request; `off` bytes already written.
    Writing { off: usize, t0: Instant },
    /// Request fully sent; accumulating the response into `inbuf`.
    Reading { t0: Instant },
}

struct MuxConn {
    stream: TcpStream,
    inbuf: Vec<u8>,
    state: MuxState,
}

/// Blocking connect, then switch to nonblocking for the poll loop.
fn mux_connect(addr: &str) -> Result<MuxConn> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_nonblocking(true)?;
    Ok(MuxConn {
        stream,
        inbuf: Vec::new(),
        state: MuxState::Writing { off: 0, t0: Instant::now() },
    })
}

/// Parse one complete response off the front of `buf`, if present.
/// Returns the response and how many bytes it consumed.
fn parse_buffered_response(buf: &[u8]) -> Result<Option<(HttpResponse, usize)>> {
    let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n") else {
        return Ok(None);
    };
    let head = std::str::from_utf8(&buf[..head_end]).context("non-utf8 response head")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    if !status_line.starts_with("HTTP/") {
        bail!("bad status line '{status_line}'");
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("status line missing code")?
        .parse()
        .context("non-numeric status code")?;
    let mut headers = Vec::new();
    for h in lines {
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let resp = HttpResponse { status, headers, body: Vec::new(), close: false };
    let content_length: usize = resp
        .header("content-length")
        .context("response missing Content-Length")?
        .parse()
        .context("bad Content-Length")?;
    let close = resp
        .header("connection")
        .map(|v| v.to_ascii_lowercase().contains("close"))
        .unwrap_or(false);
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return Ok(None);
    }
    let body = buf[body_start..body_start + content_length].to_vec();
    Ok(Some((HttpResponse { body, close, ..resp }, body_start + content_length)))
}

/// Tear a connection down and dial a replacement (best effort — a
/// refused reconnect leaves a hole until the next cycle notices).
fn mux_reconnect(conn: &mut Option<MuxConn>, addr: &str, rep: &mut ClientReport) {
    *conn = None;
    match mux_connect(addr) {
        Ok(c) => {
            rep.reconnects += 1;
            *conn = Some(c);
        }
        Err(_) => rep.io_errors += 1,
    }
}

/// Drive one ready connection as far as it goes.  Returns false when the
/// connection died and needs a replacement.
fn mux_drive(conn: &mut MuxConn, request: &str, rep: &mut ClientReport) -> bool {
    loop {
        match conn.state {
            MuxState::Writing { off, t0 } => {
                match conn.stream.write(&request.as_bytes()[off..]) {
                    Ok(0) => return false,
                    Ok(n) if off + n == request.len() => {
                        conn.state = MuxState::Reading { t0 };
                    }
                    Ok(n) => conn.state = MuxState::Writing { off: off + n, t0 },
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        rep.io_errors += 1;
                        return false;
                    }
                }
            }
            MuxState::Reading { t0 } => {
                let mut chunk = [0u8; 4096];
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        // keep-alive timeout or drain: quiet teardown
                        rep.io_errors += 1;
                        return false;
                    }
                    Ok(n) => conn.inbuf.extend_from_slice(&chunk[..n]),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        rep.io_errors += 1;
                        return false;
                    }
                }
                match parse_buffered_response(&conn.inbuf) {
                    Ok(Some((resp, consumed))) => {
                        record(&resp, t0, rep);
                        conn.inbuf.drain(..consumed);
                        if resp.close {
                            return false;
                        }
                        conn.state = MuxState::Writing { off: 0, t0: Instant::now() };
                    }
                    Ok(None) => {} // need more bytes; loop back into read
                    Err(_) => {
                        // torn framing: this connection is beyond saving
                        rep.io_errors += 1;
                        return false;
                    }
                }
            }
        }
    }
}

/// One mux thread: hold `target` keep-alive connections through a poll
/// loop until `deadline`.
fn mux_loop(addr: &str, request: &str, deadline: Instant, target: usize) -> ClientReport {
    let mut rep = ClientReport::default();
    let mut conns: Vec<Option<MuxConn>> = Vec::with_capacity(target);
    for _ in 0..target {
        match mux_connect(addr) {
            Ok(c) => conns.push(Some(c)),
            Err(_) => {
                rep.io_errors += 1;
                conns.push(None);
            }
        }
    }
    let mut fds = Vec::with_capacity(target);
    let mut slots = Vec::with_capacity(target);
    while Instant::now() < deadline {
        fds.clear();
        slots.clear();
        for (i, slot) in conns.iter().enumerate() {
            let Some(c) = slot else { continue };
            let events = match c.state {
                MuxState::Writing { .. } => POLLOUT,
                MuxState::Reading { .. } => POLLIN,
            };
            fds.push(poll::entry(c.stream.as_raw_fd(), events));
            slots.push(i);
        }
        if fds.is_empty() {
            // every socket is down (server gone?); retry a batch
            for slot in conns.iter_mut().take(64) {
                mux_reconnect(slot, addr, &mut rep);
            }
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        let wait = deadline
            .saturating_duration_since(Instant::now())
            .min(Duration::from_millis(100));
        let n = match poll::poll(&mut fds, Some(wait)) {
            Ok(n) => n,
            Err(_) => {
                rep.io_errors += 1;
                continue;
            }
        };
        if n == 0 {
            continue;
        }
        for (fd, &slot) in fds.iter().zip(&slots) {
            if fd.revents == 0 {
                continue;
            }
            let died = if fd.revents & (POLLERR | POLLNVAL) != 0 && fd.revents & POLLHUP == 0 {
                rep.io_errors += 1;
                true
            } else {
                // POLLHUP still delivers buffered response bytes; let
                // the read path run to completion first
                let conn = conns[slot].as_mut().expect("ready slot holds a connection");
                !mux_drive(conn, request, &mut rep)
            };
            if died && Instant::now() < deadline {
                mux_reconnect(&mut conns[slot], addr, &mut rep);
            }
        }
    }
    rep
}

/// Poll `GET /healthz` until the server answers 200 (a just-booted
/// server must not count as a failed run).
fn wait_healthz(addr: &str, budget: Duration) -> Result<()> {
    let deadline = Instant::now() + budget;
    loop {
        if let Ok((mut stream, mut reader)) = connect(addr) {
            let req =
                "GET /healthz HTTP/1.1\r\nHost: loadgen\r\nConnection: close\r\n\r\n".to_string();
            if stream.write_all(req.as_bytes()).is_ok() {
                if let Ok(resp) = read_response(&mut reader) {
                    if resp.status == 200 {
                        return Ok(());
                    }
                }
            }
        }
        if Instant::now() >= deadline {
            bail!("server at {addr} did not answer /healthz within {budget:?}");
        }
        std::thread::sleep(Duration::from_millis(200));
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

fn main() -> Result<()> {
    lram::util::logger::init();
    let args = Args::parse();
    let addr = args.str("addr", "127.0.0.1:8077");
    let connections = args.usize("connections", 32)?.max(1);
    let duration = Duration::from_secs_f64(args.f64("duration-secs", 10.0)?);
    let top_k = args.usize("top-k", 3)?;
    let text = args.str("text", "the [MASK] sat on the mat");
    let fail_on_5xx = args.bool("fail-on-5xx", false)?;
    let expect_some_5xx = args.bool("expect-some-5xx", false)?;
    let connection_close = args.bool("connection-close", false)?;
    let multiplex = args.bool("multiplex", false)?;
    let mux_threads = args.usize("mux-threads", 8)?.max(1);
    if fail_on_5xx && expect_some_5xx {
        bail!("--fail-on-5xx and --expect-some-5xx are mutually exclusive");
    }
    if multiplex && connection_close {
        bail!("--multiplex measures keep-alive connections; drop --connection-close");
    }
    if !text.contains("[MASK]") {
        bail!("--text must contain a [MASK] token");
    }
    if multiplex {
        // the sockets plus stdio, the listener-side pipe pair, and slack
        let want = connections as u64 + 64;
        let got = poll::raise_nofile_limit(want)
            .with_context(|| format!("raising RLIMIT_NOFILE to {want}"))?;
        if got < want {
            eprintln!(
                "LOADGEN SKIP: fd limit {got} cannot hold {connections} connections \
                 (hard cap too low)"
            );
            std::process::exit(3);
        }
    }

    wait_healthz(&addr, Duration::from_secs_f64(args.f64("wait-healthz-secs", 30.0)?))?;

    let body = Json::obj(vec![
        ("text", Json::Str(text.clone())),
        ("top_k", Json::Num(top_k as f64)),
    ])
    .to_string();
    let conn_header = if connection_close { "Connection: close\r\n" } else { "" };
    // the canonical versioned route; /predict stays as an alias
    let request = format!(
        "POST /v1/predict HTTP/1.1\r\nHost: loadgen\r\nContent-Type: application/json\r\n\
         {conn_header}Content-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );

    println!(
        "loadgen: {connections} {} connections against http://{addr} for {:.1}s{}",
        if connection_close { "close-per-request (seed-style)" } else { "keep-alive" },
        duration.as_secs_f64(),
        if multiplex {
            format!(" (multiplexed over {mux_threads} poll loops)")
        } else {
            String::new()
        }
    );
    let t0 = Instant::now();
    let deadline = t0 + duration;
    let mut total = ClientReport::default();
    if multiplex {
        // split the connection count across the poll loops; the first
        // threads absorb the remainder
        let threads = mux_threads.min(connections);
        let base = connections / threads;
        let extra = connections % threads;
        let mut handles = Vec::with_capacity(threads);
        for i in 0..threads {
            let addr = addr.clone();
            let request = request.clone();
            let target = base + usize::from(i < extra);
            handles.push(std::thread::spawn(move || mux_loop(&addr, &request, deadline, target)));
        }
        for h in handles {
            total.merge(h.join().expect("mux thread panicked"));
        }
    } else {
        let mut handles = Vec::with_capacity(connections);
        for _ in 0..connections {
            let addr = addr.clone();
            let request = request.clone();
            handles.push(std::thread::spawn(move || client_loop(&addr, &request, deadline)));
        }
        for h in handles {
            total.merge(h.join().expect("client thread panicked"));
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();

    total.latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let qps = total.ok as f64 / elapsed;
    let p50 = percentile(&total.latencies_ms, 0.50);
    let p95 = percentile(&total.latencies_ms, 0.95);
    let p99 = percentile(&total.latencies_ms, 0.99);
    let max = total.latencies_ms.last().copied().unwrap_or(0.0);
    let mean = if total.ok > 0 {
        total.latencies_ms.iter().sum::<f64>() / total.ok as f64
    } else {
        0.0
    };
    let requests = total.requests();
    let shed_rate = if requests > 0 { total.shed as f64 / requests as f64 } else { 0.0 };

    let mut t = Table::new(&["metric", "value"]);
    t.row(&["qps (successful)".into(), format!("{qps:.1}")]);
    t.row(&["requests".into(), requests.to_string()]);
    t.row(&["ok (200)".into(), total.ok.to_string()]);
    t.row(&["shed (429)".into(), total.shed.to_string()]);
    t.row(&["shed rate".into(), format!("{:.2}%", shed_rate * 100.0)]);
    t.row(&["malformed 429".into(), total.malformed_shed.to_string()]);
    t.row(&["other 4xx".into(), total.other_4xx.to_string()]);
    t.row(&["5xx".into(), total.server_5xx.to_string()]);
    t.row(&["malformed 5xx".into(), total.malformed_5xx.to_string()]);
    t.row(&["p50 latency (ms)".into(), format!("{p50:.2}")]);
    t.row(&["p95 latency (ms)".into(), format!("{p95:.2}")]);
    t.row(&["p99 latency (ms)".into(), format!("{p99:.2}")]);
    t.row(&["max latency (ms)".into(), format!("{max:.2}")]);
    t.row(&["reconnects".into(), total.reconnects.to_string()]);
    t.row(&["io errors".into(), total.io_errors.to_string()]);
    t.print();

    if let Some(out) = args.flags.get("out") {
        let mut report = BenchReport::new("serve_load");
        report.entry(
            "loadgen",
            &[
                ("connections", connections as f64),
                ("keep_alive", if connection_close { 0.0 } else { 1.0 }),
                ("multiplex", if multiplex { 1.0 } else { 0.0 }),
                ("duration_s", elapsed),
                ("requests", requests as f64),
                ("ok", total.ok as f64),
                ("shed", total.shed as f64),
                ("shed_rate", shed_rate),
                ("malformed_shed", total.malformed_shed as f64),
                ("malformed_5xx", total.malformed_5xx as f64),
                ("other_4xx", total.other_4xx as f64),
                ("server_5xx", total.server_5xx as f64),
                ("reconnects", total.reconnects as f64),
                ("io_errors", total.io_errors as f64),
                ("qps", qps),
                ("p50_ms", p50),
                ("p95_ms", p95),
                ("p99_ms", p99),
                ("max_ms", max),
                ("mean_ms", mean),
            ],
        );
        report.write(out).with_context(|| format!("writing {out}"))?;
        println!("report written to {out}");
    }

    if total.ok == 0 {
        eprintln!("LOADGEN FAILURE: no successful request in {elapsed:.1}s");
        std::process::exit(2);
    }
    if fail_on_5xx && (total.server_5xx > 0 || total.malformed_shed > 0) {
        eprintln!(
            "LOADGEN GATE FAILURE: {} 5xx responses, {} malformed 429s",
            total.server_5xx, total.malformed_shed
        );
        std::process::exit(1);
    }
    if expect_some_5xx {
        if total.malformed_shed > 0 || total.malformed_5xx > 0 {
            eprintln!(
                "LOADGEN CHAOS GATE FAILURE: {} malformed 429s, {} malformed 5xx \
                 (error responses must carry a JSON error body; 429/503 must carry Retry-After)",
                total.malformed_shed, total.malformed_5xx
            );
            std::process::exit(1);
        }
        println!(
            "chaos gate: {} 5xx observed, all well-formed ({} 429s, all well-formed)",
            total.server_5xx, total.shed
        );
    }
    Ok(())
}
