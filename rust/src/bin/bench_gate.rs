//! Perf regression gate over `BENCH_*.json` reports — the CI side of the
//! ROADMAP "perf trajectory tracking" item.
//!
//! Compares one numeric field of one entry between a freshly produced
//! report and a committed baseline, and fails (exit 1) when the fresh
//! value drops below `baseline * min_ratio`.  The default floor is
//! deliberately generous (0.35) so shared CI runners — noisy neighbours,
//! frequency scaling, cold caches — don't flake the build, while real
//! regressions (the fused path losing its multi-x headroom over the
//! scalar seed) still trip it.
//!
//! ```text
//! cargo bench --bench lattice_hot_path          # writes BENCH_lattice.json
//! cargo run --release --bin bench_gate -- \
//!     BENCH_lattice.json benches/BENCH_lattice.baseline.json
//! ```
//!
//! Flags: `--entry <name>` (default `engine_lookup_gather_b256_t1`),
//! `--field <field>` (default `qps`), `--min-ratio <r>` (default 0.35).
//! Re-record the baseline by copying a fresh `BENCH_lattice.json` over
//! `benches/BENCH_lattice.baseline.json` on a quiet machine.

use anyhow::{anyhow, bail, Context, Result};

use lram::util::cli::Args;
use lram::util::json;

/// Read `entries[name == entry].<field>` out of a bench report.
fn read_field(path: &str, entry: &str, field: &str) -> Result<f64> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let entries = v
        .req("entries")?
        .as_arr()
        .ok_or_else(|| anyhow!("{path}: 'entries' is not an array"))?;
    for e in entries {
        if e.get("name").and_then(|n| n.as_str()) == Some(entry) {
            return e
                .req(field)?
                .as_f64()
                .ok_or_else(|| anyhow!("{path}: {entry}.{field} is not a number"));
        }
    }
    bail!("{path}: no entry named '{entry}'")
}

fn main() -> Result<()> {
    let args = Args::parse();
    if args.positional.len() != 2 {
        bail!(
            "usage: bench_gate <current.json> <baseline.json> \
             [--entry NAME] [--field FIELD] [--min-ratio R]"
        );
    }
    let entry = args.str("entry", "engine_lookup_gather_b256_t1");
    let field = args.str("field", "qps");
    let min_ratio = args.f64("min-ratio", 0.35)?;
    let current = read_field(&args.positional[0], &entry, &field)?;
    let baseline = read_field(&args.positional[1], &entry, &field)?;
    if baseline <= 0.0 {
        bail!("baseline {entry}.{field} is {baseline}: nothing to gate against");
    }
    let ratio = current / baseline;
    println!(
        "perf gate: {entry}.{field} = {current:.4e} vs baseline {baseline:.4e} \
         (ratio {ratio:.3}, floor {min_ratio:.2})"
    );
    if ratio < min_ratio {
        eprintln!(
            "PERF REGRESSION: {entry}.{field} fell to {:.1}% of the recorded baseline \
             (floor is {:.1}%)",
            ratio * 100.0,
            min_ratio * 100.0
        );
        std::process::exit(1);
    }
    println!("perf gate passed");
    Ok(())
}
