//! Perf regression gate over `BENCH_*.json` reports — the CI side of the
//! ROADMAP "perf trajectory tracking" item.
//!
//! Compares one numeric field of one entry between a freshly produced
//! report and a committed baseline, and fails (exit 1) when the fresh
//! value drops below `baseline * min_ratio`.  The default floor is
//! deliberately generous (0.35) so shared CI runners — noisy neighbours,
//! frequency scaling, cold caches — don't flake the build, while real
//! regressions (the fused path losing its multi-x headroom over the
//! scalar seed) still trip it.
//!
//! ```text
//! cargo bench --bench lattice_hot_path          # writes BENCH_lattice.json
//! cargo run --release --bin bench_gate -- \
//!     BENCH_lattice.json benches/BENCH_lattice.baseline.json
//! ```
//!
//! Flags: `--entry <name>` (default `engine_lookup_gather_b256_t1`),
//! `--field <field>` (default `qps`), `--min-ratio <r>` (default 0.35).
//! Re-record the baseline by copying a fresh `BENCH_lattice.json` over
//! `benches/BENCH_lattice.baseline.json` on a quiet machine.
//!
//! `--report` switches to visibility mode: instead of gating one field,
//! it prints *every* baseline-vs-current field of *every* entry in one
//! table (ratio included) and always exits 0 — CI runs it once per
//! workflow so regressions in non-gated fields at least show in logs.
//! It also compares the reports' `host` fingerprints and warns loudly
//! when they differ: absolute fields from different iron are not
//! comparable, only same-run ratio fields are — which is why the f32
//! serving gate uses `f32_speedup_vs_f64` (measured and compared within
//! one bench run) instead of raw qps.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use lram::util::cli::Args;
use lram::util::json;
use lram::util::timing::Table;

/// Read `entries[name == entry].<field>` out of a bench report.
fn read_field(path: &str, entry: &str, field: &str) -> Result<f64> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let entries = v
        .req("entries")?
        .as_arr()
        .ok_or_else(|| anyhow!("{path}: 'entries' is not an array"))?;
    for e in entries {
        if e.get("name").and_then(|n| n.as_str()) == Some(entry) {
            return e
                .req(field)?
                .as_f64()
                .ok_or_else(|| anyhow!("{path}: {entry}.{field} is not a number"));
        }
    }
    bail!("{path}: no entry named '{entry}'")
}

/// The optional top-level `host` fingerprint of a report (see
/// `util::timing::host_fingerprint`); `None` for pre-fingerprint files.
fn read_host(path: &str) -> Result<Option<String>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
    Ok(v.get("host").and_then(|h| h.as_str()).map(|s| s.to_string()))
}

/// `entry name → field → value` for every numeric field of a report.
fn read_all(path: &str) -> Result<BTreeMap<String, BTreeMap<String, f64>>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let v = json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let entries = v
        .req("entries")?
        .as_arr()
        .ok_or_else(|| anyhow!("{path}: 'entries' is not an array"))?;
    let mut out = BTreeMap::new();
    for e in entries {
        let name = e
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("{path}: entry without a 'name'"))?;
        let obj = e.as_obj().ok_or_else(|| anyhow!("{path}: entry is not an object"))?;
        let fields: BTreeMap<String, f64> = obj
            .iter()
            .filter_map(|(k, val)| val.as_f64().map(|f| (k.clone(), f)))
            .collect();
        out.insert(name.to_string(), fields);
    }
    Ok(out)
}

/// `--report`: every baseline-vs-current field of every entry, one
/// table, no gating.
fn print_report(current_path: &str, baseline_path: &str) -> Result<()> {
    let current = read_all(current_path)?;
    let baseline = read_all(baseline_path)?;
    let fmt = |v: Option<f64>| v.map(|f| format!("{f:.4e}")).unwrap_or_else(|| "-".into());
    let mut t = Table::new(&["entry", "field", "baseline", "current", "ratio"]);
    let entry_names: Vec<&String> = baseline
        .keys()
        .chain(current.keys().filter(|k| !baseline.contains_key(*k)))
        .collect();
    for name in entry_names {
        let b = baseline.get(name);
        let c = current.get(name);
        let mut fields: Vec<&String> = Vec::new();
        if let Some(b) = b {
            fields.extend(b.keys());
        }
        if let Some(c) = c {
            fields.extend(c.keys().filter(|k| !fields.contains(k)));
        }
        for field in fields {
            let bv = b.and_then(|m| m.get(field)).copied();
            let cv = c.and_then(|m| m.get(field)).copied();
            let ratio = match (bv, cv) {
                (Some(b), Some(c)) if b != 0.0 => format!("{:.3}", c / b),
                _ => "-".into(),
            };
            t.row(&[name.clone(), field.clone(), fmt(bv), fmt(cv), ratio]);
        }
    }
    println!("bench report: {current_path} vs baseline {baseline_path}");
    match (read_host(current_path)?, read_host(baseline_path)?) {
        (Some(c), Some(b)) if c == b => println!("host: {c} (matches baseline)"),
        (c, b) => {
            let c = c.unwrap_or_else(|| "<unrecorded>".into());
            let b = b.unwrap_or_else(|| "<unrecorded>".into());
            eprintln!(
                "==========================================================================\n\
                 WARNING: baseline host differs from the current host — absolute fields\n\
                 (qps, median_us) below are NOT comparable; trust only same-run ratio\n\
                 fields (speedup_vs_scalar, f32_speedup_vs_f64).\n\
                 baseline host: {b}\n\
                 current host:  {c}\n\
                 =========================================================================="
            );
        }
    }
    t.print();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    if args.positional.len() != 2 {
        bail!(
            "usage: bench_gate <current.json> <baseline.json> \
             [--entry NAME] [--field FIELD] [--min-ratio R] [--report]"
        );
    }
    if args.bool("report", false)? {
        return print_report(&args.positional[0], &args.positional[1]);
    }
    let entry = args.str("entry", "engine_lookup_gather_b256_t1");
    let field = args.str("field", "qps");
    let min_ratio = args.f64("min-ratio", 0.35)?;
    let current = read_field(&args.positional[0], &entry, &field)?;
    let baseline = read_field(&args.positional[1], &entry, &field)?;
    if baseline <= 0.0 {
        bail!("baseline {entry}.{field} is {baseline}: nothing to gate against");
    }
    let ratio = current / baseline;
    println!(
        "perf gate: {entry}.{field} = {current:.4e} vs baseline {baseline:.4e} \
         (ratio {ratio:.3}, floor {min_ratio:.2})"
    );
    if ratio < min_ratio {
        eprintln!(
            "PERF REGRESSION: {entry}.{field} fell to {:.1}% of the recorded baseline \
             (floor is {:.1}%)",
            ratio * 100.0,
            min_ratio * 100.0
        );
        std::process::exit(1);
    }
    println!("perf gate passed");
    Ok(())
}
