//! `tidy` — the repo-native lexical static-analysis pass.
//!
//! Run as `cargo run --release --bin tidy`; CI runs it as a required
//! gate next to fmt/clippy (see `.github/workflows/ci.yml` and
//! `docs/static-analysis.md`).  The tool walks `rust/src`, strips
//! string literals and comments with a small Rust lexer, tracks
//! `#[cfg(test)]` / `mod tests` regions by brace depth, and enforces
//! five repo conventions that rustc and clippy cannot express:
//!
//! 1. **SAFETY** — every `unsafe` block/impl/fn carries a `// SAFETY:`
//!    (or `/// # Safety`) comment on or immediately above it.
//! 2. **no prod panics** — no `.unwrap()` / `.expect(` / `panic!` /
//!    `todo!` / `unimplemented!` in production code under
//!    `rust/src/{server,checkpoint,lattice,model}` outside test regions.
//! 3. **ORDERING** — every `Ordering::Relaxed` / `Ordering::SeqCst` use
//!    carries a nearby `// ORDERING:` justification (the fence-free
//!    orderings are exactly the ones whose correctness is non-local).
//! 4. **failpoint registry** — every `failpoint::inject("site")` call
//!    site is registered in `failpoint::SITES`, and every registered
//!    site has a production call site, appears in `docs/robustness.md`,
//!    and is exercised by `rust/tests/chaos.rs`.
//! 5. **tracked locks** — production modules use
//!    `util::lockcheck::{Mutex, RwLock}` (the lock-order race detector)
//!    instead of raw `std::sync` locks.
//!
//! Exceptions go through [`ALLOWLIST`] — one entry per blessed line,
//! keyed by path suffix + a needle that must appear on the raw line,
//! with a written reason.  Unused allowlist entries are themselves
//! errors, so the list can only shrink or stay honest.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use lram::util::failpoint::SITES;

/// Production directories for checks 2 and 5 (repo-relative prefixes).
const PROD_DIRS: &[&str] = &[
    "rust/src/server/",
    "rust/src/checkpoint/",
    "rust/src/lattice/",
    "rust/src/model/",
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Check {
    Safety,
    ProdPanic,
    OrderingDoc,
    Failpoints,
    RawLocks,
}

impl Check {
    fn name(self) -> &'static str {
        match self {
            Check::Safety => "safety-comments",
            Check::ProdPanic => "no-prod-panics",
            Check::OrderingDoc => "ordering-justified",
            Check::Failpoints => "failpoint-registry",
            Check::RawLocks => "tracked-locks",
        }
    }
}

/// One blessed exception: suppresses a violation of `check` on any line
/// of a file whose repo-relative path ends with `path_suffix`, provided
/// the raw line contains `needle`.  `reason` documents why the
/// exception is sound; an entry that suppresses nothing is an error.
struct Allow {
    check: Check,
    path_suffix: &'static str,
    needle: &'static str,
    reason: &'static str,
}

const ALLOWLIST: &[Allow] = &[Allow {
    check: Check::ProdPanic,
    path_suffix: "lattice/e8.rs",
    needle: "vec8 callers hand in exactly-8-lane slices",
    reason: "vec8() centralises the structurally-infallible 8-lane slice \
             conversion; every former per-call-site unwrap routes through \
             this single blessed expect",
}];

#[derive(Debug)]
struct Violation {
    check: Check,
    rel: String,
    line: usize, // 1-based; 0 for whole-file findings
    msg: String,
}

// -- lexical scanner -------------------------------------------------------

/// A scanned source file: per line, the raw text, the *code* view (string
/// literal contents and comments blanked to spaces), the *comment* text
/// (line + block + doc comments), and whether the line sits inside a
/// `#[cfg(test)]` / `mod tests` region.
struct Scanned {
    rel: String,
    raw: Vec<String>,
    code: Vec<String>,
    comment: Vec<String>,
    test: Vec<bool>,
}

/// Cross-line lexer state (strings and block comments span lines).
#[derive(Clone, Copy)]
enum LexState {
    Code,
    Block(u32),    // nested block-comment depth
    Str,           // inside "..." (or b"...")
    RawStr(usize), // inside r"…" / r#"…"# … with this many hashes
}

/// Lex `text`, producing the code/comment views.  The lexer understands
/// line and nested block comments, plain/byte/raw string literals, and
/// disambiguates char literals from lifetimes with one-char lookahead.
fn scan(rel: &str, text: &str) -> Scanned {
    let mut raw = Vec::new();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let mut state = LexState::Code;
    for line in text.lines() {
        raw.push(line.to_string());
        let chars: Vec<char> = line.chars().collect();
        let n = chars.len();
        let mut code = String::with_capacity(n);
        let mut comment = String::new();
        let mut i = 0usize;
        while i < n {
            match state {
                LexState::Block(depth) => {
                    if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                        state = LexState::Block(depth + 1);
                        code.push_str("  ");
                        i += 2;
                    } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                        state =
                            if depth <= 1 { LexState::Code } else { LexState::Block(depth - 1) };
                        code.push_str("  ");
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::Str => {
                    if chars[i] == '\\' {
                        code.push_str("  ");
                        i += 2; // escaped char (incl. \" and \\)
                    } else {
                        if chars[i] == '"' {
                            state = LexState::Code;
                        }
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::RawStr(hashes) => {
                    let mut closes = false;
                    if chars[i] == '"' {
                        let mut k = 0;
                        while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                            k += 1;
                        }
                        closes = k == hashes;
                    }
                    if closes {
                        state = LexState::Code;
                        for _ in 0..=hashes {
                            code.push(' ');
                        }
                        i += 1 + hashes;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::Code => {
                    let c = chars[i];
                    if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                        // line comment (incl. /// and //!): rest of line
                        comment.push_str(&chars[i + 2..].iter().collect::<String>());
                        break;
                    }
                    if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                        state = LexState::Block(1);
                        code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    // raw (byte) string start: r"…", r#"…, br"…, br#"… —
                    // only when the prefix begins a token
                    if (c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r'))
                        && !prev_is_ident(&chars, i)
                    {
                        let after_r = if c == 'b' { i + 2 } else { i + 1 };
                        let mut j = after_r;
                        while j < n && chars[j] == '#' {
                            j += 1;
                        }
                        if j < n && chars[j] == '"' {
                            state = LexState::RawStr(j - after_r);
                            for _ in i..=j {
                                code.push(' ');
                            }
                            i = j + 1;
                            continue;
                        }
                        // not a raw string (e.g. plain ident): keep as code
                        code.push(c);
                        i += 1;
                        continue;
                    }
                    if c == '"' {
                        state = LexState::Str;
                        code.push(' ');
                        i += 1;
                        continue;
                    }
                    if c == '\'' {
                        // char literal vs lifetime/label
                        if i + 1 < n && chars[i + 1] == '\\' {
                            // escaped char literal: skip to the closing quote
                            let mut j = i + 2;
                            while j < n && chars[j] != '\'' {
                                j += 1;
                            }
                            let end = j.min(n.saturating_sub(1));
                            for _ in i..=end {
                                code.push(' ');
                            }
                            i = j + 1;
                            continue;
                        }
                        if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' {
                            // plain char literal 'x' (incl. '"')
                            code.push_str("   ");
                            i += 3;
                            continue;
                        }
                        // lifetime or loop label: plain code
                        code.push(c);
                        i += 1;
                        continue;
                    }
                    code.push(c);
                    i += 1;
                }
            }
        }
        code_lines.push(code);
        comment_lines.push(comment);
    }
    let test = mark_test_regions(&code_lines);
    Scanned { rel: rel.to_string(), raw, code: code_lines, comment: comment_lines, test }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Mark lines inside `#[cfg(test)]` / `mod tests` regions: the marker
/// line arms the tracker, the next `{` opens a region closed at its
/// matching brace; a `;` before any `{` disarms (e.g. `mod tests;`).
fn mark_test_regions(code: &[String]) -> Vec<bool> {
    let mut test = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut open_at: Vec<i64> = Vec::new();
    let mut armed = false;
    for (li, line) in code.iter().enumerate() {
        if line.contains("#[cfg(test)]")
            || (contains_word(line, "mod") && contains_word(line, "tests"))
        {
            armed = true;
        }
        if armed || !open_at.is_empty() {
            test[li] = true;
        }
        for ch in line.chars() {
            match ch {
                '{' => {
                    if armed {
                        open_at.push(depth);
                        armed = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if open_at.last() == Some(&depth) {
                        open_at.pop();
                    }
                }
                ';' => {
                    if armed && open_at.is_empty() {
                        armed = false;
                    }
                }
                _ => {}
            }
        }
    }
    test
}

/// Word-boundary containment: `word` not embedded in a larger identifier.
fn contains_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does the comment on `li`, or on the comment/attribute block directly
/// above it, contain one of `needles`?  The walk stops at the first real
/// code line (attributes and blank lines are transparent).
fn comment_on_or_above(s: &Scanned, li: usize, needles: &[&str], max_walk: usize) -> bool {
    let hit = |i: usize| needles.iter().any(|n| s.comment[i].contains(n));
    if hit(li) {
        return true;
    }
    let mut i = li;
    let mut walked = 0;
    while i > 0 && walked < max_walk {
        i -= 1;
        walked += 1;
        if hit(i) {
            return true;
        }
        let code = s.code[i].trim();
        if !code.is_empty() && !code.starts_with("#[") && !code.starts_with("#!") {
            return false;
        }
    }
    false
}

/// Like [`comment_on_or_above`] but window-based: any comment within the
/// `window` preceding lines counts, code or not.  Used for check 3,
/// where the justified token may sit mid-call (e.g. the failure ordering
/// of a multi-line `compare_exchange_weak`).
fn comment_within(s: &Scanned, li: usize, needles: &[&str], window: usize) -> bool {
    let lo = li.saturating_sub(window);
    (lo..=li).any(|i| needles.iter().any(|n| s.comment[i].contains(n)))
}

fn in_prod_dir(rel: &str) -> bool {
    PROD_DIRS.iter().any(|d| rel.starts_with(d))
}

// -- checks ----------------------------------------------------------------

fn allowed(check: Check, rel: &str, raw_line: &str, used: &mut [bool]) -> bool {
    for (i, a) in ALLOWLIST.iter().enumerate() {
        if a.check == check && rel.ends_with(a.path_suffix) && raw_line.contains(a.needle) {
            used[i] = true;
            return true;
        }
    }
    false
}

/// Check 1: every `unsafe` carries a SAFETY comment on or above it.
fn check_safety(files: &[Scanned], used: &mut [bool], out: &mut Vec<Violation>) {
    const NEEDLES: &[&str] = &["SAFETY:", "# Safety"];
    for s in files {
        for (li, code) in s.code.iter().enumerate() {
            if !contains_word(code, "unsafe") {
                continue;
            }
            if comment_on_or_above(s, li, NEEDLES, 15) {
                continue;
            }
            if allowed(Check::Safety, &s.rel, &s.raw[li], used) {
                continue;
            }
            out.push(Violation {
                check: Check::Safety,
                rel: s.rel.clone(),
                line: li + 1,
                msg: "`unsafe` without a `// SAFETY:` (or `/// # Safety`) comment \
                      on or immediately above it"
                    .into(),
            });
        }
    }
}

/// Check 2: no panicking constructs in production code.
fn check_prod_panics(files: &[Scanned], used: &mut [bool], out: &mut Vec<Violation>) {
    const PATTERNS: &[&str] = &[".unwrap()", ".expect(", "panic!(", "todo!(", "unimplemented!("];
    for s in files.iter().filter(|s| in_prod_dir(&s.rel)) {
        for (li, code) in s.code.iter().enumerate() {
            if s.test[li] {
                continue;
            }
            for p in PATTERNS {
                if !code.contains(p) {
                    continue;
                }
                if allowed(Check::ProdPanic, &s.rel, &s.raw[li], used) {
                    continue;
                }
                out.push(Violation {
                    check: Check::ProdPanic,
                    rel: s.rel.clone(),
                    line: li + 1,
                    msg: format!(
                        "`{p}` in production code; return a typed error (or add an \
                         ALLOWLIST entry with a written reason)"
                    ),
                });
            }
        }
    }
}

/// Check 3: fence-free atomic orderings carry a written justification.
fn check_ordering(files: &[Scanned], used: &mut [bool], out: &mut Vec<Violation>) {
    const TOKENS: &[&str] = &["Ordering::Relaxed", "Ordering::SeqCst"];
    for s in files {
        for (li, code) in s.code.iter().enumerate() {
            if s.test[li] {
                continue;
            }
            for t in TOKENS {
                if !code.contains(t) {
                    continue;
                }
                if comment_within(s, li, &["ORDERING:"], 18) {
                    continue;
                }
                if allowed(Check::OrderingDoc, &s.rel, &s.raw[li], used) {
                    continue;
                }
                out.push(Violation {
                    check: Check::OrderingDoc,
                    rel: s.rel.clone(),
                    line: li + 1,
                    msg: format!(
                        "`{t}` without a nearby `// ORDERING:` justification — say why \
                         this ordering is sufficient (or deliberately strong)"
                    ),
                });
            }
        }
    }
}

/// Check 4: the failpoint registry is the single source of truth.
fn check_failpoints(
    files: &[Scanned],
    sites: &[(&str, &str)],
    docs_text: &str,
    chaos_text: &str,
    out: &mut Vec<Violation>,
) {
    let mut called: Vec<&str> = Vec::new();
    for s in files {
        for (li, code) in s.code.iter().enumerate() {
            if s.test[li] || !code.contains("failpoint::inject(") {
                continue;
            }
            // site names live in string literals, blanked in the code
            // view: pull the literal off the raw line
            let raw = &s.raw[li];
            let lit = raw.find("inject(").map(|p| &raw[p..]).and_then(|r| r.split('"').nth(1));
            match lit {
                Some(site) => {
                    called.push(site);
                    if !sites.iter().any(|&(name, _)| name == site) {
                        out.push(Violation {
                            check: Check::Failpoints,
                            rel: s.rel.clone(),
                            line: li + 1,
                            msg: format!(
                                "failpoint site \"{site}\" is not registered in \
                                 `failpoint::SITES` — add it there (and to \
                                 docs/robustness.md and rust/tests/chaos.rs)"
                            ),
                        });
                    }
                }
                None => out.push(Violation {
                    check: Check::Failpoints,
                    rel: s.rel.clone(),
                    line: li + 1,
                    msg: "failpoint::inject with a non-literal site name; sites must \
                          be string literals so the registry stays checkable"
                        .into(),
                }),
            }
        }
    }
    for &(site, _) in sites {
        if !called.contains(&site) {
            out.push(Violation {
                check: Check::Failpoints,
                rel: "rust/src/util/failpoint.rs".into(),
                line: 0,
                msg: format!(
                    "registered failpoint site \"{site}\" has no production \
                     `failpoint::inject` call site — dead registry entry"
                ),
            });
        }
        if !docs_text.contains(site) {
            out.push(Violation {
                check: Check::Failpoints,
                rel: "docs/robustness.md".into(),
                line: 0,
                msg: format!("failpoint site \"{site}\" is missing from the docs site table"),
            });
        }
        if !chaos_text.contains(site) {
            out.push(Violation {
                check: Check::Failpoints,
                rel: "rust/tests/chaos.rs".into(),
                line: 0,
                msg: format!("failpoint site \"{site}\" is not exercised by the chaos tests"),
            });
        }
    }
}

/// Check 5: production modules use the tracked lockcheck wrappers.
fn check_raw_locks(files: &[Scanned], used: &mut [bool], out: &mut Vec<Violation>) {
    for s in files.iter().filter(|s| in_prod_dir(&s.rel)) {
        for (li, code) in s.code.iter().enumerate() {
            if s.test[li] {
                continue;
            }
            let qualified =
                code.contains("std::sync::Mutex") || code.contains("std::sync::RwLock");
            let imported = code.trim_start().starts_with("use std::sync")
                && (contains_word(code, "Mutex") || contains_word(code, "RwLock"));
            if !(qualified || imported) {
                continue;
            }
            if allowed(Check::RawLocks, &s.rel, &s.raw[li], used) {
                continue;
            }
            out.push(Violation {
                check: Check::RawLocks,
                rel: s.rel.clone(),
                line: li + 1,
                msg: "raw std::sync lock in a production module; use \
                      `util::lockcheck::{Mutex, RwLock}` with a declared rank so \
                      lock-order inversions fail fast in debug builds"
                    .into(),
            });
        }
    }
}

// -- driver ----------------------------------------------------------------

fn repo_root() -> PathBuf {
    std::env::var("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")))
}

/// All `.rs` files under `dir`, recursively, sorted for stable reports.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            rust_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// The whole pass over a checkout: scan `rust/src`, run all five checks,
/// and report unused allowlist entries.
fn run(root: &Path) -> Result<Vec<Violation>, String> {
    let src = root.join("rust/src");
    let mut paths = Vec::new();
    rust_files(&src, &mut paths).map_err(|e| format!("walking {}: {e}", src.display()))?;
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let text =
            std::fs::read_to_string(p).map_err(|e| format!("reading {}: {e}", p.display()))?;
        let rel = p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/");
        files.push(scan(&rel, &text));
    }
    let read = |rel: &str| {
        std::fs::read_to_string(root.join(rel)).map_err(|e| format!("reading {rel}: {e}"))
    };
    let docs_text = read("docs/robustness.md")?;
    let chaos_text = read("rust/tests/chaos.rs")?;

    let mut v = Vec::new();
    let mut used = vec![false; ALLOWLIST.len()];
    check_safety(&files, &mut used, &mut v);
    check_prod_panics(&files, &mut used, &mut v);
    check_ordering(&files, &mut used, &mut v);
    check_failpoints(&files, SITES, &docs_text, &chaos_text, &mut v);
    check_raw_locks(&files, &mut used, &mut v);
    for (i, a) in ALLOWLIST.iter().enumerate() {
        if !used[i] {
            v.push(Violation {
                check: a.check,
                rel: a.path_suffix.into(),
                line: 0,
                msg: format!(
                    "unused ALLOWLIST entry (needle {:?}): the exception it blessed is \
                     gone — delete the entry (reason was: {})",
                    a.needle, a.reason
                ),
            });
        }
    }
    Ok(v)
}

fn main() -> ExitCode {
    let root = repo_root();
    match run(&root) {
        Ok(v) if v.is_empty() => {
            println!("tidy: clean (5 checks over rust/src)");
            ExitCode::SUCCESS
        }
        Ok(v) => {
            let mut report = String::new();
            for x in &v {
                let _ = writeln!(report, "{}:{}: [{}] {}", x.rel, x.line, x.check.name(), x.msg);
            }
            eprint!("{report}");
            eprintln!("tidy: {} violation(s)", v.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("tidy: {e}");
            ExitCode::FAILURE
        }
    }
}

// -- self-tests ------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_one(rel: &str, text: &str) -> Vec<Scanned> {
        vec![scan(rel, text)]
    }

    fn fresh_used() -> Vec<bool> {
        vec![false; ALLOWLIST.len()]
    }

    fn violations_of(check: Check, v: &[Violation]) -> usize {
        v.iter().filter(|x| x.check == check).count()
    }

    #[test]
    fn scanner_blanks_strings_comments_and_char_literals() {
        let s = scan(
            "x.rs",
            r##"let a = "unsafe in a string"; // unsafe in a comment
let b = r#"raw "quoted" unsafe"#;
let c = '"'; let lt: &'static str = "x";
/* block unsafe
   still comment */ let after = 1;"##,
        );
        for line in &s.code {
            assert!(!line.contains("unsafe"), "leaked into code view: {line:?}");
        }
        assert!(s.comment[0].contains("unsafe in a comment"));
        assert!(s.comment[3].contains("block unsafe"));
        // code after a block comment closes is visible again
        assert!(s.code[4].contains("let after"));
        // the '"' char literal must not open a string, and the lifetime's
        // quote must not open a char literal that swallows the line
        assert!(s.code[2].contains("static"));
    }

    #[test]
    fn test_region_tracking_follows_braces() {
        let text = "fn prod() { x.unwrap(); }\n\
                    #[cfg(test)]\n\
                    mod tests {\n\
                    fn t() { y.unwrap(); }\n\
                    }\n\
                    fn prod2() { z.unwrap(); }\n";
        let s = scan("rust/src/server/x.rs", text);
        assert!(!s.test[0]);
        assert!(s.test[1] && s.test[2] && s.test[3] && s.test[4]);
        assert!(!s.test[5], "code after the test module is production again");
    }

    #[test]
    fn safety_check_flags_bare_unsafe_and_accepts_commented() {
        let bad = scan_one("rust/src/util/x.rs", "let p = unsafe { deref(q) };\n");
        let mut used = fresh_used();
        let mut v = Vec::new();
        check_safety(&bad, &mut used, &mut v);
        assert_eq!(violations_of(Check::Safety, &v), 1);

        let good = scan_one(
            "rust/src/util/x.rs",
            "// SAFETY: q is valid for reads, checked above.\n\
             let p = unsafe { deref(q) };\n\
             /// # Safety\n\
             /// Caller guarantees exclusivity.\n\
             #[inline]\n\
             pub unsafe fn f() {}\n",
        );
        let mut v = Vec::new();
        check_safety(&good, &mut used, &mut v);
        assert_eq!(violations_of(Check::Safety, &v), 0, "{v:?}");
    }

    #[test]
    fn prod_panic_check_scopes_to_prod_dirs_and_skips_tests() {
        let text = "fn f() { a.unwrap(); b.expect(\"x\"); panic!(\"y\"); }\n\
                    fn g() { c.unwrap_or_else(|p| p.into_inner()); }\n\
                    #[cfg(test)]\n\
                    mod tests { fn t() { d.unwrap(); } }\n";
        let mut used = fresh_used();
        let mut v = Vec::new();
        check_prod_panics(&scan_one("rust/src/server/x.rs", text), &mut used, &mut v);
        assert_eq!(violations_of(Check::ProdPanic, &v), 3, "{v:?}");

        // same text outside the production dirs: out of scope
        let mut v = Vec::new();
        check_prod_panics(&scan_one("rust/src/util/x.rs", text), &mut used, &mut v);
        assert_eq!(violations_of(Check::ProdPanic, &v), 0);
    }

    #[test]
    fn ordering_check_wants_a_written_justification() {
        let bad = scan_one("rust/src/util/x.rs", "flag.store(true, Ordering::Relaxed);\n");
        let mut used = fresh_used();
        let mut v = Vec::new();
        check_ordering(&bad, &mut used, &mut v);
        assert_eq!(violations_of(Check::OrderingDoc, &v), 1);

        let good = scan_one(
            "rust/src/util/x.rs",
            "// ORDERING: polled flag; staleness costs one extra poll.\n\
             flag.store(true, Ordering::Relaxed);\n",
        );
        let mut v = Vec::new();
        check_ordering(&good, &mut used, &mut v);
        assert_eq!(violations_of(Check::OrderingDoc, &v), 0, "{v:?}");
    }

    #[test]
    fn ordering_window_covers_midcall_tokens() {
        // the justified token may be an argument of a multi-line CAS,
        // lines below the comment — the window must reach it
        let mut text = String::from("// ORDERING: CAS failure reload may be relaxed.\n");
        for _ in 0..12 {
            text.push_str("let filler = 0;\n");
        }
        text.push_str("x.compare_exchange_weak(a, b,\n");
        text.push_str("    Ordering::SeqCst,\n    Ordering::Relaxed);\n");
        let mut used = fresh_used();
        let mut v = Vec::new();
        check_ordering(&scan_one("rust/src/util/x.rs", &text), &mut used, &mut v);
        assert_eq!(violations_of(Check::OrderingDoc, &v), 0, "{v:?}");
    }

    #[test]
    fn failpoint_check_cross_checks_registry_docs_and_chaos() {
        let sites: &[(&str, &str)] = &[("a.b", "site one"), ("c.d", "site two")];
        let files = scan_one(
            "rust/src/server/x.rs",
            "fn f() { failpoint::inject(\"a.b\"); }\n\
             fn g() { failpoint::inject(\"not.registered\"); }\n",
        );
        let mut v = Vec::new();
        check_failpoints(&files, sites, "docs mention a.b only", "chaos arms a.b", &mut v);
        let msgs: Vec<&str> = v.iter().map(|x| x.msg.as_str()).collect();
        // unregistered call site
        assert!(msgs.iter().any(|m| m.contains("not.registered")), "{msgs:?}");
        // c.d: no call site, missing from docs, missing from chaos
        assert_eq!(msgs.iter().filter(|m| m.contains("\"c.d\"")).count(), 3, "{msgs:?}");
        // a.b is fully wired: no violations about it
        assert!(!msgs.iter().any(|m| m.contains("\"a.b\"")), "{msgs:?}");
    }

    #[test]
    fn failpoint_sites_in_comments_or_tests_are_ignored() {
        let files = scan_one(
            "rust/src/server/x.rs",
            "// failpoint::inject(\"doc.example\") is how you arm one\n\
             #[cfg(test)]\n\
             mod tests { fn t() { failpoint::inject(\"t.adhoc\"); } }\n",
        );
        let mut v = Vec::new();
        check_failpoints(&files, &[], "", "", &mut v);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn raw_lock_check_flags_std_locks_in_prod_dirs_only() {
        let text = "use std::sync::{Arc, Mutex};\n\
                    static S: std::sync::RwLock<u32> = std::sync::RwLock::new(0);\n\
                    use std::sync::Arc;\n";
        let mut used = fresh_used();
        let mut v = Vec::new();
        check_raw_locks(&scan_one("rust/src/server/x.rs", text), &mut used, &mut v);
        assert_eq!(violations_of(Check::RawLocks, &v), 2, "{v:?}");

        // util (lockcheck itself, failpoint) may hold raw locks
        let mut v = Vec::new();
        check_raw_locks(&scan_one("rust/src/util/x.rs", text), &mut used, &mut v);
        assert_eq!(violations_of(Check::RawLocks, &v), 0);
    }

    #[test]
    fn allowlist_suppresses_and_tracks_usage() {
        // the real allowlist's vec8 entry, against a matching fixture
        let text = "fn f(chunk: &[f64]) -> &Vec8 {\n\
            chunk.try_into().expect(\"vec8 callers hand in exactly-8-lane slices\")\n\
            }\n";
        let mut used = fresh_used();
        let mut v = Vec::new();
        check_prod_panics(&scan_one("rust/src/lattice/e8.rs", text), &mut used, &mut v);
        assert_eq!(violations_of(Check::ProdPanic, &v), 0, "{v:?}");
        assert!(used[0], "the vec8 entry must be marked used");
    }

    #[test]
    fn the_real_tree_is_clean() {
        // the binary's contract: `cargo run --bin tidy` exits 0 on HEAD.
        // Running the full pass here keeps `cargo test` and the CI gate
        // in lockstep — a violation fails both, with the same message.
        let v = run(&repo_root()).expect("tidy walk must succeed");
        assert!(
            v.is_empty(),
            "tidy violations on the checked-in tree:\n{}",
            v.iter()
                .map(|x| format!("{}:{}: [{}] {}", x.rel, x.line, x.check.name(), x.msg))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
