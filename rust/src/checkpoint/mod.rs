//! Versioned on-disk checkpoints: train → save → serve trained weights.
//!
//! A checkpoint is a directory:
//!
//! ```text
//! ckpt/
//!   manifest.json     format tag, version, id, step, tokenizer hash,
//!                     model config, tensor index (shape + checksum)
//!   embed.bin         raw little-endian f32, row-major
//!   values.bin        the value table — mmap'd zero-copy at load
//!   ...
//! ```
//!
//! The split matters: the manifest is small, human-readable JSON parsed
//! with [`crate::util::json`]; the tensors are raw little-endian blobs
//! whose on-disk layout *is* the in-memory layout, so the multi-GB value
//! table is served straight out of the page cache via a copy-on-write
//! map ([`crate::memstore::ValueTable::open_cow`]) — the O(1)-lookup
//! serving claim survives persistence with no load-time copy.
//!
//! Format version 4 adds an optional *shard manifest*: when the value
//! table was saved partitioned for sharded serving, the manifest's
//! `shards.bounds` array records the row boundaries and the table blobs
//! are written per shard (`values_shard_<k>` plus matching q8
//! companions) instead of one monolithic `values`.  Unsharded v4
//! checkpoints serialize exactly as v3 did — the `shards` key is simply
//! absent — so their manifest bytes (and content ids) are unchanged.
//!
//! Failure discipline: every load-path mismatch — missing file, size
//! mismatch (truncation), checksum mismatch (corruption), version skew,
//! tokenizer drift — is a loud [`anyhow::Error`], never a silently
//! misweighted model.  Saves are *staged*: blobs and the manifest are
//! written into a `<dir>.tmp-*` sibling and atomically `rename`d into
//! place at [`CheckpointWriter::finish`], so a save killed at any point
//! while writing leaves an existing checkpoint at `<dir>` untouched and
//! openable (the manifest is still written last within the stage, so a
//! half-staged directory can never be opened either).  A kill in the
//! one non-atomic commit window (between moving the old checkpoint
//! aside and moving the stage in) leaves two *complete* copies at
//! sibling names; the next save restores one to the live name before
//! staging.  Stale debris is swept only right after a successful
//! commit, when a complete checkpoint is guaranteed at `<dir>`.
//!
//! Checksums are FNV-1a 64 (corruption detection, not cryptography).
//! Tensors up to [`EAGER_VERIFY_BYTES`] are verified at open; larger
//! blobs (the value table) are length-checked at open and fully
//! verified only by [`Checkpoint::verify`] (`lram checkpoint inspect
//! --verify`), because hashing a multi-GB blob would fault in every
//! page and defeat the zero-copy load.

use std::borrow::Cow;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::memstore::ValueTable;
use crate::util::failpoint;
use crate::util::fnv1a64;
use crate::util::json::{self, Json};
use crate::util::mmap::{MmapI8, MmapU32};

/// Format tag in every manifest; a different tag is not ours.
pub const FORMAT_TAG: &str = "lram-checkpoint";
/// Current format version, written into every manifest.  Version 2 was
/// the routing-gradient minor bump (optional `wq_adam_*` tensors in the
/// index).  Version 3 adds the `i8` tensor dtype and the quantized
/// value-table companion blobs (`values_q8` as `i8 [rows, m]` plus
/// `values_q8_scale` as `f32 [rows]`) that the f32-q8 serving path maps
/// zero-copy; the f64/f32 blob layout is unchanged.  Version 4 adds
/// the optional `shards` manifest section (row `bounds` of a
/// partitioned value table saved as per-shard `values_shard_<k>`
/// blobs); unsharded checkpoints omit it and keep the v3 byte layout.
/// Readers accept [`MIN_READ_VERSION`]`..=FORMAT_VERSION` —
/// version-1/2/3 checkpoints load fine (paths that want the q8 blobs
/// re-quantize from `values` when they are absent, and a manifest
/// without `shards` is one implicit shard) — and refuse anything newer
/// loudly: older readers equality- or range-check the field, so they
/// refuse checkpoints whose dtypes they cannot parse rather than
/// silently dropping state (a "best effort" load of a future layout
/// would serve garbage weights).
pub const FORMAT_VERSION: i64 = 4;
/// Oldest manifest version this reader still accepts.
pub const MIN_READ_VERSION: i64 = 1;
/// Manifest file name inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "manifest.json";
/// Tensors at most this large get their checksum verified at open.
pub const EAGER_VERIFY_BYTES: u64 = 4 << 20;

/// Element type of a checkpointed tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorDtype {
    F32,
    U32,
    /// Signed 8-bit codes (format version 3+): the quantized value-table
    /// blob.  Single-byte, so the on-disk layout is endian-free.
    I8,
}

impl TensorDtype {
    fn as_str(self) -> &'static str {
        match self {
            TensorDtype::F32 => "f32",
            TensorDtype::U32 => "u32",
            TensorDtype::I8 => "i8",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(TensorDtype::F32),
            "u32" => Ok(TensorDtype::U32),
            "i8" => Ok(TensorDtype::I8),
            other => bail!("unsupported tensor dtype '{other}'"),
        }
    }

    /// Bytes per element on disk.
    pub fn byte_width(self) -> u64 {
        match self {
            TensorDtype::F32 | TensorDtype::U32 => 4,
            TensorDtype::I8 => 1,
        }
    }
}

/// One tensor in the manifest index.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Logical name ("embed", "values", "adam_m", ...).
    pub name: String,
    /// Blob file name relative to the checkpoint directory.
    pub file: String,
    pub dtype: TensorDtype,
    pub shape: Vec<u64>,
    /// FNV-1a 64 over the blob bytes, 16 hex digits.
    pub checksum: String,
}

impl TensorSpec {
    /// Total elements, rejecting shape-product overflow — the same
    /// discipline as [`ValueTable::open`], so a hostile manifest can not
    /// wrap a huge tensor into a tiny allocation.
    pub fn element_count(&self) -> Result<u64> {
        self.shape
            .iter()
            .try_fold(1u64, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow!("tensor {}: shape {:?} overflows u64", self.name, self.shape))
    }

    /// Blob size in bytes (per-dtype element width).
    pub fn byte_len(&self) -> Result<u64> {
        self.element_count()?
            .checked_mul(self.dtype.byte_width())
            .ok_or_else(|| anyhow!("tensor {}: byte size overflows u64", self.name))
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("file", Json::Str(self.file.clone())),
            ("dtype", Json::Str(self.dtype.as_str().into())),
            ("shape", Json::Arr(self.shape.iter().map(|&d| Json::Num(d as f64)).collect())),
            ("checksum", Json::Str(self.checksum.clone())),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("tensor shape must be an array"))?
            .iter()
            .map(|d| {
                d.as_f64()
                    .filter(|f| *f >= 0.0)
                    .map(|f| f as u64)
                    .ok_or_else(|| anyhow!("tensor shape entries must be non-negative numbers"))
            })
            .collect::<Result<Vec<u64>>>()?;
        Ok(TensorSpec {
            name: req_str(v, "name")?,
            file: req_str(v, "file")?,
            dtype: TensorDtype::parse(&req_str(v, "dtype")?)?,
            shape,
            checksum: req_str(v, "checksum")?,
        })
    }
}

/// The model geometry a checkpoint was trained with.  Serving validates
/// compatibility against this — it is the config side of "serve what you
/// trained", next to the tensor blobs themselves.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDesc {
    pub vocab: usize,
    pub width: usize,
    pub heads: usize,
    pub m: usize,
    pub k_top: usize,
    pub seq_len: usize,
    /// Serving-batch hint recorded at save time (overridable at load).
    pub max_batch: usize,
    /// Torus side lengths — the lattice geometry; value-table row count
    /// is a pure function of this.
    pub torus_k: [i64; 8],
    pub query_scale: f64,
}

impl ModelDesc {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::Num(self.vocab as f64)),
            ("width", Json::Num(self.width as f64)),
            ("heads", Json::Num(self.heads as f64)),
            ("m", Json::Num(self.m as f64)),
            ("k_top", Json::Num(self.k_top as f64)),
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("torus_k", Json::from_i64s(&self.torus_k)),
            ("query_scale", Json::Num(self.query_scale)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let req_usize = |k: &str| -> Result<usize> {
            v.req(k)?.as_usize().ok_or_else(|| anyhow!("model.{k} must be a non-negative number"))
        };
        let tk = v.req("torus_k")?.as_i64_vec()?;
        ensure!(tk.len() == 8, "model.torus_k must have 8 entries, got {}", tk.len());
        let mut torus_k = [0i64; 8];
        torus_k.copy_from_slice(&tk);
        Ok(ModelDesc {
            vocab: req_usize("vocab")?,
            width: req_usize("width")?,
            heads: req_usize("heads")?,
            m: req_usize("m")?,
            k_top: req_usize("k_top")?,
            seq_len: req_usize("seq_len")?,
            max_batch: req_usize("max_batch")?,
            torus_k,
            query_scale: v
                .req("query_scale")?
                .as_f64()
                .ok_or_else(|| anyhow!("model.query_scale must be a number"))?,
        })
    }
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub version: i64,
    /// Content-derived id (`ck-` + 16 hex), surfaced in `/stats`.
    pub checkpoint_id: String,
    /// Trainer step the checkpoint was taken at.
    pub step: u64,
    /// [`crate::tokenizer::Bpe::fingerprint`] of the training tokenizer.
    pub tokenizer_hash: String,
    pub model: ModelDesc,
    pub tensors: Vec<TensorSpec>,
    /// Row boundaries of a partitioned value table (format version 4+):
    /// shard `k` owns rows `bounds[k]..bounds[k+1]` of the logical table
    /// and its blob is `values_shard_<k>`.  `None` — the common case —
    /// means one monolithic `values` blob, and is *omitted* from the
    /// JSON entirely so unsharded manifests stay byte-identical to v3.
    pub shards: Option<Vec<u64>>,
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("'{key}' must be a string"))?
        .to_string())
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("format", Json::Str(FORMAT_TAG.into())),
            ("version", Json::Num(self.version as f64)),
            ("checkpoint_id", Json::Str(self.checkpoint_id.clone())),
            ("step", Json::Num(self.step as f64)),
            ("tokenizer_hash", Json::Str(self.tokenizer_hash.clone())),
            ("model", self.model.to_json()),
            ("tensors", Json::Arr(self.tensors.iter().map(TensorSpec::to_json).collect())),
        ];
        if let Some(bounds) = &self.shards {
            // only sharded checkpoints carry the key: unsharded manifests
            // must serialize byte-identically to format version 3
            pairs.push((
                "shards",
                Json::obj(vec![(
                    "bounds",
                    Json::Arr(bounds.iter().map(|&b| Json::Num(b as f64)).collect()),
                )]),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let format = req_str(v, "format")?;
        ensure!(
            format == FORMAT_TAG,
            "not an lram checkpoint (format tag '{format}', expected '{FORMAT_TAG}')"
        );
        let version = v
            .req("version")?
            .as_i64()
            .ok_or_else(|| anyhow!("'version' must be a number"))?;
        ensure!(
            (MIN_READ_VERSION..=FORMAT_VERSION).contains(&version),
            "checkpoint format version {version} is not supported (this build reads \
             versions {MIN_READ_VERSION} through {FORMAT_VERSION}); refusing to guess \
             at the layout — if a newer lram wrote it, upgrade this reader"
        );
        let tensors = v
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| anyhow!("'tensors' must be an array"))?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let shards = match v.get("shards") {
            None => None,
            Some(s) => Some(
                s.req("bounds")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("'shards.bounds' must be an array"))?
                    .iter()
                    .map(|d| {
                        d.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64).ok_or_else(|| {
                            anyhow!("'shards.bounds' entries must be non-negative numbers")
                        })
                    })
                    .collect::<Result<Vec<u64>>>()?,
            ),
        };
        Ok(Manifest {
            version,
            checkpoint_id: req_str(v, "checkpoint_id")?,
            step: v.req("step")?.as_usize().ok_or_else(|| anyhow!("'step' must be a number"))?
                as u64,
            tokenizer_hash: req_str(v, "tokenizer_hash")?,
            model: ModelDesc::from_json(v.req("model")?)?,
            tensors,
            shards,
        })
    }

    pub fn tensor(&self, name: &str) -> Result<&TensorSpec> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("checkpoint has no tensor '{name}'"))
    }

    pub fn has_tensor(&self, name: &str) -> bool {
        self.tensors.iter().any(|t| t.name == name)
    }
}

// -- byte-level helpers ----------------------------------------------------

/// View f32s as little-endian bytes (zero-copy on LE hosts).
fn f32s_as_le_bytes(data: &[f32]) -> Cow<'_, [u8]> {
    if cfg!(target_endian = "little") {
        // SAFETY: f32 has no invalid bit patterns as bytes; len*4 fits
        // because the slice already exists in memory.
        Cow::Borrowed(unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        })
    } else {
        Cow::Owned(data.iter().flat_map(|v| v.to_le_bytes()).collect())
    }
}

fn u32s_as_le_bytes(data: &[u32]) -> Cow<'_, [u8]> {
    if cfg!(target_endian = "little") {
        // SAFETY: as above.
        Cow::Borrowed(unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        })
    } else {
        Cow::Owned(data.iter().flat_map(|v| v.to_le_bytes()).collect())
    }
}

/// View i8 codes as bytes (zero-copy on every host: single-byte
/// elements have no endianness).
fn i8s_as_bytes(data: &[i8]) -> &[u8] {
    // SAFETY: i8 and u8 have identical size/alignment and every bit
    // pattern is valid for both; the slice already exists in memory.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) }
}

fn checksum_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

/// Blob file name for a tensor ("adam/m" → "adam_m.bin").
fn blob_file_name(name: &str) -> String {
    let safe: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
        .collect();
    format!("{safe}.bin")
}

// -- writer ----------------------------------------------------------------

/// Streams tensors into a *staging* directory next to the target, then
/// seals the manifest and atomically renames the stage into place.
/// Overwriting an existing checkpoint is crash-safe: until the final
/// rename, the old checkpoint at `dir` stays untouched and openable; a
/// save killed mid-write leaves only a `<dir>.tmp-*` sibling, which the
/// next save sweeps.
///
/// ```no_run
/// # use lram::checkpoint::{CheckpointWriter, ModelDesc};
/// # fn demo(model: ModelDesc) -> anyhow::Result<()> {
/// let mut w = CheckpointWriter::new("ckpt".as_ref())?;
/// w.write_f32("embed", &[512, 64], &vec![0.0; 512 * 64])?;
/// let manifest = w.finish(100, "0123456789abcdef", model)?;
/// println!("saved {}", manifest.checkpoint_id);
/// # Ok(()) }
/// ```
pub struct CheckpointWriter {
    /// Where the checkpoint lands at [`Self::finish`].
    final_dir: PathBuf,
    /// Where blobs are written until then.
    stage: PathBuf,
    tensors: Vec<TensorSpec>,
    committed: bool,
    /// fsync blobs, the manifest, and the directories on commit (see
    /// [`Self::with_fsync`]).
    fsync: bool,
    /// total checkpoints retained: the live one plus up to `keep - 1`
    /// `<dir>.prev-<step>` predecessors (see [`Self::with_keep`]).
    keep: usize,
    /// Row bounds of a partitioned value table (see
    /// [`Self::with_shards`]); `None` for the common unsharded save.
    shards: Option<Vec<u64>>,
}

/// Monotonic suffix so sequential (or accidentally overlapping) writers
/// in one process never share a staging directory.  Note that
/// *concurrent* saves into the same final path are still unsupported:
/// whichever commits last wins, and its post-commit sweep removes the
/// other's leftovers.
static STAGE_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// `<dir>.{tag}-<pid>-<n>`, as a sibling of `dir`.
fn sibling_dir(dir: &Path, tag: &str) -> PathBuf {
    // ORDERING: uniqueness only — fetch_add's atomicity guarantees
    // distinct suffixes; no other memory is published through the counter
    let n = STAGE_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut name = dir.as_os_str().to_os_string();
    name.push(format!(".{tag}-{}-{n}", std::process::id()));
    PathBuf::from(name)
}

/// `<dir>.tmp-*` / `<dir>.old-*` siblings left by saves that were
/// killed mid-write or mid-commit.
fn stale_commit_siblings(dir: &Path) -> Vec<PathBuf> {
    let parent = match dir.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    let name = match dir.file_name().and_then(|n| n.to_str()) {
        Some(n) => n,
        None => return Vec::new(),
    };
    let entries = match std::fs::read_dir(parent) {
        Ok(e) => e,
        Err(_) => return Vec::new(),
    };
    entries
        .flatten()
        .filter(|e| {
            e.file_name().to_str().is_some_and(|n| {
                n.starts_with(&format!("{name}.tmp-")) || n.starts_with(&format!("{name}.old-"))
            })
        })
        .map(|e| e.path())
        .collect()
}

/// Best-effort sweep of stale commit debris.  Only called right after a
/// successful commit, when a complete checkpoint sits at `dir` — never
/// while `dir` might be missing, so recovery copies are never destroyed.
fn sweep_stale_stages(dir: &Path) {
    for p in stale_commit_siblings(dir) {
        let _ = std::fs::remove_dir_all(p);
    }
}

/// Repair a save that was killed *between* the two commit renames: the
/// live name is empty but a complete previous checkpoint (manifest
/// present) sits at a `<dir>.old-*` sibling.  Restore it so the live
/// name always holds the best complete checkpoint available.  A
/// complete-but-uncommitted `<dir>.tmp-*` stage is restored only if no
/// `.old-*` exists (prefer the checkpoint that was actually committed
/// once over one that never was).
fn recover_interrupted_commit(dir: &Path) {
    if dir.exists() {
        return;
    }
    let mut old = None;
    let mut tmp = None;
    for p in stale_commit_siblings(dir) {
        if !p.join(MANIFEST_FILE).is_file() {
            continue; // incomplete stage: not a usable checkpoint
        }
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
        if name.contains(".old-") && old.is_none() {
            old = Some(p);
        } else if name.contains(".tmp-") && tmp.is_none() {
            tmp = Some(p);
        }
    }
    if let Some(source) = old.or(tmp) {
        match std::fs::rename(&source, dir) {
            Ok(()) => log::warn!(
                "recovered checkpoint {} from interrupted save ({})",
                dir.display(),
                source.display()
            ),
            Err(e) => log::warn!(
                "could not recover {} from {}: {e}",
                dir.display(),
                source.display()
            ),
        }
    }
}

/// Retained predecessors of a checkpoint path — every complete
/// `<dir>.prev-<step>` sibling, sorted newest-first by step.  These are
/// written by [`CheckpointWriter::with_keep`] and consumed by
/// [`Checkpoint::open_with_fallback`].
pub fn prev_siblings(dir: &Path) -> Vec<(u64, PathBuf)> {
    let parent = match dir.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    let name = match dir.file_name().and_then(|n| n.to_str()) {
        Some(n) => n,
        None => return Vec::new(),
    };
    let prefix = format!("{name}.prev-");
    let entries = match std::fs::read_dir(parent) {
        Ok(e) => e,
        Err(_) => return Vec::new(),
    };
    let mut prevs: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| {
            let fname = e.file_name();
            let step = fname.to_str()?.strip_prefix(&prefix)?.parse::<u64>().ok()?;
            Some((step, e.path()))
        })
        .collect();
    prevs.sort_by(|a, b| b.0.cmp(&a.0));
    prevs
}

/// `<dir>.prev-<step>` for a displaced checkpoint at `step`.
fn prev_path(dir: &Path, step: u64) -> PathBuf {
    let mut name = dir.as_os_str().to_os_string();
    name.push(format!(".prev-{step}"));
    PathBuf::from(name)
}

/// Retire the just-displaced old checkpoint (currently at `old`, a
/// `<dir>.old-*` sibling) into the `<dir>.prev-<step>` retention slot
/// instead of deleting it.  Best-effort: retention failures are logged,
/// never allowed to fail the save that already committed.
fn retire_previous(dir: &Path, old: &Path) {
    let step = std::fs::read_to_string(old.join(MANIFEST_FILE))
        .ok()
        .and_then(|text| json::parse(&text).ok())
        .and_then(|v| v.req("step").ok().and_then(|s| s.as_usize()))
        .map(|s| s as u64);
    let Some(step) = step else {
        // a committed checkpoint without a readable step should not
        // exist; do not let an unreadable one poison the retention set
        log::warn!("retiring {}: unreadable manifest step, deleting instead", old.display());
        let _ = std::fs::remove_dir_all(old);
        return;
    };
    let target = prev_path(dir, step);
    if target.exists() {
        // same step saved twice: the newer bytes win the slot
        let _ = std::fs::remove_dir_all(&target);
    }
    if let Err(e) = std::fs::rename(old, &target) {
        log::warn!("retiring {} to {}: {e}", old.display(), target.display());
        let _ = std::fs::remove_dir_all(old);
    }
}

/// Delete retained predecessors beyond the newest `keep_prev`.
fn prune_previous(dir: &Path, keep_prev: usize) {
    for (_, p) in prev_siblings(dir).into_iter().skip(keep_prev) {
        let _ = std::fs::remove_dir_all(&p);
    }
}

impl CheckpointWriter {
    pub fn new(dir: &Path) -> Result<Self> {
        if let Some(parent) = dir.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        // a previous save may have been killed between its two commit
        // renames, leaving the live name empty but a complete checkpoint
        // at a sibling: restore it first (never delete recovery copies
        // here — sweeping happens only after a successful commit)
        recover_interrupted_commit(dir);
        let stage = sibling_dir(dir, "tmp");
        std::fs::create_dir_all(&stage)
            .with_context(|| format!("creating checkpoint staging dir {}", stage.display()))?;
        Ok(CheckpointWriter {
            final_dir: dir.to_path_buf(),
            stage,
            tensors: Vec::new(),
            committed: false,
            fsync: false,
            keep: 1,
            shards: None,
        })
    }

    /// Declare the row bounds of a partitioned value table (format
    /// version 4): shard `k` of the logical table owns rows
    /// `bounds[k]..bounds[k+1]` and its blob was written as
    /// `values_shard_<k>`.  The bounds land in the manifest's `shards`
    /// section; without this call the key is omitted entirely and the
    /// manifest stays byte-identical to an unsharded v3 save.
    pub fn with_shards(mut self, bounds: Vec<u64>) -> Self {
        self.shards = Some(bounds);
        self
    }

    /// Retain up to `keep` checkpoints total: the live one at `dir`,
    /// plus the `keep - 1` most recent predecessors at
    /// `<dir>.prev-<step>` siblings.  Predecessors are what
    /// [`Checkpoint::open_with_fallback`] falls back to when the live
    /// checkpoint is corrupt — with the default `keep = 1` there is
    /// nothing to fall back to and overwriting deletes the old copy,
    /// exactly the pre-retention behavior.  `keep = 0` is treated as 1.
    pub fn with_keep(mut self, keep: usize) -> Self {
        self.keep = keep.max(1);
        self
    }

    /// Opt into fsyncing every blob, the manifest, and the enclosing
    /// directories around the commit renames.  The staged-rename
    /// protocol already survives process *crashes*; with fsync the
    /// committed checkpoint also survives *power loss* — without it,
    /// the rename can hit the journal before the blob data does, and a
    /// badly-timed outage leaves a committed name over zero-length
    /// blobs (which `open` would at least refuse loudly, but the
    /// checkpoint is gone).  Costs one `fsync` per blob plus two
    /// directory syncs; exposed as `lram train --fsync`.
    pub fn with_fsync(mut self, on: bool) -> Self {
        self.fsync = on;
        self
    }

    fn write_blob(
        &mut self,
        name: &str,
        shape: &[u64],
        dtype: TensorDtype,
        bytes: &[u8],
    ) -> Result<()> {
        ensure!(
            !self.tensors.iter().any(|t| t.name == name),
            "duplicate tensor '{name}' in checkpoint"
        );
        let spec = TensorSpec {
            name: name.to_string(),
            file: blob_file_name(name),
            dtype,
            shape: shape.to_vec(),
            checksum: checksum_hex(bytes),
        };
        ensure!(
            !self.tensors.iter().any(|t| t.file == spec.file),
            "tensor '{name}' collides with an existing blob file '{}'",
            spec.file
        );
        let expect = spec.byte_len()?;
        ensure!(
            bytes.len() as u64 == expect,
            "tensor '{name}': {} bytes for shape {:?} ({expect} expected)",
            bytes.len(),
            shape
        );
        let path = self.stage.join(&spec.file);
        write_file(&path, bytes, self.fsync)?;
        self.tensors.push(spec);
        Ok(())
    }

    pub fn write_f32(&mut self, name: &str, shape: &[u64], data: &[f32]) -> Result<()> {
        self.write_blob(name, shape, TensorDtype::F32, &f32s_as_le_bytes(data))
    }

    pub fn write_u32(&mut self, name: &str, shape: &[u64], data: &[u32]) -> Result<()> {
        self.write_blob(name, shape, TensorDtype::U32, &u32s_as_le_bytes(data))
    }

    /// Write an i8 tensor (format version 3+: quantized value codes).
    pub fn write_i8(&mut self, name: &str, shape: &[u64], data: &[i8]) -> Result<()> {
        self.write_blob(name, shape, TensorDtype::I8, i8s_as_bytes(data))
    }

    /// Seal the checkpoint: derive the content id, write the manifest
    /// (last, so a half-staged directory can never be opened), then
    /// atomically rename the stage over `dir`.  An existing checkpoint
    /// at `dir` stays openable right up to the commit renames.
    pub fn finish(mut self, step: u64, tokenizer_hash: &str, model: ModelDesc) -> Result<Manifest> {
        let mut manifest = Manifest {
            version: FORMAT_VERSION,
            checkpoint_id: String::new(),
            step,
            tokenizer_hash: tokenizer_hash.to_string(),
            model,
            tensors: std::mem::take(&mut self.tensors),
            shards: self.shards.take(),
        };
        // content id over the manifest with the id field still empty:
        // any change to config, step, tokenizer or tensor bytes (via the
        // per-tensor checksums) changes the id
        manifest.checkpoint_id =
            format!("ck-{:016x}", fnv1a64(manifest.to_json().to_string().as_bytes()));
        let path = self.stage.join(MANIFEST_FILE);
        write_file(&path, manifest.to_json().to_string().as_bytes(), self.fsync)?;
        if self.fsync {
            // make the staged *directory entries* durable before the
            // commit renames can possibly hit the journal
            sync_dir(&self.stage)?;
        }
        // commit: the stage is complete, swap it into place.  rename()
        // cannot replace a non-empty directory, so an existing
        // checkpoint is first moved aside (atomic), then the stage moves
        // in (atomic), then the old copy is deleted.  A kill between
        // the two renames is the one non-atomic window: it leaves the
        // complete old copy at `<dir>.old-*` and the complete new one at
        // `<dir>.tmp-*` — never a torn mix under the live name.
        if self.final_dir.exists() {
            let old = sibling_dir(&self.final_dir, "old");
            std::fs::rename(&self.final_dir, &old).with_context(|| {
                format!("moving previous checkpoint {} aside", self.final_dir.display())
            })?;
            if let Err(e) = std::fs::rename(&self.stage, &self.final_dir) {
                // put the old checkpoint back rather than leaving nothing
                // at the live name
                let _ = std::fs::rename(&old, &self.final_dir);
                return Err(e).with_context(|| {
                    format!("committing checkpoint into {}", self.final_dir.display())
                });
            }
            if self.keep > 1 {
                retire_previous(&self.final_dir, &old);
                prune_previous(&self.final_dir, self.keep - 1);
            } else {
                let _ = std::fs::remove_dir_all(&old);
            }
        } else {
            std::fs::rename(&self.stage, &self.final_dir).with_context(|| {
                format!("committing checkpoint into {}", self.final_dir.display())
            })?;
        }
        self.committed = true;
        if self.fsync {
            // the renames themselves become durable when the parent
            // directory is synced
            let parent = match self.final_dir.parent() {
                Some(p) if !p.as_os_str().is_empty() => p,
                _ => Path::new("."),
            };
            sync_dir(parent)?;
        }
        // a complete checkpoint now sits at the live name: stale debris
        // from earlier killed saves is safe to sweep.  (Concurrent saves
        // into the same path are not supported — last committer wins.)
        sweep_stale_stages(&self.final_dir);
        Ok(manifest)
    }
}

/// Write `bytes` to `path`, optionally fsyncing before close.
fn write_file(path: &Path, bytes: &[u8], fsync: bool) -> Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(bytes).with_context(|| format!("writing {}", path.display()))?;
    if fsync {
        f.sync_all().with_context(|| format!("fsyncing {}", path.display()))?;
    }
    Ok(())
}

/// fsync a directory so its entries (blob files, commit renames) are
/// durable, not merely written.
fn sync_dir(dir: &Path) -> Result<()> {
    std::fs::File::open(dir)
        .with_context(|| format!("opening {} to fsync it", dir.display()))?
        .sync_all()
        .with_context(|| format!("fsyncing directory {}", dir.display()))
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        // a writer abandoned without finish() (error path) must not
        // leave its staging directory behind; a SIGKILL mid-save does,
        // and the next save into the same path sweeps it
        if !self.committed {
            let _ = std::fs::remove_dir_all(&self.stage);
        }
    }
}

// -- reader ----------------------------------------------------------------

/// An opened (validated) checkpoint directory.
pub struct Checkpoint {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Checkpoint {
    /// Open and validate: manifest parse + version gate, every tensor
    /// file present with the exact byte length, checksums verified for
    /// tensors up to [`EAGER_VERIFY_BYTES`].
    pub fn open(dir: &Path) -> Result<Self> {
        if let Some(e) = failpoint::inject("checkpoint.open") {
            return Err(e.context(format!("opening checkpoint {}", dir.display())));
        }
        let manifest_path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} (not a checkpoint directory?)", manifest_path.display())
        })?;
        let manifest = Manifest::from_json(
            &json::parse(&text)
                .with_context(|| format!("parsing {}", manifest_path.display()))?,
        )
        .with_context(|| format!("validating {}", manifest_path.display()))?;
        let ckpt = Checkpoint { dir: dir.to_path_buf(), manifest };
        for spec in &ckpt.manifest.tensors {
            let expect = spec.byte_len()?;
            let path = ckpt.blob_path(spec);
            let actual = std::fs::metadata(&path)
                .with_context(|| format!("tensor '{}': missing blob {}", spec.name, path.display()))?
                .len();
            ensure!(
                actual == expect,
                "tensor '{}': blob {} has {actual} bytes, manifest says {expect} \
                 (truncated or tampered checkpoint)",
                spec.name,
                path.display()
            );
            if expect <= EAGER_VERIFY_BYTES {
                ckpt.verify_tensor(spec)?;
            }
        }
        Ok(ckpt)
    }

    /// [`Self::open`] with a crash-recovery fallback chain for serving:
    /// when the live checkpoint is corrupt/truncated/unreadable, move it
    /// aside to a `<dir>.quarantine-*` sibling (preserved for forensics,
    /// never silently deleted) and promote the newest *verifying*
    /// `<dir>.prev-<step>` predecessor (see
    /// [`CheckpointWriter::with_keep`]) to the live name — loudly.
    /// Predecessors that fail verification are skipped, not destroyed.
    /// With no verifying predecessor the original open error propagates.
    ///
    /// Training resume intentionally stays on strict [`Self::open`]: a
    /// trainer silently resuming from older weights would burn compute
    /// on a lie, while a server restoring last-good availability is the
    /// whole point.
    pub fn open_with_fallback(dir: &Path) -> Result<Self> {
        let primary_err = match Self::open(dir) {
            Ok(ck) => return Ok(ck),
            Err(e) => e,
        };
        let prevs = prev_siblings(dir);
        if prevs.is_empty() {
            return Err(primary_err);
        }
        if dir.exists() {
            let quarantine = sibling_dir(dir, "quarantine");
            match std::fs::rename(dir, &quarantine) {
                Ok(()) => log::error!(
                    "checkpoint {} failed to open ({primary_err:#}); quarantined it to {}",
                    dir.display(),
                    quarantine.display()
                ),
                Err(e) => {
                    // cannot move the bad copy aside: promoting a
                    // predecessor over it is impossible, fail loudly
                    return Err(primary_err.context(format!(
                        "quarantining the corrupt checkpoint to {} also failed: {e}",
                        quarantine.display()
                    )));
                }
            }
        } else {
            log::error!(
                "checkpoint {} failed to open ({primary_err:#}); trying retained predecessors",
                dir.display()
            );
        }
        for (step, prev) in prevs {
            match Self::open(&prev) {
                Ok(_) => {
                    std::fs::rename(&prev, dir).with_context(|| {
                        format!("promoting predecessor {} to {}", prev.display(), dir.display())
                    })?;
                    // re-open at the live name so self.dir (and every
                    // blob path derived from it) points at reality
                    let ck = Self::open(dir).with_context(|| {
                        format!("re-opening promoted predecessor at {}", dir.display())
                    })?;
                    log::error!(
                        "RECOVERED: serving predecessor checkpoint {} (step {step}) promoted \
                         from {}; the corrupt latest is quarantined next to it",
                        ck.manifest.checkpoint_id,
                        prev.display()
                    );
                    return Ok(ck);
                }
                Err(e) => log::error!(
                    "predecessor {} (step {step}) also failed to open: {e:#}; skipping",
                    prev.display()
                ),
            }
        }
        Err(primary_err.context("no retained predecessor checkpoint verified either"))
    }

    fn blob_path(&self, spec: &TensorSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    fn verify_tensor(&self, spec: &TensorSpec) -> Result<()> {
        self.read_verified(spec).map(|_| ())
    }

    /// Verify *every* tensor checksum, including blobs too large for the
    /// eager pass at open (`lram checkpoint inspect --verify`).
    pub fn verify(&self) -> Result<()> {
        for spec in &self.manifest.tensors {
            self.verify_tensor(spec)?;
        }
        Ok(())
    }

    fn typed_spec(&self, name: &str, dtype: TensorDtype) -> Result<&TensorSpec> {
        let spec = self.manifest.tensor(name)?;
        ensure!(
            spec.dtype == dtype,
            "tensor '{name}' is {}, expected {}",
            spec.dtype.as_str(),
            dtype.as_str()
        );
        Ok(spec)
    }

    /// Read a tensor's bytes once, checksum the in-memory buffer (one
    /// read, one hash — no second pass over the file).
    fn read_verified(&self, spec: &TensorSpec) -> Result<Vec<u8>> {
        if let Some(e) = failpoint::inject("checkpoint.read_blob") {
            return Err(e.context(format!("reading tensor '{}'", spec.name)));
        }
        let path = self.blob_path(spec);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let actual = checksum_hex(&bytes);
        ensure!(
            actual == spec.checksum,
            "tensor '{}': checksum {actual} != manifest {} (corrupt checkpoint blob {})",
            spec.name,
            spec.checksum,
            path.display()
        );
        Ok(bytes)
    }

    /// Read a (small) f32 tensor fully into memory, verifying its
    /// checksum regardless of size.
    pub fn read_f32(&self, name: &str) -> Result<Vec<f32>> {
        let spec = self.typed_spec(name, TensorDtype::F32)?;
        let bytes = self.read_verified(spec)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn read_u32(&self, name: &str) -> Result<Vec<u32>> {
        let spec = self.typed_spec(name, TensorDtype::U32)?;
        let bytes = self.read_verified(spec)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Map a 2-D f32 tensor as a [`ValueTable`] — zero-copy (copy-on-
    /// write) on little-endian hosts, so multi-GB tables load in O(1).
    /// Shape-product overflow is rejected exactly like `ValueTable::open`.
    pub fn map_table(&self, name: &str) -> Result<ValueTable> {
        let spec = self.typed_spec(name, TensorDtype::F32)?;
        ensure!(
            spec.shape.len() == 2,
            "tensor '{name}' has rank {}, expected a rows x dim table",
            spec.shape.len()
        );
        let (rows, dim) = (spec.shape[0], spec.shape[1]);
        ensure!(dim > 0 && dim <= usize::MAX as u64, "tensor '{name}': bad dim {dim}");
        if cfg!(target_endian = "little") {
            ValueTable::open_cow(&self.blob_path(spec), rows, dim as usize)
                .with_context(|| format!("mapping tensor '{name}'"))
        } else {
            // big-endian fallback: byte-swapped copy into an anonymous map
            let data = self.read_f32(name)?;
            let mut t = ValueTable::zeros(rows, dim as usize)?;
            t.load_from(&data)?;
            Ok(t)
        }
    }

    /// Read a (small) i8 tensor fully into memory, verifying its
    /// checksum regardless of size.
    pub fn read_i8(&self, name: &str) -> Result<Vec<i8>> {
        let spec = self.typed_spec(name, TensorDtype::I8)?;
        let bytes = self.read_verified(spec)?;
        Ok(bytes.iter().map(|&b| b as i8).collect())
    }

    /// Map an i8 tensor copy-on-write (quantized value codes) — i8 is
    /// single-byte, so unlike [`Self::map_table`] this is zero-copy on
    /// every host, big-endian included.  Length-checked at open like all
    /// blobs; checksum verification is deferred exactly as for the f32
    /// value table ([`EAGER_VERIFY_BYTES`]).
    pub fn map_i8(&self, name: &str) -> Result<MmapI8> {
        let spec = self.typed_spec(name, TensorDtype::I8)?;
        let len = spec.element_count()?;
        ensure!(len <= usize::MAX as u64, "tensor '{name}' too large for this host");
        MmapI8::open_cow(&self.blob_path(spec), len as usize)
            .with_context(|| format!("mapping tensor '{name}'"))
    }

    /// Map a 1-D u32 tensor copy-on-write (optimizer step counts).
    pub fn map_u32(&self, name: &str) -> Result<MmapU32> {
        let spec = self.typed_spec(name, TensorDtype::U32)?;
        let len = spec.element_count()?;
        ensure!(len <= usize::MAX as u64, "tensor '{name}' too large for this host");
        if cfg!(target_endian = "little") {
            MmapU32::open_cow(&self.blob_path(spec), len as usize)
                .with_context(|| format!("mapping tensor '{name}'"))
        } else {
            let data = self.read_u32(name)?;
            let mut m = MmapU32::anon(len as usize)?;
            m.as_mut_slice().copy_from_slice(&data);
            Ok(m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn demo_model() -> ModelDesc {
        ModelDesc {
            vocab: 512,
            width: 16,
            heads: 2,
            m: 8,
            k_top: 32,
            seq_len: 16,
            max_batch: 4,
            torus_k: [4; 8],
            query_scale: 4.0,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "lram_ckpt_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_demo(dir: &Path) -> Manifest {
        let mut w = CheckpointWriter::new(dir).unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 3.0).collect();
        w.write_f32("embed", &[8, 8], &data).unwrap();
        w.write_f32("values", &[16, 4], &vec![0.25; 64]).unwrap();
        w.write_u32("adam_t", &[16], &(0..16u32).collect::<Vec<_>>()).unwrap();
        w.finish(42, "0123456789abcdef", demo_model()).unwrap()
    }

    #[test]
    fn save_open_roundtrip_preserves_everything() {
        let dir = tmp_dir("roundtrip");
        let saved = write_demo(&dir);
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.manifest, saved);
        assert_eq!(ck.manifest.step, 42);
        assert!(ck.manifest.checkpoint_id.starts_with("ck-"));
        let embed = ck.read_f32("embed").unwrap();
        assert_eq!(embed[2], -2.0);
        let table = ck.map_table("values").unwrap();
        assert_eq!(table.rows(), 16);
        assert_eq!(table.row(3), &[0.25; 4]);
        let t = ck.map_u32("adam_t").unwrap();
        assert_eq!(t.as_slice()[7], 7);
        ck.verify().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_json_roundtrip_is_lossless_under_arbitrary_configs() {
        // property: any manifest we can construct survives
        // serialize → parse → serialize bit-for-bit
        forall(64, |rng| {
            let model = ModelDesc {
                vocab: rng.below(100_000) as usize + 1,
                width: rng.below(4096) as usize + 1,
                heads: rng.below(16) as usize + 1,
                m: rng.below(512) as usize + 1,
                k_top: rng.below(64) as usize + 1,
                seq_len: rng.below(512) as usize + 2,
                max_batch: rng.below(256) as usize + 1,
                torus_k: std::array::from_fn(|_| 4 * (1 + rng.below(16) as i64)),
                query_scale: rng.uniform(0.01, 64.0),
            };
            let n_tensors = rng.below(5) as usize;
            let tensors: Vec<TensorSpec> = (0..n_tensors)
                .map(|i| TensorSpec {
                    // names exercise escaping: quotes, newlines, unicode
                    name: format!("t{i}-\"q\"\n-héllo"),
                    file: format!("t{i}.bin"),
                    dtype: if rng.bool(0.5) { TensorDtype::F32 } else { TensorDtype::U32 },
                    shape: (0..1 + rng.below(4)).map(|_| rng.below(1 << 20)).collect(),
                    checksum: format!("{:016x}", rng.next_u64()),
                })
                .collect();
            let shards = if rng.bool(0.5) {
                None
            } else {
                // monotone bounds starting at 0, like a real shard plan
                let n = 1 + rng.below(6) as usize;
                let mut bounds = vec![0u64];
                for _ in 0..n {
                    bounds.push(bounds.last().copied().unwrap_or(0) + rng.below(1 << 20));
                }
                Some(bounds)
            };
            let m = Manifest {
                version: FORMAT_VERSION,
                checkpoint_id: format!("ck-{:016x}", rng.next_u64()),
                step: rng.below(1 << 40),
                tokenizer_hash: format!("{:016x}", rng.next_u64()),
                model,
                tensors,
                shards,
            };
            let text = m.to_json().to_string();
            let back = Manifest::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, m);
            assert_eq!(back.to_json().to_string(), text);
        });
    }

    #[test]
    fn corrupt_blob_fails_open_with_checksum_error() {
        let dir = tmp_dir("corrupt");
        write_demo(&dir);
        // flip one byte of a small tensor
        let path = dir.join("embed.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[9] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::open(&dir).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_blob_fails_open_with_size_error() {
        let dir = tmp_dir("trunc");
        write_demo(&dir);
        let path = dir.join("values.bin");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        let err = format!("{:#}", Checkpoint::open(&dir).unwrap_err());
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_blob_fails_open() {
        let dir = tmp_dir("missing");
        write_demo(&dir);
        std::fs::remove_file(dir.join("adam_t.bin")).unwrap();
        let err = format!("{:#}", Checkpoint::open(&dir).unwrap_err());
        assert!(err.contains("missing blob"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Patch the manifest's version field in place (skew simulations).
    fn patch_version(dir: &Path, to: i64) {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let from = format!("\"version\":{FORMAT_VERSION}");
        assert!(text.contains(&from), "manifest must carry the current version");
        std::fs::write(&path, text.replace(&from, &format!("\"version\":{to}"))).unwrap();
    }

    #[test]
    fn version_skew_fails_open_loudly() {
        let dir = tmp_dir("skew");
        write_demo(&dir);
        patch_version(&dir, 9000);
        let err = format!("{:#}", Checkpoint::open(&dir).unwrap_err());
        assert!(err.contains("version 9000"), "{err}");
        assert!(err.contains("not supported"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn previous_format_version_still_opens() {
        // PR-3-era checkpoints carry version 1 with the same blob
        // layout; the version-3 (q8) reader must keep loading them
        let dir = tmp_dir("back_compat");
        write_demo(&dir);
        patch_version(&dir, MIN_READ_VERSION);
        let ck = Checkpoint::open(&dir).expect("version-1 checkpoints must keep loading");
        assert_eq!(ck.manifest.version, MIN_READ_VERSION);
        assert_eq!(ck.read_f32("embed").unwrap()[2], -2.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn next_format_version_is_refused_with_upgrade_guidance() {
        // the other skew direction: this reader meeting a manifest from
        // the future must refuse and tell the operator what to do
        let dir = tmp_dir("fwd_skew");
        write_demo(&dir);
        patch_version(&dir, FORMAT_VERSION + 1);
        let err = format!("{:#}", Checkpoint::open(&dir).unwrap_err());
        assert!(err.contains(&format!("version {}", FORMAT_VERSION + 1)), "{err}");
        assert!(err.contains("not supported"), "{err}");
        assert!(err.contains("upgrade"), "refusal must point at the fix: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fsync_save_is_bit_identical_to_a_plain_save() {
        // the durability flag changes *when* bytes are durable, never
        // which bytes: same content-derived id, same verified blobs
        let plain = tmp_dir("fsync_plain");
        let durable = tmp_dir("fsync_durable");
        let a = write_demo(&plain);
        let b = {
            let mut w = CheckpointWriter::new(&durable).unwrap().with_fsync(true);
            let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 3.0).collect();
            w.write_f32("embed", &[8, 8], &data).unwrap();
            w.write_f32("values", &[16, 4], &vec![0.25; 64]).unwrap();
            w.write_u32("adam_t", &[16], &(0..16u32).collect::<Vec<_>>()).unwrap();
            w.finish(42, "0123456789abcdef", demo_model()).unwrap()
        };
        assert_eq!(a.checkpoint_id, b.checkpoint_id);
        let ck = Checkpoint::open(&durable).unwrap();
        ck.verify().unwrap();
        assert_eq!(ck.manifest, b);
        // overwrite path with fsync: the rename protocol is unchanged
        let mut w = CheckpointWriter::new(&durable).unwrap().with_fsync(true);
        w.write_f32("embed", &[8, 8], &[1.0; 64]).unwrap();
        w.finish(43, "0123456789abcdef", demo_model()).unwrap();
        assert_eq!(Checkpoint::open(&durable).unwrap().read_f32("embed").unwrap()[0], 1.0);
        std::fs::remove_dir_all(&plain).ok();
        std::fs::remove_dir_all(&durable).ok();
    }

    #[test]
    fn foreign_format_tag_is_rejected() {
        let dir = tmp_dir("foreign");
        write_demo(&dir);
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace(FORMAT_TAG, "other-format")).unwrap();
        assert!(Checkpoint::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_load_rejects_shape_overflow_like_open() {
        // a hostile manifest with rows*dim > usize::MAX must error, not
        // wrap into a tiny map — the same guard ValueTable::open has
        let dir = tmp_dir("overflow");
        write_demo(&dir);
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        // values is [16, 4]: blow up the row count; element_count (u64)
        // survives but the usize byte math must refuse
        let patched = text.replace("\"shape\":[16,4]", "\"shape\":[4611686018427387904,16]");
        std::fs::write(&path, patched).unwrap();
        // open() fails earlier (size mismatch); go through map_table to
        // exercise the overflow path itself
        let manifest = Manifest::from_json(
            &json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap(),
        )
        .unwrap();
        let ck = Checkpoint { dir: dir.clone(), manifest };
        let err = format!("{:#}", ck.map_table("values").unwrap_err());
        assert!(err.contains("overflow"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_rejects_duplicates_and_shape_mismatch() {
        let dir = tmp_dir("dup");
        let mut w = CheckpointWriter::new(&dir).unwrap();
        w.write_f32("a", &[4], &[0.0; 4]).unwrap();
        assert!(w.write_f32("a", &[4], &[0.0; 4]).is_err(), "duplicate name");
        // distinct names mapping to the same sanitised blob file
        w.write_f32("x/y", &[4], &[0.0; 4]).unwrap();
        assert!(w.write_f32("x?y", &[4], &[0.0; 4]).is_err(), "file collision");
        assert!(w.write_f32("b", &[5], &[0.0; 4]).is_err(), "shape mismatch");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `<dir>.tmp-*` / `<dir>.old-*` siblings of a checkpoint path.
    fn stale_siblings(dir: &Path) -> Vec<PathBuf> {
        let parent = dir.parent().unwrap();
        let name = dir.file_name().unwrap().to_str().unwrap();
        std::fs::read_dir(parent)
            .unwrap()
            .flatten()
            .filter(|e| {
                let n = e.file_name();
                let n = n.to_str().unwrap_or("");
                n.starts_with(&format!("{name}.tmp-")) || n.starts_with(&format!("{name}.old-"))
            })
            .map(|e| e.path())
            .collect()
    }

    #[test]
    fn overwrite_keeps_the_old_checkpoint_openable_until_commit() {
        // the whole point of staged saves: while a re-save is writing
        // blobs, the existing checkpoint stays intact and openable
        let dir = tmp_dir("staged");
        let original = write_demo(&dir);
        let mut w = CheckpointWriter::new(&dir).unwrap();
        w.write_f32("embed", &[8, 8], &[1.5; 64]).unwrap();
        let mid = Checkpoint::open(&dir).expect("old checkpoint must open mid-save");
        assert_eq!(mid.manifest, original, "mid-save open must see the OLD manifest");
        assert_eq!(mid.read_f32("embed").unwrap()[2], -2.0, "old blob bytes, not new");
        // completing the save swaps the new content in and leaves no
        // staging or backup debris behind
        w.write_f32("values", &[16, 4], &vec![0.25; 64]).unwrap();
        w.write_u32("adam_t", &[16], &(0..16u32).collect::<Vec<_>>()).unwrap();
        let new = w.finish(43, "0123456789abcdef", demo_model()).unwrap();
        assert_ne!(new.checkpoint_id, original.checkpoint_id);
        let after = Checkpoint::open(&dir).unwrap();
        assert_eq!(after.manifest, new);
        assert_eq!(after.read_f32("embed").unwrap()[2], 1.5);
        assert!(stale_siblings(&dir).is_empty(), "{:?}", stale_siblings(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mid_save_kill_leaves_the_old_checkpoint_intact() {
        // SIGKILL simulation: a killed process runs no Drop, so forget()
        // reproduces its exact filesystem state — blobs half-staged in
        // <dir>.tmp-*, nothing committed
        let dir = tmp_dir("killed");
        let original = write_demo(&dir);
        let before = Checkpoint::open(&dir).unwrap().read_f32("embed").unwrap();
        let mut w = CheckpointWriter::new(&dir).unwrap();
        w.write_f32("embed", &[8, 8], &[9.0; 64]).unwrap();
        std::mem::forget(w); // <- the "kill"
        assert_eq!(stale_siblings(&dir).len(), 1, "the killed save left its stage");
        // the original checkpoint is bit-identical and opens cleanly
        let ck = Checkpoint::open(&dir).expect("old checkpoint survives the kill");
        assert_eq!(ck.manifest, original);
        assert_eq!(ck.read_f32("embed").unwrap(), before);
        ck.verify().unwrap();
        // the next save into the same path sweeps the stale stage and
        // completes normally
        let resaved = write_demo(&dir);
        assert_eq!(resaved.checkpoint_id, original.checkpoint_id);
        Checkpoint::open(&dir).unwrap();
        assert!(stale_siblings(&dir).is_empty(), "{:?}", stale_siblings(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kill_between_commit_renames_is_recovered_on_next_save() {
        // the one non-atomic window in finish(): the old checkpoint was
        // moved aside but the process died before the stage moved in —
        // the live name is empty, a complete copy sits at <dir>.old-*
        let dir = tmp_dir("window");
        let original = write_demo(&dir);
        let name = dir.file_name().unwrap().to_str().unwrap().to_string();
        let old = dir.parent().unwrap().join(format!("{name}.old-999-0"));
        std::fs::rename(&dir, &old).unwrap();
        // an incomplete stage (no manifest) from the same crash must
        // never be chosen for recovery
        let junk = dir.parent().unwrap().join(format!("{name}.tmp-999-0"));
        std::fs::create_dir_all(&junk).unwrap();
        std::fs::write(junk.join("embed.bin"), [0u8; 8]).unwrap();
        assert!(Checkpoint::open(&dir).is_err(), "the kill left nothing at the live name");
        // starting the next save restores the committed copy first...
        let w = CheckpointWriter::new(&dir).unwrap();
        let recovered = Checkpoint::open(&dir).expect("recovery must restore the old checkpoint");
        assert_eq!(recovered.manifest, original);
        drop(w);
        // ...and completing a save leaves a clean directory layout
        let resaved = write_demo(&dir);
        assert_eq!(resaved.checkpoint_id, original.checkpoint_id);
        Checkpoint::open(&dir).unwrap();
        assert!(stale_siblings(&dir).is_empty(), "{:?}", stale_siblings(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn abandoned_writer_cleans_its_staging_dir() {
        // the error path (writer dropped without finish) must not
        // accumulate staging directories
        let dir = tmp_dir("abandon");
        write_demo(&dir);
        let mut w = CheckpointWriter::new(&dir).unwrap();
        w.write_f32("embed", &[8, 8], &[0.0; 64]).unwrap();
        drop(w);
        assert!(stale_siblings(&dir).is_empty(), "{:?}", stale_siblings(&dir));
        Checkpoint::open(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn first_save_into_a_fresh_path_needs_no_existing_dir() {
        // CheckpointWriter::new used to create the final dir eagerly;
        // the staged writer must still handle a target that never
        // existed (and a nested parent)
        let dir = tmp_dir("fresh").join("nested").join("ckpt");
        let saved = {
            let mut w = CheckpointWriter::new(&dir).unwrap();
            let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 3.0).collect();
            w.write_f32("embed", &[8, 8], &data).unwrap();
            w.finish(1, "0123456789abcdef", demo_model()).unwrap()
        };
        assert_eq!(Checkpoint::open(&dir).unwrap().manifest, saved);
        std::fs::remove_dir_all(dir.parent().unwrap().parent().unwrap()).ok();
    }

    /// Save a one-tensor checkpoint at `step` with distinctive content,
    /// retaining `keep` copies.
    fn write_step(dir: &Path, step: u64, keep: usize) -> Manifest {
        let mut w = CheckpointWriter::new(dir).unwrap().with_keep(keep);
        w.write_f32("embed", &[8, 8], &[step as f32; 64]).unwrap();
        w.finish(step, "0123456789abcdef", demo_model()).unwrap()
    }

    #[test]
    fn with_keep_retains_and_prunes_predecessors() {
        let dir = tmp_dir("keep");
        for step in 1..=4 {
            write_step(&dir, step, 3);
        }
        // live = step 4; retained predecessors = steps 3 and 2 (keep-1),
        // step 1 pruned
        assert_eq!(Checkpoint::open(&dir).unwrap().manifest.step, 4);
        let prevs = prev_siblings(&dir);
        assert_eq!(prevs.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![3, 2], "{prevs:?}");
        for (step, p) in &prevs {
            let ck = Checkpoint::open(p).expect("retained predecessors stay openable");
            assert_eq!(ck.manifest.step, *step);
            assert_eq!(ck.read_f32("embed").unwrap()[0], *step as f32);
        }
        // default keep=1 still deletes on overwrite: no *new* prevs
        write_step(&dir, 5, 1);
        assert_eq!(prev_siblings(&dir).len(), 2, "keep=1 must not retire more");
        std::fs::remove_dir_all(&dir).ok();
        for (_, p) in prev_siblings(&dir) {
            std::fs::remove_dir_all(&p).ok();
        }
    }

    /// `<dir>.quarantine-*` siblings.
    fn quarantine_siblings(dir: &Path) -> Vec<PathBuf> {
        let parent = dir.parent().unwrap();
        let name = dir.file_name().unwrap().to_str().unwrap();
        std::fs::read_dir(parent)
            .unwrap()
            .flatten()
            .filter(|e| {
                e.file_name()
                    .to_str()
                    .is_some_and(|n| n.starts_with(&format!("{name}.quarantine-")))
            })
            .map(|e| e.path())
            .collect()
    }

    #[test]
    fn open_with_fallback_quarantines_corrupt_latest_and_promotes_predecessor() {
        let dir = tmp_dir("fallback");
        write_step(&dir, 1, 3);
        write_step(&dir, 2, 3);
        // corrupt the live checkpoint's blob
        let path = dir.join("embed.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::open(&dir).is_err(), "corruption must fail the strict open");
        let ck = Checkpoint::open_with_fallback(&dir).expect("predecessor must be promoted");
        assert_eq!(ck.manifest.step, 1, "newest verifying predecessor wins");
        assert_eq!(ck.read_f32("embed").unwrap()[0], 1.0);
        assert_eq!(ck.dir, dir, "promotion must land at the live name");
        // the bad copy is preserved for forensics, not deleted
        let q = quarantine_siblings(&dir);
        assert_eq!(q.len(), 1, "{q:?}");
        assert!(q[0].join(MANIFEST_FILE).is_file());
        // the live name now opens strictly again
        assert_eq!(Checkpoint::open(&dir).unwrap().manifest.step, 1);
        std::fs::remove_dir_all(&dir).ok();
        for p in quarantine_siblings(&dir) {
            std::fs::remove_dir_all(&p).ok();
        }
    }

    #[test]
    fn open_with_fallback_without_predecessors_propagates_and_preserves_dir() {
        let dir = tmp_dir("no_fallback");
        write_step(&dir, 7, 1);
        let path = dir.join("embed.bin");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", Checkpoint::open_with_fallback(&dir).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
        // nothing to fall back to → the (only) copy must stay in place
        // for the operator, not get quarantined into a dead end
        assert!(dir.join(MANIFEST_FILE).is_file(), "live dir must not be moved");
        assert!(quarantine_siblings(&dir).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    // NOTE: the `checkpoint.open` / `checkpoint.read_blob` failpoint
    // wiring is exercised by `rust/tests/chaos.rs`, which owns its whole
    // process — arming those sites here would race the other #[test]
    // threads of this crate through the same global registry.

    #[test]
    fn i8_tensors_roundtrip_and_map_zero_copy() {
        // version-3 addition: quantized codes save as i8 [rows, dim]
        // next to their f32 per-row scales and come back bit-identical,
        // both via the verified read and via the zero-copy map
        let dir = tmp_dir("i8");
        let codes: Vec<i8> = (0..96).map(|i| (i * 7 % 255 - 127) as i8).collect();
        let scales: Vec<f32> = (0..12).map(|r| 0.25 + r as f32).collect();
        let saved = {
            let mut w = CheckpointWriter::new(&dir).unwrap();
            w.write_f32("values", &[12, 8], &vec![0.5; 96]).unwrap();
            w.write_i8("values_q8", &[12, 8], &codes).unwrap();
            w.write_f32("values_q8_scale", &[12], &scales).unwrap();
            w.finish(7, "0123456789abcdef", demo_model()).unwrap()
        };
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.manifest, saved);
        let spec = ck.manifest.tensor("values_q8").unwrap();
        assert_eq!(spec.dtype, TensorDtype::I8);
        assert_eq!(spec.byte_len().unwrap(), 96, "i8 is one byte per element");
        assert_eq!(ck.read_i8("values_q8").unwrap(), codes);
        let map = ck.map_i8("values_q8").unwrap();
        assert_eq!(map.as_slice(), &codes[..]);
        assert_eq!(ck.read_f32("values_q8_scale").unwrap(), scales);
        // dtype confusion is refused, not coerced
        assert!(ck.read_f32("values_q8").is_err());
        assert!(ck.read_i8("values").is_err());
        ck.verify().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_manifest_roundtrips_and_is_absent_when_unsharded() {
        // format version 4: sharded saves carry `shards.bounds`;
        // unsharded saves must omit the key entirely so their manifest
        // bytes (and content ids) match a pre-shard-aware writer
        let dir = tmp_dir("shards");
        let plain = write_demo(&dir);
        assert_eq!(plain.shards, None);
        let text = std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
        assert!(!text.contains("shards"), "unsharded manifest must omit the key: {text}");

        let sharded = {
            let mut w = CheckpointWriter::new(&dir).unwrap().with_shards(vec![0, 10, 16]);
            w.write_f32("values_shard_0", &[10, 4], &vec![0.25; 40]).unwrap();
            w.write_f32("values_shard_1", &[6, 4], &vec![0.5; 24]).unwrap();
            w.finish(43, "0123456789abcdef", demo_model()).unwrap()
        };
        assert_eq!(sharded.shards, Some(vec![0, 10, 16]));
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.manifest, sharded);
        assert_eq!(ck.manifest.shards, Some(vec![0, 10, 16]));
        assert_eq!(ck.map_table("values_shard_1").unwrap().rows(), 6);
        // the shard section is part of the content id
        assert_ne!(plain.checkpoint_id, sharded.checkpoint_id);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_id_tracks_content() {
        let d1 = tmp_dir("id1");
        let d2 = tmp_dir("id2");
        let a = write_demo(&d1);
        let b = write_demo(&d2);
        assert_eq!(a.checkpoint_id, b.checkpoint_id, "same content, same id");
        let mut w = CheckpointWriter::new(&d2).unwrap();
        w.write_f32("embed", &[8, 8], &[1.0; 64]).unwrap();
        let c = w.finish(42, "0123456789abcdef", demo_model()).unwrap();
        assert_ne!(a.checkpoint_id, c.checkpoint_id, "different bytes, different id");
        std::fs::remove_dir_all(&d1).ok();
        std::fs::remove_dir_all(&d2).ok();
    }
}
