//! The fixed 232-point candidate table (paper §2.6).
//!
//! All lattice points within distance `< sqrt(8)` of the fundamental
//! region `F`.  The paper derives the count 232 by convex quadratic
//! programming; we recompute the table at first use by enumerating the
//! ~9.1k lattice points with `|p|^2 <= 24` (every point within
//! `sqrt(8)` of `F` satisfies `|p| < sqrt(8) + 2 < sqrt(24)`) and solving
//! `min_{z in F} |p - z|^2` with Dykstra's alternating projections onto
//! `F`'s ten halfspaces.  The result is cached in a `OnceLock` and
//! cross-checked against the python implementation through
//! `artifacts/lattice_fixture.json`.

use std::sync::OnceLock;

use super::e8::IVec8;
use super::SQRT8;

/// Exactly this many lattice points lie within `sqrt(8)` of `F`.
pub const N_NEIGHBORS: usize = 232;

/// Halfspaces `a.z <= b` whose intersection is F.
fn halfspaces() -> ([[f64; 8]; 10], [f64; 10]) {
    let mut a = [[0.0f64; 8]; 10];
    let b = [0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 2.0, 4.0];
    for i in 0..6 {
        a[i][i] = -1.0;
        a[i][i + 1] = 1.0;
    }
    a[6][6] = -1.0;
    a[6][7] = 1.0; //  z8 <= z7
    a[7][6] = -1.0;
    a[7][7] = -1.0; // -z8 <= z7
    a[8][0] = 1.0;
    a[8][1] = 1.0; // z1 + z2 <= 2
    a[9] = [1.0; 8]; // sum <= 4
    (a, b)
}

/// Squared distance from `p` to `F` via Dykstra's projection algorithm.
pub fn dist2_to_f(p: &[f64; 8], iters: usize) -> f64 {
    let (a, b) = halfspaces();
    let mut an = [0.0f64; 10];
    for k in 0..10 {
        an[k] = a[k].iter().map(|v| v * v).sum();
    }
    let mut x = *p;
    let mut y = [[0.0f64; 8]; 10];
    for _ in 0..iters {
        for k in 0..10 {
            let mut w = [0.0f64; 8];
            let mut dot = 0.0;
            for i in 0..8 {
                w[i] = x[i] + y[k][i];
                dot += a[k][i] * w[i];
            }
            let viol = (dot - b[k]).max(0.0) / an[k];
            for i in 0..8 {
                let xn = w[i] - viol * a[k][i];
                y[k][i] = w[i] - xn;
                x[i] = xn;
            }
        }
    }
    (0..8).map(|i| (p[i] - x[i]).powi(2)).sum()
}

/// Enumerate all points of Lambda with `|p|^2 <= 24` (both cosets).
fn enumerate_candidates() -> Vec<IVec8> {
    let mut out = Vec::with_capacity(10_000);
    // depth-first over per-coordinate values, pruned by partial norm
    fn dfs(vals: &[i64], depth: usize, acc: &mut IVec8, n2: i64, sum: i64, out: &mut Vec<IVec8>) {
        if n2 > 24 {
            return;
        }
        if depth == 8 {
            if sum.rem_euclid(4) == 0 {
                out.push(*acc);
            }
            return;
        }
        for &v in vals {
            acc[depth] = v;
            dfs(vals, depth + 1, acc, n2 + v * v, sum + v, out);
        }
    }
    let mut acc = [0i64; 8];
    dfs(&[0, 2, -2, 4, -4], 0, &mut acc, 0, 0, &mut out);
    dfs(&[1, -1, 3, -3], 0, &mut acc, 0, 0, &mut out);
    out
}

/// The canonical (lexicographically sorted) 232-point table.
pub fn neighbor_table() -> &'static [IVec8; N_NEIGHBORS] {
    static TABLE: OnceLock<[IVec8; N_NEIGHBORS]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let cands = enumerate_candidates();
        let mut near: Vec<IVec8> = Vec::with_capacity(N_NEIGHBORS);
        for c in cands {
            let p: [f64; 8] = std::array::from_fn(|i| c[i] as f64);
            if dist2_to_f(&p, 400) < SQRT8 * SQRT8 - 1e-6 {
                near.push(c);
            }
        }
        near.sort();
        assert_eq!(
            near.len(),
            N_NEIGHBORS,
            "neighbour enumeration produced {} points, expected 232",
            near.len()
        );
        let mut table = [[0i64; 8]; N_NEIGHBORS];
        table.copy_from_slice(&near);
        table
    })
}

/// The neighbour table pre-converted to f64 (hot-path scoring avoids
/// 232 x 8 int->float conversions per query; see bench lattice_hot_path).
pub fn neighbor_table_f64() -> &'static [[f64; 8]; N_NEIGHBORS] {
    static TABLE: OnceLock<[[f64; 8]; N_NEIGHBORS]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let t = neighbor_table();
        std::array::from_fn(|i| std::array::from_fn(|j| t[i][j] as f64))
    })
}

/// The neighbour table transposed into structure-of-arrays layout:
/// `soa[lane][candidate]`.  The batch engine scores one lane across all
/// 232 candidates per pass, so each pass is a contiguous
/// multiply-accumulate over a 232-element f64 row — the layout LLVM
/// autovectorizes (see `lattice::batch`).
pub fn neighbor_table_soa() -> &'static [[f64; N_NEIGHBORS]; 8] {
    static TABLE: OnceLock<[[f64; N_NEIGHBORS]; 8]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let t = neighbor_table();
        std::array::from_fn(|j| std::array::from_fn(|i| t[i][j] as f64))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::e8::is_lattice_point;

    #[test]
    fn table_has_exactly_232_points() {
        let t = neighbor_table();
        assert_eq!(t.len(), 232);
        for p in t.iter() {
            assert!(is_lattice_point(p), "{p:?}");
            let n2: i64 = p.iter().map(|v| v * v).sum();
            assert!(n2 <= 24, "{p:?} too far from origin");
        }
        // origin (the lattice point of F itself) is in the table
        assert!(t.contains(&[0i64; 8]));
        // no duplicates (table is sorted)
        for w in t.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn dykstra_projects_inside_points_to_themselves() {
        // deep interior point of F
        let p = [0.5, 0.4, 0.3, 0.2, 0.2, 0.1, 0.1, 0.0];
        assert!(dist2_to_f(&p, 200) < 1e-12);
    }

    #[test]
    fn dykstra_distance_matches_hand_case() {
        // p = (4,0,...,0): nearest point of F on the z1+z2<=2 face vs
        // ordering constraints; known projection is (2, ...)? verify
        // against a fine grid search along the symmetric direction.
        let p = [4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let d2 = dist2_to_f(&p, 2000);
        // grid check: F points of form (a, b, 0...0), a>=b>=0, a+b<=2
        let mut best = f64::MAX;
        let n = 400;
        for ia in 0..=n {
            let a = 2.0 * ia as f64 / n as f64;
            for ib in 0..=ia {
                let b = 2.0 * ib as f64 / n as f64;
                if a + b <= 2.0 {
                    let d = (4.0 - a).powi(2) + b * b;
                    best = best.min(d);
                }
            }
        }
        assert!((d2 - best).abs() < 1e-3, "dykstra {d2} vs grid {best}");
    }

    #[test]
    fn soa_table_is_the_transpose() {
        let aos = neighbor_table_f64();
        let soa = neighbor_table_soa();
        for (i, row) in aos.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                assert_eq!(soa[j][i], v);
            }
        }
    }

    #[test]
    fn minimal_vectors_are_included() {
        // the 240*... minimal vectors of Lambda at norm sqrt(8) adjacent to
        // the origin region: e.g. (2,2,0,...), (1,...,1,-1) variants with
        // small distance to F must appear
        let t = neighbor_table();
        assert!(t.contains(&[2, 2, 0, 0, 0, 0, 0, 0]));
        assert!(t.contains(&[1, 1, 1, 1, 1, 1, 1, 1]));
    }
}
