//! Batched SoA lookup→gather engine — the fused L3 hot path.
//!
//! [`super::lookup::LatticeLookup`] answers one query at a time and
//! allocates a `Vec<Hit>` per call; fine as a reference oracle, too slow
//! to serve traffic.  [`BatchLookupEngine`] processes N queries through
//! reduce → candidate scoring → top-k → inverse isometry → torus index
//! (→ optionally the weighted value-table gather) as one allocation-free
//! pipeline over structure-of-arrays buffers.
//!
//! # SoA layout
//!
//! Queries arrive row-major (`N x 8` f64).  Results live in flat
//! parallel arrays (see [`BatchOutput`]), `k = k_top` slots per query:
//!
//! ```text
//! indices:      [N*k] u64   indices[q*k + j] = torus slot of hit j
//! weights:      [N*k] f32   weights[q*k + j] = kernel weight of hit j
//! total_weight: [N]   f64   sum of all in-support candidate weights
//! ```
//!
//! Queries with fewer than `k` in-support candidates pad the tail with
//! `(index 0, weight 0.0)` — the same "zero weight means no access"
//! convention the memstore gather and `AccessStats` already use.  The
//! fused gather writes `out: [N*m] f32` with
//! `out[q] = sum_j weights[q,j] * table[indices[q,j]]`, skipping the
//! intermediate `k x m` gathered buffer entirely.
//!
//! # Why it is fast
//!
//! * **Scoring** walks the candidate table in transposed (lane-major)
//!   order: per lane, one contiguous fused multiply-add pass over 232
//!   f64s (`d2[c] += (z_j - soa[j][c])^2`), which LLVM autovectorizes;
//!   the scalar path's unrolled 8-lane loop stays in `lookup.rs` as the
//!   differential-testing reference.  The per-candidate accumulation
//!   order (lane 0..7) is identical to the scalar path, so distances —
//!   and therefore weights — are bit-identical.
//! * **Top-k** replaces the O(n*k) selection sort with an O(n + k log k)
//!   quickselect ([`crate::util::topk`]); candidates with `d2 >= 8`
//!   never enter the selection.
//! * **Gather** fuses into the same pass with software prefetch of the
//!   upcoming rows, so index math overlaps the memory latency of the
//!   O(1) random accesses.
//! * **Batch sharding** splits the queries across `std::thread` scoped
//!   workers with per-worker scratch; output shards are disjoint, so
//!   results are bit-identical for every thread count.

use super::e8::{reduce, vec8, Reduction, Vec8};
use super::kernel::kernel_df_dd2;
use super::neighbors::{neighbor_table, neighbor_table_soa, N_NEIGHBORS};
use super::torus::TorusK;
use crate::memstore::ValueTable;
use crate::util::topk::partial_top_k_desc;

/// Structure-of-arrays results for a batch of lookups (see module docs).
#[derive(Debug, Clone, Default)]
pub struct BatchOutput {
    /// `[N*k]` torus memory slots, `k_top` per query, weight-descending.
    pub indices: Vec<u64>,
    /// `[N*k]` kernel weights; `0.0` marks padded (absent) hits.
    pub weights: Vec<f32>,
    /// `[N]` total kernel weight over *all* in-support candidates
    /// (paper bound: `[0.851, 1]`).
    pub total_weight: Vec<f64>,
    k_top: usize,
}

impl BatchOutput {
    /// Number of queries currently held.
    pub fn queries(&self) -> usize {
        self.total_weight.len()
    }

    /// Hits kept per query.
    pub fn k_top(&self) -> usize {
        self.k_top
    }

    /// The `(indices, weights)` rows of query `q`.
    pub fn query(&self, q: usize) -> (&[u64], &[f32]) {
        let lo = q * self.k_top;
        let hi = lo + self.k_top;
        (&self.indices[lo..hi], &self.weights[lo..hi])
    }

    fn reset(&mut self, n: usize, k_top: usize) {
        self.k_top = k_top;
        self.indices.resize(n * k_top, 0);
        self.weights.resize(n * k_top, 0.0);
        self.total_weight.resize(n, 0.0);
    }
}

/// Per-worker scratch: one distance row over the candidate table plus
/// the in-support `(weight, candidate)` pairs awaiting selection.
struct Scratch {
    d2: [f64; N_NEIGHBORS],
    cand: Vec<(f64, u32)>,
}

impl Scratch {
    fn new() -> Self {
        Scratch { d2: [0.0; N_NEIGHBORS], cand: Vec::with_capacity(N_NEIGHBORS) }
    }
}

/// Batched lattice lookup (+ optional fused gather) over a fixed torus.
///
/// Construction is cheap; the engine holds no per-batch state, so one
/// engine can be shared by reference across threads.
pub struct BatchLookupEngine {
    pub torus: TorusK,
    pub k_top: usize,
    n_threads: usize,
}

impl BatchLookupEngine {
    /// Single-threaded engine (the common serving-shard configuration).
    pub fn new(torus: TorusK, k_top: usize) -> Self {
        Self::with_threads(torus, k_top, 1)
    }

    /// Engine sharding each batch across `n_threads` scoped workers.
    pub fn with_threads(torus: TorusK, k_top: usize, n_threads: usize) -> Self {
        assert!(k_top >= 1, "k_top must be at least 1");
        BatchLookupEngine { torus, k_top, n_threads: n_threads.max(1) }
    }

    /// Engine using all available hardware parallelism.
    pub fn auto(torus: TorusK, k_top: usize) -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(torus, k_top, n)
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Look up a batch of row-major queries (`N x 8` f64) into `out`.
    ///
    /// Allocation-free after `out` reaches batch size; results are
    /// independent of the thread count.
    pub fn lookup_batch_into(&self, queries: &[f64], out: &mut BatchOutput) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        out.reset(n, self.k_top);
        self.dispatch(queries, out, None, &mut []);
    }

    /// Convenience wrapper allocating the output.
    pub fn lookup_batch(&self, queries: &[f64]) -> BatchOutput {
        let mut out = BatchOutput::default();
        self.lookup_batch_into(queries, &mut out);
        out
    }

    /// Fused lookup → weighted gather: fills `lookup` as
    /// [`Self::lookup_batch_into`] and accumulates
    /// `gathered[q] = sum_j w[q,j] * table[idx[q,j]]` (`N x m` f32)
    /// without materialising any intermediate `k x m` buffer.
    pub fn lookup_gather_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) {
        assert_eq!(
            gathered.len(),
            queries.len() / 8 * table.dim(),
            "gather output must be N x m"
        );
        self.lookup_gather_ragged_into(queries, table, lookup, gathered);
    }

    /// [`Self::lookup_gather_into`] sized for ragged final batches:
    /// `gathered` may be *larger* than `N x m` (serving reuses one
    /// max-batch-sized buffer while the last batch of a stream is rarely
    /// full); only the first `N * m` elements are written, the tail is
    /// left untouched.
    pub fn lookup_gather_ragged_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let need = n * table.dim();
        assert!(
            gathered.len() >= need,
            "gather output holds {} floats, batch needs {need}",
            gathered.len()
        );
        lookup.reset(n, self.k_top);
        self.dispatch(queries, lookup, Some(table), &mut gathered[..need]);
    }

    /// Backward of the fused lookup→gather with respect to the
    /// *queries* — the routing gradient that lets the query projection
    /// train through the memory layer (ROADMAP "Routing gradient /
    /// trained `wq`").
    ///
    /// The forward computes `out[q] = sum_j w_j * T[idx_j]` with
    /// `w_j = f(d2_j)` and `d2_j = |q - p_j|^2` for the selected
    /// original-frame lattice points `p_j` (the reduction is an
    /// isometry, so reduced-frame distances *are* original-frame
    /// distances).  Given the upstream gradient `d_gathered = dL/d(out)`
    /// this accumulates, per query,
    ///
    /// ```text
    /// dL/dq = sum_j <d_gathered[q], T[idx_j]> * f'(d2_j) * 2 (q - p_j)
    /// ```
    ///
    /// over exactly the hits the forward selected: the candidate
    /// scoring and top-k selection are recomputed here with the same
    /// scratch and the same operation order, so the selected set is
    /// bit-identical to the forward's.  The raw kernel weights are the
    /// gather coefficients (there is no normalising denominator in the
    /// forward — `total_weight` is observability, not part of the
    /// output), so no quotient-rule term appears.
    ///
    /// Ragged like the forward: `d_gathered` may be larger than `N x m`
    /// (only the prefix is read) and `d_queries` larger than `N x 8`
    /// (only the prefix is written).  Queries whose upstream gradient
    /// row is entirely zero — unmasked positions, the common case in a
    /// training batch — skip the pipeline outright.  Allocation-free
    /// per worker and sharded exactly like the forward dispatch;
    /// results are independent of the thread count.
    pub fn backward_gather_ragged_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        d_gathered: &[f32],
        d_queries: &mut [f64],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let m = table.dim();
        assert!(
            d_gathered.len() >= n * m,
            "upstream gradient holds {} floats, batch needs {}",
            d_gathered.len(),
            n * m
        );
        assert!(
            d_queries.len() >= n * 8,
            "query-gradient output holds {} floats, batch needs {}",
            d_queries.len(),
            n * 8
        );
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let d_gathered = &d_gathered[..n * m];
        let d_queries = &mut d_queries[..n * 8];
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            let mut scratch = Scratch::new();
            backward_range(torus, k, queries, table, d_gathered, &mut scratch, d_queries);
            return;
        }
        let chunk = n.div_ceil(shards);
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let gs = d_gathered.chunks(chunk * m);
            let dqs = d_queries.chunks_mut(chunk * 8);
            for ((q, g), dq) in qs.zip(gs).zip(dqs) {
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    backward_range(torus, k, q, table, g, &mut scratch, dq);
                });
            }
        });
    }

    /// Shard the batch across workers (or run inline when one worker or
    /// one query makes threading pure overhead).
    fn dispatch(
        &self,
        queries: &[f64],
        out: &mut BatchOutput,
        table: Option<&ValueTable>,
        gathered: &mut [f32],
    ) {
        let n = queries.len() / 8;
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let m = table.map(ValueTable::dim).unwrap_or(0);
        // keep each shard worth more than its thread-spawn cost: small
        // batches run inline rather than fanning out for microseconds
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            let mut scratch = Scratch::new();
            run_range(
                torus,
                k,
                queries,
                &mut scratch,
                &mut out.indices,
                &mut out.weights,
                &mut out.total_weight,
                table,
                gathered,
            );
            return;
        }
        let chunk = n.div_ceil(shards);
        // per-shard windows of the gather output (empty when there is
        // no fused gather; `&mut []` is 'static by promotion)
        let mut gs: Vec<&mut [f32]> = Vec::with_capacity(shards);
        if m == 0 {
            gs.resize_with(shards, || &mut []);
        } else {
            gs.extend(gathered.chunks_mut(chunk * m));
        }
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let is = out.indices.chunks_mut(chunk * k);
            let ws = out.weights.chunks_mut(chunk * k);
            let ts = out.total_weight.chunks_mut(chunk);
            for ((((q, idx), wts), tot), g) in qs.zip(is).zip(ws).zip(ts).zip(gs) {
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    run_range(torus, k, q, &mut scratch, idx, wts, tot, table, g);
                });
            }
        });
    }
}

/// Process a contiguous query range into equally-shaped output shards.
#[allow(clippy::too_many_arguments)]
fn run_range(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    scratch: &mut Scratch,
    indices: &mut [u64],
    weights: &mut [f32],
    totals: &mut [f64],
    table: Option<&ValueTable>,
    gathered: &mut [f32],
) {
    let soa = neighbor_table_soa();
    let nbr = neighbor_table();
    let m = table.map(ValueTable::dim).unwrap_or(0);
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let idx_row = &mut indices[qi * k_top..(qi + 1) * k_top];
        let w_row = &mut weights[qi * k_top..(qi + 1) * k_top];
        totals[qi] = lookup_one(torus, k_top, soa, nbr, q, scratch, idx_row, w_row);
        if let Some(t) = table {
            t.gather_weighted(idx_row, w_row, &mut gathered[qi * m..(qi + 1) * m]);
        }
    }
}

/// Candidate scoring shared by forward and backward: lane-major squared
/// distances into `scratch.d2`, in-support `(weight, candidate)` pairs
/// into `scratch.cand`; returns the total kernel weight.  Forward and
/// backward run the exact same operations in the same order here, so the
/// backward's recomputed selection is bit-identical to the forward's.
fn score_candidates(
    red: &Reduction,
    soa: &[[f64; N_NEIGHBORS]; 8],
    scratch: &mut Scratch,
) -> f64 {
    // Lane-major squared distances: eight contiguous FMA passes over the
    // 232-candidate row.  Accumulation order per candidate (lane 0..7)
    // matches the scalar path's unrolled sum, keeping d2 bit-identical.
    let d2 = &mut scratch.d2;
    // lane 0 initialises the accumulators, lanes 1..8 add — both arrays
    // are fixed [_; 8]s, so the split is bounds-check- and panic-free
    let (z0, z_rest) = (red.z[0], &red.z[1..]);
    let (lane0, lanes_rest) = (&soa[0], &soa[1..]);
    for (acc, &c) in d2.iter_mut().zip(lane0.iter()) {
        let d = z0 - c;
        *acc = d * d;
    }
    for (&zj, lane) in z_rest.iter().zip(lanes_rest.iter()) {
        for (acc, &c) in d2.iter_mut().zip(lane.iter()) {
            let d = zj - c;
            *acc += d * d;
        }
    }

    // Branchless kernel weights; only in-support candidates (d2 < 8,
    // i.e. w > 0) enter the selection.  `t^2 * t^2` is the same
    // operation order as `kernel_f`, so weights stay bit-identical, and
    // adding exact zeros leaves the total bit-identical to the scalar
    // path's in-support-only sum.
    scratch.cand.clear();
    let mut total = 0.0;
    for (ci, &d) in d2.iter().enumerate() {
        let t = (1.0 - d * 0.125).max(0.0);
        let t2 = t * t;
        let w = t2 * t2;
        total += w;
        if w > 0.0 {
            scratch.cand.push((w, ci as u32));
        }
    }
    total
}

/// One query through the fused pipeline; returns the total weight.
#[allow(clippy::too_many_arguments)]
fn lookup_one(
    torus: TorusK,
    k_top: usize,
    soa: &[[f64; N_NEIGHBORS]; 8],
    nbr: &[[i64; 8]; N_NEIGHBORS],
    q: &Vec8,
    scratch: &mut Scratch,
    idx_out: &mut [u64],
    w_out: &mut [f32],
) -> f64 {
    let red = reduce(q);
    let total = score_candidates(&red, soa, scratch);

    let top = partial_top_k_desc(&mut scratch.cand, k_top);
    for (j, &(w, ci)) in top.iter().enumerate() {
        let u = red.unmap(&nbr[ci as usize]);
        idx_out[j] = torus.index(&u);
        w_out[j] = w as f32;
    }
    for j in top.len()..k_top {
        idx_out[j] = 0;
        w_out[j] = 0.0;
    }
    total
}

/// The routing gradient for a contiguous query range (see
/// [`BatchLookupEngine::backward_gather_ragged_into`]): recompute the
/// forward's scoring + selection, then accumulate
/// `dL/dq = sum_j <dg, T[idx_j]> * f'(d2_j) * 2 (q - p_j)` over the
/// selected hits, with `p_j = unmap(c_j)` the original-frame lattice
/// point (`|q - p_j|^2 = d2_j` because the reduction is an isometry).
fn backward_range(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    table: &ValueTable,
    d_gathered: &[f32],
    scratch: &mut Scratch,
    d_queries: &mut [f64],
) {
    let soa = neighbor_table_soa();
    let nbr = neighbor_table();
    let m = table.dim();
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let dq = &mut d_queries[qi * 8..(qi + 1) * 8];
        dq.fill(0.0);
        let dg = &d_gathered[qi * m..(qi + 1) * m];
        // no-loss queries (unmasked positions) skip the whole pipeline
        if dg.iter().all(|&g| g == 0.0) {
            continue;
        }
        let red = reduce(q);
        score_candidates(&red, soa, scratch);
        let top = partial_top_k_desc(&mut scratch.cand, k_top);
        for &(_w, ci) in top {
            let df = kernel_df_dd2(scratch.d2[ci as usize]);
            let u = red.unmap(&nbr[ci as usize]);
            let row = table.row(torus.index(&u));
            let mut dldw = 0.0f64;
            for (&g, &r) in dg.iter().zip(row) {
                dldw += g as f64 * r as f64;
            }
            let coef = 2.0 * dldw * df;
            if coef == 0.0 {
                continue; // e.g. the hit's value row is all zeros
            }
            for (d, out) in dq.iter_mut().enumerate() {
                *out += coef * (q[d] - u[d] as f64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::kernel::TOTAL_WEIGHT_LOWER;
    use crate::lattice::LatticeLookup;
    use crate::util::rng::Rng;

    fn torus() -> TorusK {
        TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap()
    }

    fn random_queries(rng: &mut Rng, n: usize, span: f64) -> Vec<f64> {
        (0..n * 8).map(|_| rng.uniform(-span, span)).collect()
    }

    #[test]
    fn matches_scalar_oracle_bit_for_bit() {
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut oracle = LatticeLookup::new(torus(), 32);
        let mut rng = Rng::new(77);
        let queries = random_queries(&mut rng, 64, 9.0);
        let out = engine.lookup_batch(&queries);
        assert_eq!(out.queries(), 64);
        for qi in 0..64 {
            let q: Vec8 = queries[qi * 8..(qi + 1) * 8].try_into().unwrap();
            let want = oracle.lookup(&q);
            let (idx, wts) = out.query(qi);
            assert_eq!(out.total_weight[qi], want.total_weight, "query {qi}");
            for (j, hit) in want.hits.iter().enumerate() {
                assert_eq!(idx[j], hit.index, "query {qi} hit {j}");
                assert_eq!(wts[j], hit.weight as f32, "query {qi} hit {j}");
            }
            for j in want.hits.len()..32 {
                assert_eq!(idx[j], 0);
                assert_eq!(wts[j], 0.0);
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(5);
        let queries = random_queries(&mut rng, 101, 12.0);
        let base = BatchLookupEngine::new(torus(), 32).lookup_batch(&queries);
        for threads in [2, 3, 8, 64] {
            let out =
                BatchLookupEngine::with_threads(torus(), 32, threads).lookup_batch(&queries);
            assert_eq!(out.indices, base.indices, "{threads} threads");
            assert_eq!(out.weights, base.weights, "{threads} threads");
            assert_eq!(out.total_weight, base.total_weight, "{threads} threads");
        }
    }

    #[test]
    fn total_weights_stay_in_paper_bounds() {
        let engine = BatchLookupEngine::with_threads(torus(), 32, 4);
        let mut rng = Rng::new(13);
        let queries = random_queries(&mut rng, 500, 10.0);
        let out = engine.lookup_batch(&queries);
        for &tw in &out.total_weight {
            assert!(tw >= TOTAL_WEIGHT_LOWER - 1e-9, "{tw}");
            assert!(tw <= 1.0 + 1e-9, "{tw}");
        }
    }

    #[test]
    fn fused_gather_equals_lookup_then_gather() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(21, 0.02);
        let engine = BatchLookupEngine::with_threads(torus(), 32, 3);
        let mut rng = Rng::new(99);
        let queries = random_queries(&mut rng, 40, 8.0);
        let mut lk = BatchOutput::default();
        let mut fused = vec![0.0f32; 40 * 16];
        engine.lookup_gather_into(&queries, &table, &mut lk, &mut fused);

        let plain = engine.lookup_batch(&queries);
        assert_eq!(lk.indices, plain.indices);
        assert_eq!(lk.weights, plain.weights);
        let mut expect = vec![0.0f32; 16];
        for qi in 0..40 {
            let (idx, wts) = plain.query(qi);
            table.gather_weighted(idx, wts, &mut expect);
            assert_eq!(&fused[qi * 16..(qi + 1) * 16], &expect[..], "query {qi}");
        }
    }

    #[test]
    fn ragged_gather_writes_prefix_only() {
        // serving keeps one max-batch buffer; a ragged final batch must
        // fill exactly its own rows and leave the tail untouched
        let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
        table.randomize(4, 0.1);
        let engine = BatchLookupEngine::new(torus(), 16);
        let mut rng = Rng::new(12);
        let queries = random_queries(&mut rng, 5, 7.0);
        let sentinel = 123.5f32;
        let mut ragged = vec![sentinel; 12 * 8]; // max batch 12, fill 5
        let mut lk = BatchOutput::default();
        engine.lookup_gather_ragged_into(&queries, &table, &mut lk, &mut ragged);
        assert_eq!(lk.queries(), 5);
        let mut exact = vec![0.0f32; 5 * 8];
        let mut lk2 = BatchOutput::default();
        engine.lookup_gather_into(&queries, &table, &mut lk2, &mut exact);
        assert_eq!(&ragged[..5 * 8], &exact[..]);
        assert!(ragged[5 * 8..].iter().all(|&v| v == sentinel), "tail overwritten");
    }

    #[test]
    fn empty_batch_and_reused_output() {
        let engine = BatchLookupEngine::new(torus(), 8);
        let mut out = BatchOutput::default();
        engine.lookup_batch_into(&[], &mut out);
        assert_eq!(out.queries(), 0);
        // shrink a previously larger buffer
        let mut rng = Rng::new(3);
        engine.lookup_batch_into(&random_queries(&mut rng, 10, 5.0), &mut out);
        assert_eq!(out.queries(), 10);
        engine.lookup_batch_into(&random_queries(&mut rng, 2, 5.0), &mut out);
        assert_eq!(out.queries(), 2);
        assert_eq!(out.indices.len(), 16);
    }

    /// `loss = <dg, gathered(q)>` — the scalar probe the backward's
    /// query gradient is checked against by central finite differences.
    fn probe_loss(
        engine: &BatchLookupEngine,
        table: &ValueTable,
        queries: &[f64],
        dg: &[f32],
        lk: &mut BatchOutput,
        gathered: &mut [f32],
    ) -> f64 {
        engine.lookup_gather_into(queries, table, lk, gathered);
        gathered.iter().zip(dg).map(|(&v, &g)| v as f64 * g as f64).sum()
    }

    #[test]
    fn backward_matches_finite_difference_of_the_fused_gather() {
        // k_top = 232 keeps every in-support candidate selected, so the
        // gather is a smooth function of the query (the kernel is C^3 at
        // the support boundary) and a central difference converges
        let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
        table.randomize(7, 0.5);
        let engine = BatchLookupEngine::new(torus(), N_NEIGHBORS);
        let mut rng = Rng::new(31);
        let n = 12;
        let queries = random_queries(&mut rng, n, 6.0);
        let dg: Vec<f32> = (0..n * 8).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut dq = vec![0.0f64; n * 8];
        engine.backward_gather_ragged_into(&queries, &table, &dg, &mut dq);

        let mut lk = BatchOutput::default();
        let mut gathered = vec![0.0f32; n * 8];
        // the forward gathers in f32, so the step must sit well above
        // the f32 rounding floor of the loss difference
        let h = 1e-3;
        let mut probe = queries.clone();
        for i in 0..n * 8 {
            probe[i] = queries[i] + h;
            let up = probe_loss(&engine, &table, &probe, &dg, &mut lk, &mut gathered);
            probe[i] = queries[i] - h;
            let down = probe_loss(&engine, &table, &probe, &dg, &mut lk, &mut gathered);
            probe[i] = queries[i];
            let fd = (up - down) / (2.0 * h);
            let tol = 1e-3 + 1e-2 * fd.abs().max(dq[i].abs());
            assert!(
                (fd - dq[i]).abs() <= tol,
                "lane {i}: analytic {} vs finite difference {fd}",
                dq[i]
            );
        }
    }

    #[test]
    fn backward_zero_upstream_gradient_writes_zeros_and_leaves_the_tail() {
        let mut table = ValueTable::zeros(1 << 18, 4).unwrap();
        table.randomize(3, 0.2);
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(8);
        let queries = random_queries(&mut rng, 5, 6.0);
        // ragged buffers (max batch 9, fill 5) prefilled with sentinels:
        // stale prefix values must be overwritten, the tail untouched
        let dg = vec![0.0f32; 9 * 4];
        let mut dq = vec![7.5f64; 9 * 8];
        engine.backward_gather_ragged_into(&queries, &table, &dg, &mut dq);
        assert!(dq[..5 * 8].iter().all(|&v| v == 0.0), "zero upstream must mean zero grad");
        assert!(dq[5 * 8..].iter().all(|&v| v == 7.5), "tail overwritten");
    }

    #[test]
    fn backward_thread_count_does_not_change_results() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(11, 0.1);
        let mut rng = Rng::new(40);
        let n = 101;
        let queries = random_queries(&mut rng, n, 10.0);
        let dg: Vec<f32> = (0..n * 16).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut base = vec![0.0f64; n * 8];
        BatchLookupEngine::new(torus(), 32)
            .backward_gather_ragged_into(&queries, &table, &dg, &mut base);
        for threads in [2, 3, 8] {
            let mut dq = vec![0.0f64; n * 8];
            BatchLookupEngine::with_threads(torus(), 32, threads)
                .backward_gather_ragged_into(&queries, &table, &dg, &mut dq);
            for (i, (a, b)) in dq.iter().zip(&base).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads, lane {i}");
            }
        }
    }

    #[test]
    fn lattice_point_queries_hit_themselves() {
        let engine = BatchLookupEngine::with_threads(torus(), 32, 2);
        let k = engine.torus;
        let ids = [0u64, 1, 1000, 12345];
        let mut queries = Vec::new();
        for &idx in &ids {
            let x = k.representative(idx);
            queries.extend(x.iter().map(|&v| v as f64));
        }
        let out = engine.lookup_batch(&queries);
        for (qi, &want) in ids.iter().enumerate() {
            let (idx, wts) = out.query(qi);
            assert_eq!(idx[0], want);
            assert!((wts[0] - 1.0).abs() < 1e-6);
            assert_eq!(wts[1], 0.0, "open-ball kernel: only the point itself");
        }
    }
}
