//! Batched SoA lookup→gather engine — the fused L3 hot path.
//!
//! [`super::lookup::LatticeLookup`] answers one query at a time and
//! allocates a `Vec<Hit>` per call; fine as a reference oracle, too slow
//! to serve traffic.  [`BatchLookupEngine`] processes N queries through
//! reduce → candidate scoring → top-k → inverse isometry → torus index
//! (→ optionally the weighted value-table gather) as one allocation-free
//! pipeline over structure-of-arrays buffers.
//!
//! # SoA layout
//!
//! Queries arrive row-major (`N x 8` f64).  Results live in flat
//! parallel arrays (see [`BatchOutput`]), `k = k_top` slots per query:
//!
//! ```text
//! indices:      [N*k] u64   indices[q*k + j] = torus slot of hit j
//! weights:      [N*k] f32   weights[q*k + j] = kernel weight of hit j
//! total_weight: [N]   f64   sum of all in-support candidate weights
//! ```
//!
//! Queries with fewer than `k` in-support candidates pad the tail with
//! `(index 0, weight 0.0)` — the same "zero weight means no access"
//! convention the memstore gather and `AccessStats` already use.  The
//! fused gather writes `out: [N*m] f32` with
//! `out[q] = sum_j weights[q,j] * table[indices[q,j]]`, skipping the
//! intermediate `k x m` gathered buffer entirely.
//!
//! # Why it is fast
//!
//! * **Scoring** walks the candidate table in transposed (lane-major)
//!   order: per lane, one contiguous fused multiply-add pass over 232
//!   f64s (`d2[c] += (z_j - soa[j][c])^2`), which LLVM autovectorizes;
//!   the scalar path's unrolled 8-lane loop stays in `lookup.rs` as the
//!   differential-testing reference.  The per-candidate accumulation
//!   order (lane 0..7) is identical to the scalar path, so distances —
//!   and therefore weights — are bit-identical.
//! * **Top-k** replaces the O(n*k) selection sort with an O(n + k log k)
//!   quickselect ([`crate::util::topk`]); candidates with `d2 >= 8`
//!   never enter the selection.
//! * **Gather** fuses into the same pass with software prefetch of the
//!   upcoming rows, so index math overlaps the memory latency of the
//!   O(1) random accesses.
//! * **Batch sharding** splits the queries across `std::thread` scoped
//!   workers with per-worker scratch; output shards are disjoint, so
//!   results are bit-identical for every thread count.
//! * **f32 serving fast path** (`lookup_batch_f32*`,
//!   `lookup_gather_ragged_f32_into`, `lookup_gather_ragged_q8_into`):
//!   the same pipeline with the 232-candidate row scored by the
//!   runtime-dispatched SIMD kernels in [`super::simd`]
//!   (AVX2+FMA / NEON / scalar-f32) and weights produced directly as
//!   f32.  The f64 pipeline stays the training oracle; the f32 path is
//!   differential-tested against it with tolerance bounds
//!   (`rust/tests/numeric_differential.rs`), and `LRAM_SIMD=off` pins
//!   the scalar-f32 fallback for CI.
//!
//! # Tie determinism
//!
//! Equal kernel weights are ordered canonically — weight descending,
//! then **torus row ascending**, then candidate index ascending — by
//! [`select_canonical`], shared by every path (f64 forward, backward
//! recompute, f32 SIMD, and the scalar oracle in `lookup.rs`).  The
//! selected hit set is therefore a deterministic function of the query
//! alone, never of scan order or a selection algorithm's swap history.
//!
//! # The staged sharded pipeline
//!
//! Sharded serving partitions the value-table rows across owners (one
//! [`ShardPlan`]) and needs scoring and gathering to run on *different*
//! workers, so the fused lookup→gather is also exposed as four explicit
//! stages:
//!
//! 1. **score** ([`BatchLookupEngine::score_into`] /
//!    [`BatchLookupEngine::score_f32_into`]) — per query, every
//!    in-support candidate resolved to `(weight, torus row, candidate)`
//!    ([`ScoredBatch`]);
//! 2. **select** ([`BatchLookupEngine::select_owned`]) — each shard's
//!    canonical top-k over the rows it owns ([`ShardSelection`]);
//! 3. **merge** ([`BatchLookupEngine::merge_into`]) — re-select over
//!    the union of the shard lists into a [`BatchOutput`];
//! 4. **gather** ([`BatchLookupEngine::stage_gather`] /
//!    [`BatchLookupEngine::stage_gather_q8`] +
//!    [`BatchLookupEngine::combine_gather`]) — shards stage the value
//!    rows they own, the coordinator combines them in canonical slot
//!    order.
//!
//! Because the canonical order is a *total* order and every row has
//! exactly one owner, the union of per-shard top-k lists is a superset
//! of the global top-k — the merged selection, weights, and (f64/f32)
//! gathered outputs are **bit-identical** to the fused path for every
//! shard count, which the tests below pin down.

use anyhow::{bail, Result};

use super::e8::{reduce, vec8, Reduction, Vec8};
use super::kernel::kernel_df_dd2;
use super::neighbors::{neighbor_table, neighbor_table_soa, N_NEIGHBORS};
use super::simd::{self, AlignedScores};
use super::torus::TorusK;
use crate::memstore::{QuantizedValueTable, ValueTable};
use crate::util::topk::{desc_nan_last, partial_top_k_desc, Score};

/// Structure-of-arrays results for a batch of lookups (see module docs).
#[derive(Debug, Clone, Default)]
pub struct BatchOutput {
    /// `[N*k]` torus memory slots, `k_top` per query, weight-descending.
    pub indices: Vec<u64>,
    /// `[N*k]` kernel weights; `0.0` marks padded (absent) hits.
    pub weights: Vec<f32>,
    /// `[N]` total kernel weight over *all* in-support candidates
    /// (paper bound: `[0.851, 1]`).
    pub total_weight: Vec<f64>,
    k_top: usize,
}

impl BatchOutput {
    /// Number of queries currently held.
    pub fn queries(&self) -> usize {
        self.total_weight.len()
    }

    /// Hits kept per query.
    pub fn k_top(&self) -> usize {
        self.k_top
    }

    /// The `(indices, weights)` rows of query `q`.
    pub fn query(&self, q: usize) -> (&[u64], &[f32]) {
        let lo = q * self.k_top;
        let hi = lo + self.k_top;
        (&self.indices[lo..hi], &self.weights[lo..hi])
    }

    fn reset(&mut self, n: usize, k_top: usize) {
        self.k_top = k_top;
        self.indices.resize(n * k_top, 0);
        self.weights.resize(n * k_top, 0.0);
        self.total_weight.resize(n, 0.0);
    }
}

/// Contiguous-range partition of the value-table rows across `N` shard
/// owners — the candidate→owner routing contract of the staged
/// pipeline (module docs, "The staged sharded pipeline").
///
/// `bounds` holds `N + 1` non-decreasing row offsets with
/// `bounds[0] = 0` and `bounds[N] = rows`; shard `s` owns the half-open
/// row range `bounds[s]..bounds[s+1]`.  Every torus row therefore has
/// **exactly one** owner (the ownership-partition property the tests
/// pin), which is what makes the per-shard top-k merge exact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    bounds: Vec<u64>,
}

impl ShardPlan {
    /// Evenly partition `rows` across `n_shards` contiguous ranges:
    /// `bounds[s] = floor(rows * s / n)`, so shard sizes differ by at
    /// most one row.
    pub fn new(rows: u64, n_shards: usize) -> Self {
        assert!(n_shards >= 1, "a shard plan needs at least one shard");
        let bounds =
            (0..=n_shards).map(|s| (rows as u128 * s as u128 / n_shards as u128) as u64).collect();
        ShardPlan { bounds }
    }

    /// Rebuild a plan from checkpoint-manifest bounds, refusing
    /// malformed ones loudly (the manifest is external input).
    pub fn from_bounds(bounds: Vec<u64>) -> Result<Self> {
        if bounds.len() < 2 {
            bail!("shard bounds need at least 2 offsets, got {}", bounds.len());
        }
        if bounds[0] != 0 {
            bail!("shard bounds must start at row 0, got {}", bounds[0]);
        }
        if bounds.windows(2).any(|p| p[0] > p[1]) {
            bail!("shard bounds must be non-decreasing: {bounds:?}");
        }
        Ok(ShardPlan { bounds })
    }

    /// Total rows covered by the plan.
    pub fn rows(&self) -> u64 {
        *self.bounds.last().unwrap_or(&0)
    }

    pub fn n_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// The `N + 1` row offsets (checkpoint-manifest serialisation).
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// The row range shard `shard` owns.
    pub fn range(&self, shard: usize) -> std::ops::Range<u64> {
        self.bounds[shard]..self.bounds[shard + 1]
    }

    /// The unique shard owning `row` (`row` must be `< rows()`).
    #[inline]
    pub fn owner_of(&self, row: u64) -> usize {
        debug_assert!(row < self.rows(), "row {row} out of range ({})", self.rows());
        self.bounds.partition_point(|&b| b <= row) - 1
    }
}

/// Per-query scored candidate lists — the `score` stage output.
///
/// Unlike the fused path, every in-support candidate is resolved to its
/// torus row *at scoring time* (integer arithmetic, identical across
/// paths), so the `select`/`merge` stages can route by row ownership
/// without redoing the inverse isometry.
#[derive(Debug, Clone, Default)]
pub struct ScoredBatch<S> {
    /// `(weight, torus row, candidate)` triples, grouped by query.
    entries: Vec<(S, u64, u32)>,
    /// `offsets[q]..offsets[q+1]` bounds query `q`'s triples (`N + 1`).
    offsets: Vec<usize>,
    /// `[N]` total kernel weight, as in [`BatchOutput::total_weight`].
    total_weight: Vec<f64>,
}

impl<S: Copy> ScoredBatch<S> {
    /// Number of queries scored.
    pub fn queries(&self) -> usize {
        self.total_weight.len()
    }

    /// Query `q`'s `(weight, torus row, candidate)` triples.
    pub fn query(&self, q: usize) -> &[(S, u64, u32)] {
        &self.entries[self.offsets[q]..self.offsets[q + 1]]
    }

    /// Query `q`'s total in-support kernel weight.
    pub fn total_weight(&self, q: usize) -> f64 {
        self.total_weight[q]
    }

    fn reset(&mut self) {
        self.entries.clear();
        self.offsets.clear();
        self.offsets.push(0);
        self.total_weight.clear();
    }
}

/// One shard's per-query canonical top-k over the rows it owns — the
/// `select` stage output, at most `k_top` triples per query.
#[derive(Debug, Clone, Default)]
pub struct ShardSelection<S> {
    entries: Vec<(S, u64, u32)>,
    offsets: Vec<usize>,
}

impl<S: Copy> ShardSelection<S> {
    /// Number of queries covered.
    pub fn queries(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Query `q`'s selected `(weight, torus row, candidate)` triples,
    /// canonically ordered.
    pub fn query(&self, q: usize) -> &[(S, u64, u32)] {
        &self.entries[self.offsets[q]..self.offsets[q + 1]]
    }

    fn reset(&mut self) {
        self.entries.clear();
        self.offsets.clear();
        self.offsets.push(0);
    }
}

/// One shard's staged value rows for the `gather` stage: the surviving
/// (positive-weight) merged slots it owns, in global slot order, held
/// as either f32 rows or i8 codes plus per-row scales.  The
/// coordinator's [`BatchLookupEngine::combine_gather`] replays the
/// slots in canonical order with one cursor per shard, reproducing the
/// fused gathers' exact operation sequence.
#[derive(Debug, Clone, Default)]
pub struct GatherStage {
    rows: Vec<f32>,
    codes: Vec<i8>,
    scales: Vec<f32>,
    dim: usize,
    quantized: bool,
}

impl GatherStage {
    /// How many value rows this shard staged (observability/tests).
    pub fn staged_rows(&self) -> usize {
        if self.quantized {
            self.scales.len()
        } else if self.dim == 0 {
            0
        } else {
            self.rows.len() / self.dim
        }
    }
}

/// Weight narrowing for the staged merge: the fused f64 path stores
/// `w as f32` into [`BatchOutput`] (see [`lookup_one`]), the f32 path
/// stores the score unchanged — the staged merge must match both
/// bit-for-bit.
pub trait MergeWeight: Score {
    /// Narrow to the `BatchOutput` weight exactly as the fused path does.
    fn narrow(self) -> f32;
}

impl MergeWeight for f64 {
    fn narrow(self) -> f32 {
        self as f32
    }
}

impl MergeWeight for f32 {
    fn narrow(self) -> f32 {
        self
    }
}

/// Forward-pass routing decisions captured for the backward pass — the
/// trainer-side companion of [`BatchOutput`].
///
/// The routing backward only needs, per selected hit, the squared
/// distance `d2` (for `f'(d2)`) and the candidate index (for the
/// original-frame lattice point and torus row, both cheap integer
/// arithmetic given the query's reduction).  Capturing `(d2, candidate)`
/// during the forward lets
/// [`BatchLookupEngine::backward_gather_ragged_cached_into`] skip the
/// expensive part of the recompute — the 8×232 distance passes, the
/// kernel weights, and the canonical top-k — per masked query.
///
/// Layout mirrors `BatchOutput`: `k_top` slots per query, stored in the
/// forward's canonical selection order, padded with
/// [`BackwardCache::NO_HIT`] candidates.  The cache is only coherent
/// with the forward pass that filled it; callers must
/// [`BackwardCache::invalidate`] it whenever the queries, the engine, or
/// the numeric path change (the f32/q8/sharded/oracle paths never fill
/// it — the routing backward is defined against the f64 forward).
#[derive(Debug, Clone, Default)]
pub struct BackwardCache {
    /// `[N*k]` squared distances of the selected hits.
    d2: Vec<f64>,
    /// `[N*k]` candidate indices; [`Self::NO_HIT`] marks padding.
    cand: Vec<u32>,
    k_top: usize,
    queries: usize,
    valid: bool,
}

impl BackwardCache {
    /// Padding sentinel: no real candidate index (they are `< 232`).
    pub const NO_HIT: u32 = u32::MAX;

    /// Whether the cache holds the routing decisions of a forward pass
    /// over exactly `n` queries at `k_top` hits per query.
    pub fn matches(&self, n: usize, k_top: usize) -> bool {
        self.valid && self.queries == n && self.k_top == k_top
    }

    /// Drop the cached decisions (the next backward must recompute).
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    fn reset(&mut self, n: usize, k_top: usize) {
        self.queries = n;
        self.k_top = k_top;
        self.d2.clear();
        self.d2.resize(n * k_top, 0.0);
        self.cand.clear();
        self.cand.resize(n * k_top, Self::NO_HIT);
        self.valid = true;
    }
}

/// Per-worker scratch: one distance row over the candidate table, the
/// in-support `(weight, candidate)` pairs awaiting selection, and the
/// canonically-ordered `(weight, torus row, candidate)` selection.
struct Scratch {
    d2: [f64; N_NEIGHBORS],
    cand: Vec<(f64, u32)>,
    sel: Vec<(f64, u64, u32)>,
}

impl Scratch {
    fn new() -> Self {
        Scratch {
            d2: [0.0; N_NEIGHBORS],
            cand: Vec::with_capacity(N_NEIGHBORS),
            sel: Vec::with_capacity(N_NEIGHBORS),
        }
    }
}

/// Per-worker scratch for the f32 SIMD path: the aligned 232-wide score
/// row plus the f32 selection buffers.
struct ScratchF32 {
    scores: AlignedScores,
    cand: Vec<(f32, u32)>,
    sel: Vec<(f32, u64, u32)>,
}

impl ScratchF32 {
    fn new() -> Self {
        ScratchF32 {
            scores: AlignedScores::new(),
            cand: Vec::with_capacity(N_NEIGHBORS),
            sel: Vec::with_capacity(N_NEIGHBORS),
        }
    }
}

/// The value-table flavour behind a fused f32 gather.
#[derive(Clone, Copy)]
enum GatherTable<'a> {
    None,
    F32(&'a ValueTable),
    Q8(&'a QuantizedValueTable),
}

impl GatherTable<'_> {
    fn dim(self) -> usize {
        match self {
            GatherTable::None => 0,
            GatherTable::F32(t) => t.dim(),
            GatherTable::Q8(t) => t.dim(),
        }
    }
}

/// Batched lattice lookup (+ optional fused gather) over a fixed torus.
///
/// Construction is cheap; the engine holds no per-batch state, so one
/// engine can be shared by reference across threads (or cheaply cloned
/// into per-shard workers).
#[derive(Clone)]
pub struct BatchLookupEngine {
    pub torus: TorusK,
    pub k_top: usize,
    n_threads: usize,
}

impl BatchLookupEngine {
    /// Single-threaded engine (the common serving-shard configuration).
    pub fn new(torus: TorusK, k_top: usize) -> Self {
        Self::with_threads(torus, k_top, 1)
    }

    /// Engine sharding each batch across `n_threads` scoped workers.
    pub fn with_threads(torus: TorusK, k_top: usize, n_threads: usize) -> Self {
        assert!(k_top >= 1, "k_top must be at least 1");
        BatchLookupEngine { torus, k_top, n_threads: n_threads.max(1) }
    }

    /// Engine using all available hardware parallelism.
    pub fn auto(torus: TorusK, k_top: usize) -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(torus, k_top, n)
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Look up a batch of row-major queries (`N x 8` f64) into `out`.
    ///
    /// Allocation-free after `out` reaches batch size; results are
    /// independent of the thread count.
    pub fn lookup_batch_into(&self, queries: &[f64], out: &mut BatchOutput) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        out.reset(n, self.k_top);
        self.dispatch(queries, out, None, &mut []);
    }

    /// Convenience wrapper allocating the output.
    pub fn lookup_batch(&self, queries: &[f64]) -> BatchOutput {
        let mut out = BatchOutput::default();
        self.lookup_batch_into(queries, &mut out);
        out
    }

    /// Fused lookup → weighted gather: fills `lookup` as
    /// [`Self::lookup_batch_into`] and accumulates
    /// `gathered[q] = sum_j w[q,j] * table[idx[q,j]]` (`N x m` f32)
    /// without materialising any intermediate `k x m` buffer.
    pub fn lookup_gather_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) {
        assert_eq!(
            gathered.len(),
            queries.len() / 8 * table.dim(),
            "gather output must be N x m"
        );
        self.lookup_gather_ragged_into(queries, table, lookup, gathered);
    }

    /// [`Self::lookup_gather_into`] sized for ragged final batches:
    /// `gathered` may be *larger* than `N x m` (serving reuses one
    /// max-batch-sized buffer while the last batch of a stream is rarely
    /// full); only the first `N * m` elements are written, the tail is
    /// left untouched.
    pub fn lookup_gather_ragged_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let need = n * table.dim();
        assert!(
            gathered.len() >= need,
            "gather output holds {} floats, batch needs {need}",
            gathered.len()
        );
        lookup.reset(n, self.k_top);
        self.dispatch(queries, lookup, Some(table), &mut gathered[..need]);
    }

    /// [`Self::lookup_gather_ragged_into`] that additionally captures
    /// each query's selected `(d2, candidate)` pairs into `cache` so the
    /// backward pass can skip the scoring + top-k recompute
    /// ([`Self::backward_gather_ragged_cached_into`]).  The lookup and
    /// gather results are bit-identical to the uncached path — the
    /// capture reads the same per-worker scratch the selection already
    /// filled, adding two stores per hit.
    pub fn lookup_gather_ragged_cached_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
        cache: &mut BackwardCache,
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let need = n * table.dim();
        assert!(
            gathered.len() >= need,
            "gather output holds {} floats, batch needs {need}",
            gathered.len()
        );
        lookup.reset(n, self.k_top);
        cache.reset(n, self.k_top);
        self.dispatch_cached(queries, lookup, table, &mut gathered[..need], cache);
    }

    /// The routing gradient over a forward's captured selection: exactly
    /// [`Self::backward_gather_ragged_into`] — same hits, same operation
    /// order, bit-identical `d_queries` — but reading each masked
    /// query's `(d2, candidate)` pairs from `cache` instead of re-running
    /// the candidate scoring and canonical top-k.  Only the query's
    /// reduction (exact integer-dominated arithmetic) is recomputed, for
    /// the original-frame lattice points and torus rows.
    ///
    /// `cache` must hold the selections of the forward pass over these
    /// exact queries ([`BackwardCache::matches`]); anything else is a
    /// logic error upstream and panics rather than silently producing
    /// gradients for the wrong routing.
    pub fn backward_gather_ragged_cached_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        d_gathered: &[f32],
        cache: &BackwardCache,
        d_queries: &mut [f64],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        assert!(
            cache.matches(n, self.k_top),
            "backward cache is stale: holds {} queries x {} hits (valid: {}), \
             the batch needs {n} x {}",
            cache.queries,
            cache.k_top,
            cache.valid,
            self.k_top
        );
        let m = table.dim();
        assert!(
            d_gathered.len() >= n * m,
            "upstream gradient holds {} floats, batch needs {}",
            d_gathered.len(),
            n * m
        );
        assert!(
            d_queries.len() >= n * 8,
            "query-gradient output holds {} floats, batch needs {}",
            d_queries.len(),
            n * 8
        );
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let d_gathered = &d_gathered[..n * m];
        let d_queries = &mut d_queries[..n * 8];
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            backward_range_cached(
                torus,
                k,
                queries,
                table,
                d_gathered,
                &cache.d2,
                &cache.cand,
                d_queries,
            );
            return;
        }
        let chunk = n.div_ceil(shards);
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let gs = d_gathered.chunks(chunk * m);
            let d2s = cache.d2.chunks(chunk * k);
            let cis = cache.cand.chunks(chunk * k);
            let dqs = d_queries.chunks_mut(chunk * 8);
            for ((((q, g), d2), ci), dq) in qs.zip(gs).zip(d2s).zip(cis).zip(dqs) {
                s.spawn(move || {
                    backward_range_cached(torus, k, q, table, g, d2, ci, dq);
                });
            }
        });
    }

    /// f32 SIMD lookup: same shapes and padding as
    /// [`Self::lookup_batch_into`], with the candidate row scored by the
    /// runtime-dispatched kernel in [`super::simd`].  Weights agree with
    /// the f64 engine to ~1e-6 absolute; hit *sets* agree exactly except
    /// for candidates within f32 rounding of the `d2 = 8` support
    /// boundary, whose weights are below that same tolerance.
    pub fn lookup_batch_f32_into(&self, queries: &[f64], out: &mut BatchOutput) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        out.reset(n, self.k_top);
        self.dispatch_f32(queries, out, GatherTable::None, &mut []);
    }

    /// Convenience wrapper allocating the output (f32 scoring path).
    pub fn lookup_batch_f32(&self, queries: &[f64]) -> BatchOutput {
        let mut out = BatchOutput::default();
        self.lookup_batch_f32_into(queries, &mut out);
        out
    }

    /// The f32 serving fast path: fused SIMD lookup → weighted gather,
    /// ragged like [`Self::lookup_gather_ragged_into`] (only the first
    /// `N * m` elements of `gathered` are written).
    pub fn lookup_gather_ragged_f32_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let need = n * table.dim();
        assert!(
            gathered.len() >= need,
            "gather output holds {} floats, batch needs {need}",
            gathered.len()
        );
        lookup.reset(n, self.k_top);
        self.dispatch_f32(queries, lookup, GatherTable::F32(table), &mut gathered[..need]);
    }

    /// [`Self::lookup_gather_ragged_f32_into`] over an int8-quantized
    /// value table: rows dequantize inside the fused gather (one fused
    /// multiply-add per element, the per-row scale folded into the
    /// kernel weight).
    pub fn lookup_gather_ragged_q8_into(
        &self,
        queries: &[f64],
        table: &QuantizedValueTable,
        lookup: &mut BatchOutput,
        gathered: &mut [f32],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let need = n * table.dim();
        assert!(
            gathered.len() >= need,
            "gather output holds {} floats, batch needs {need}",
            gathered.len()
        );
        lookup.reset(n, self.k_top);
        self.dispatch_f32(queries, lookup, GatherTable::Q8(table), &mut gathered[..need]);
    }

    // ------------------------------------------------------------------
    // The staged score / select / merge / gather API (sharded serving)
    // ------------------------------------------------------------------

    /// Stage 1 of the staged pipeline: score every query against the
    /// 232-candidate table and resolve each in-support candidate to its
    /// torus row.  Scoring is numerically identical to
    /// [`Self::lookup_batch_into`] (same reduce, same accumulation
    /// order), so the staged pipeline's final selection and weights are
    /// bit-identical to the fused path's.  Single-threaded by design:
    /// sharded executors parallelise by slicing `queries` across
    /// workers and passing the parts to [`Self::select_owned`] in query
    /// order.
    pub fn score_into(&self, queries: &[f64], out: &mut ScoredBatch<f64>) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let soa = neighbor_table_soa();
        let nbr = neighbor_table();
        let mut scratch = Scratch::new();
        out.reset();
        for chunk in queries.chunks_exact(8) {
            let q = vec8(chunk);
            let red = reduce(q);
            let total = score_candidates(&red, soa, &mut scratch);
            for &(w, ci) in &scratch.cand {
                out.entries.push((w, self.torus.index(&red.unmap(&nbr[ci as usize])), ci));
            }
            out.offsets.push(out.entries.len());
            out.total_weight.push(total);
        }
    }

    /// [`Self::score_into`] for the f32 SIMD serving path — same
    /// scoring kernel as [`Self::lookup_batch_f32_into`].
    pub fn score_f32_into(&self, queries: &[f64], out: &mut ScoredBatch<f32>) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let nbr = neighbor_table();
        let mut scratch = ScratchF32::new();
        out.reset();
        for chunk in queries.chunks_exact(8) {
            let q = vec8(chunk);
            let red = reduce(q);
            let mut z32 = [0.0f32; 8];
            for (o, &v) in z32.iter_mut().zip(red.z.iter()) {
                *o = v as f32;
            }
            let total = simd::score_row(&z32, &mut scratch.scores);
            for (ci, &w) in scratch.scores.0.iter().enumerate() {
                if w > 0.0 {
                    out.entries.push((w, self.torus.index(&red.unmap(&nbr[ci])), ci as u32));
                }
            }
            out.offsets.push(out.entries.len());
            out.total_weight.push(total);
        }
    }

    /// Stage 2: shard `shard`'s canonical top-k over the rows it owns,
    /// for every query.
    ///
    /// `scored` holds query-contiguous parts (the per-worker outputs of
    /// stage 1, in query order).  Selection reuses
    /// [`crate::util::topk::partial_top_k_desc`] with `(row, candidate)`
    /// payloads, whose ascending payload tie-break *is* the canonical
    /// `(weight desc, row asc, candidate asc)` order — each shard list
    /// comes out canonically sorted, at most `k_top` long.
    pub fn select_owned<S: Score>(
        &self,
        scored: &[ScoredBatch<S>],
        plan: &ShardPlan,
        shard: usize,
        out: &mut ShardSelection<S>,
    ) {
        let range = plan.range(shard);
        out.reset();
        let mut cand: Vec<(S, (u64, u32))> = Vec::with_capacity(N_NEIGHBORS);
        for part in scored {
            for q in 0..part.queries() {
                cand.clear();
                for &(w, row, ci) in part.query(q) {
                    if range.contains(&row) {
                        cand.push((w, (row, ci)));
                    }
                }
                for &(w, (row, ci)) in partial_top_k_desc(&mut cand, self.k_top) {
                    out.entries.push((w, row, ci));
                }
                out.offsets.push(out.entries.len());
            }
        }
    }

    /// Stage 3: merge the per-shard selections back into one
    /// [`BatchOutput`], query by query, under the same canonical total
    /// order.  Every row has exactly one owner and each shard kept its
    /// own canonical top-k, so the union of the shard lists is a
    /// superset of the global top-k — re-selecting over it is
    /// bit-identical to the fused [`select_canonical`] result for any
    /// shard count.
    pub fn merge_into<S: MergeWeight>(
        &self,
        scored: &[ScoredBatch<S>],
        selections: &[ShardSelection<S>],
        out: &mut BatchOutput,
    ) {
        let n: usize = scored.iter().map(ScoredBatch::queries).sum();
        for sel in selections {
            assert_eq!(sel.queries(), n, "every shard selection must cover every query");
        }
        out.reset(n, self.k_top);
        let mut cand: Vec<(S, (u64, u32))> =
            Vec::with_capacity(selections.len() * self.k_top);
        let mut qg = 0usize;
        for part in scored {
            for q in 0..part.queries() {
                cand.clear();
                for sel in selections {
                    cand.extend(sel.query(qg).iter().map(|&(w, row, ci)| (w, (row, ci))));
                }
                let top = partial_top_k_desc(&mut cand, self.k_top);
                let idx_row = &mut out.indices[qg * self.k_top..(qg + 1) * self.k_top];
                let w_row = &mut out.weights[qg * self.k_top..(qg + 1) * self.k_top];
                for (j, &(w, (row, _ci))) in top.iter().enumerate() {
                    idx_row[j] = row;
                    w_row[j] = w.narrow();
                }
                for j in top.len()..self.k_top {
                    idx_row[j] = 0;
                    w_row[j] = 0.0;
                }
                out.total_weight[qg] = part.total_weight(q);
                qg += 1;
            }
        }
    }

    /// Stage 4a (shard side): stage the f32 value rows this shard owns
    /// among the merged surviving (positive-weight) slots, in global
    /// slot order.  `base` is the global torus row of `table`'s row 0 —
    /// `0` for a full-table view, `plan.range(shard).start` for a
    /// compact per-shard table.
    pub fn stage_gather(
        &self,
        merged: &BatchOutput,
        plan: &ShardPlan,
        shard: usize,
        base: u64,
        table: &ValueTable,
        out: &mut GatherStage,
    ) {
        out.rows.clear();
        out.codes.clear();
        out.scales.clear();
        out.dim = table.dim();
        out.quantized = false;
        let range = plan.range(shard);
        for (&row, &w) in merged.indices.iter().zip(&merged.weights) {
            if w == 0.0 || !range.contains(&row) {
                continue;
            }
            out.rows.extend_from_slice(table.row(row - base));
        }
    }

    /// [`Self::stage_gather`] over an int8-quantized shard table: stages
    /// the raw codes plus per-row scales so the combine step replays the
    /// exact fused `axpy_q8` kernel.
    pub fn stage_gather_q8(
        &self,
        merged: &BatchOutput,
        plan: &ShardPlan,
        shard: usize,
        base: u64,
        table: &QuantizedValueTable,
        out: &mut GatherStage,
    ) {
        out.rows.clear();
        out.codes.clear();
        out.scales.clear();
        out.dim = table.dim();
        out.quantized = true;
        let range = plan.range(shard);
        for (&row, &w) in merged.indices.iter().zip(&merged.weights) {
            if w == 0.0 || !range.contains(&row) {
                continue;
            }
            out.codes.extend_from_slice(table.row(row - base));
            out.scales.push(table.scale(row - base));
        }
    }

    /// Stage 4b (coordinator side): combine the per-shard stages into
    /// the gathered output, walking each query's slots in canonical
    /// order with one cursor per shard.  The per-slot operation
    /// sequence (zero the row, skip zero weights, `out += w * value` /
    /// `axpy_q8(w * scale, codes)`) is exactly the fused gathers', so
    /// f64- and f32-path results are bit-identical to
    /// [`Self::lookup_gather_ragged_into`] /
    /// [`Self::lookup_gather_ragged_f32_into`], and q8 results to
    /// [`Self::lookup_gather_ragged_q8_into`].  Ragged like those: only
    /// the first `N * m` elements of `gathered` are written.
    pub fn combine_gather(
        &self,
        merged: &BatchOutput,
        plan: &ShardPlan,
        stages: &[GatherStage],
        gathered: &mut [f32],
    ) {
        assert_eq!(stages.len(), plan.n_shards(), "one gather stage per shard");
        let m = stages.iter().map(|s| s.dim).max().unwrap_or(0);
        for s in stages {
            assert!(
                s.dim == m || s.staged_rows() == 0,
                "shard gather stages disagree on the row dim"
            );
        }
        let n = merged.queries();
        assert!(
            gathered.len() >= n * m,
            "gather output holds {} floats, batch needs {}",
            gathered.len(),
            n * m
        );
        let k = merged.k_top();
        let mut cursors = vec![0usize; stages.len()];
        for q in 0..n {
            let out_row = &mut gathered[q * m..(q + 1) * m];
            out_row.fill(0.0);
            let lo = q * k;
            let slots = merged.indices[lo..lo + k].iter().zip(&merged.weights[lo..lo + k]);
            for (&row, &w) in slots {
                if w == 0.0 {
                    continue;
                }
                let s = plan.owner_of(row);
                let stage = &stages[s];
                let c = cursors[s];
                cursors[s] += 1;
                if stage.quantized {
                    simd::axpy_q8(w * stage.scales[c], &stage.codes[c * m..(c + 1) * m], out_row);
                } else {
                    let staged = &stage.rows[c * m..(c + 1) * m];
                    for (o, &v) in out_row.iter_mut().zip(staged) {
                        *o += w * v;
                    }
                }
            }
        }
    }

    /// Backward of the fused lookup→gather with respect to the
    /// *queries* — the routing gradient that lets the query projection
    /// train through the memory layer (ROADMAP "Routing gradient /
    /// trained `wq`").
    ///
    /// The forward computes `out[q] = sum_j w_j * T[idx_j]` with
    /// `w_j = f(d2_j)` and `d2_j = |q - p_j|^2` for the selected
    /// original-frame lattice points `p_j` (the reduction is an
    /// isometry, so reduced-frame distances *are* original-frame
    /// distances).  Given the upstream gradient `d_gathered = dL/d(out)`
    /// this accumulates, per query,
    ///
    /// ```text
    /// dL/dq = sum_j <d_gathered[q], T[idx_j]> * f'(d2_j) * 2 (q - p_j)
    /// ```
    ///
    /// over exactly the hits the forward selected: the candidate
    /// scoring and top-k selection are recomputed here with the same
    /// scratch and the same operation order, so the selected set is
    /// bit-identical to the forward's.  The raw kernel weights are the
    /// gather coefficients (there is no normalising denominator in the
    /// forward — `total_weight` is observability, not part of the
    /// output), so no quotient-rule term appears.
    ///
    /// Ragged like the forward: `d_gathered` may be larger than `N x m`
    /// (only the prefix is read) and `d_queries` larger than `N x 8`
    /// (only the prefix is written).  Queries whose upstream gradient
    /// row is entirely zero — unmasked positions, the common case in a
    /// training batch — skip the pipeline outright.  Allocation-free
    /// per worker and sharded exactly like the forward dispatch;
    /// results are independent of the thread count.
    pub fn backward_gather_ragged_into(
        &self,
        queries: &[f64],
        table: &ValueTable,
        d_gathered: &[f32],
        d_queries: &mut [f64],
    ) {
        assert_eq!(queries.len() % 8, 0, "queries must be N x 8 row-major");
        let n = queries.len() / 8;
        let m = table.dim();
        assert!(
            d_gathered.len() >= n * m,
            "upstream gradient holds {} floats, batch needs {}",
            d_gathered.len(),
            n * m
        );
        assert!(
            d_queries.len() >= n * 8,
            "query-gradient output holds {} floats, batch needs {}",
            d_queries.len(),
            n * 8
        );
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let d_gathered = &d_gathered[..n * m];
        let d_queries = &mut d_queries[..n * 8];
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            let mut scratch = Scratch::new();
            backward_range(torus, k, queries, table, d_gathered, &mut scratch, d_queries);
            return;
        }
        let chunk = n.div_ceil(shards);
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let gs = d_gathered.chunks(chunk * m);
            let dqs = d_queries.chunks_mut(chunk * 8);
            for ((q, g), dq) in qs.zip(gs).zip(dqs) {
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    backward_range(torus, k, q, table, g, &mut scratch, dq);
                });
            }
        });
    }

    /// Shard the batch across workers (or run inline when one worker or
    /// one query makes threading pure overhead).
    fn dispatch(
        &self,
        queries: &[f64],
        out: &mut BatchOutput,
        table: Option<&ValueTable>,
        gathered: &mut [f32],
    ) {
        let n = queries.len() / 8;
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let m = table.map(ValueTable::dim).unwrap_or(0);
        // keep each shard worth more than its thread-spawn cost: small
        // batches run inline rather than fanning out for microseconds
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            let mut scratch = Scratch::new();
            run_range(
                torus,
                k,
                queries,
                &mut scratch,
                &mut out.indices,
                &mut out.weights,
                &mut out.total_weight,
                table,
                gathered,
            );
            return;
        }
        let chunk = n.div_ceil(shards);
        // per-shard windows of the gather output (empty when there is
        // no fused gather; `&mut []` is 'static by promotion)
        let mut gs: Vec<&mut [f32]> = Vec::with_capacity(shards);
        if m == 0 {
            gs.resize_with(shards, || &mut []);
        } else {
            gs.extend(gathered.chunks_mut(chunk * m));
        }
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let is = out.indices.chunks_mut(chunk * k);
            let ws = out.weights.chunks_mut(chunk * k);
            let ts = out.total_weight.chunks_mut(chunk);
            for ((((q, idx), wts), tot), g) in qs.zip(is).zip(ws).zip(ts).zip(gs) {
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    run_range(torus, k, q, &mut scratch, idx, wts, tot, table, g);
                });
            }
        });
    }

    /// [`Self::dispatch`] with the backward-cache capture: identical
    /// sharding and shard-size heuristics, the cache buffers sharded in
    /// lockstep with the output shards.
    fn dispatch_cached(
        &self,
        queries: &[f64],
        out: &mut BatchOutput,
        table: &ValueTable,
        gathered: &mut [f32],
        cache: &mut BackwardCache,
    ) {
        let n = queries.len() / 8;
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let m = table.dim();
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            let mut scratch = Scratch::new();
            run_range_cached(
                torus,
                k,
                queries,
                &mut scratch,
                &mut out.indices,
                &mut out.weights,
                &mut out.total_weight,
                table,
                gathered,
                &mut cache.d2,
                &mut cache.cand,
            );
            return;
        }
        let chunk = n.div_ceil(shards);
        // per-shard windows of the gather output (empty when the table
        // is zero-dim; `&mut []` is 'static by promotion)
        let mut gs: Vec<&mut [f32]> = Vec::with_capacity(shards);
        if m == 0 {
            gs.resize_with(shards, || &mut []);
        } else {
            gs.extend(gathered.chunks_mut(chunk * m));
        }
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let is = out.indices.chunks_mut(chunk * k);
            let ws = out.weights.chunks_mut(chunk * k);
            let ts = out.total_weight.chunks_mut(chunk);
            let d2s = cache.d2.chunks_mut(chunk * k);
            let cis = cache.cand.chunks_mut(chunk * k);
            for ((((((q, idx), wts), tot), g), d2), ci) in
                qs.zip(is).zip(ws).zip(ts).zip(gs).zip(d2s).zip(cis)
            {
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    run_range_cached(
                        torus, k, q, &mut scratch, idx, wts, tot, table, g, d2, ci,
                    );
                });
            }
        });
    }

    /// [`Self::dispatch`] for the f32 SIMD path: identical sharding and
    /// shard-size heuristics, per-worker [`ScratchF32`].
    fn dispatch_f32(
        &self,
        queries: &[f64],
        out: &mut BatchOutput,
        table: GatherTable<'_>,
        gathered: &mut [f32],
    ) {
        let n = queries.len() / 8;
        if n == 0 {
            return;
        }
        let k = self.k_top;
        let torus = self.torus;
        let m = table.dim();
        const MIN_QUERIES_PER_SHARD: usize = 32;
        let shards = self.n_threads.min(n.div_ceil(MIN_QUERIES_PER_SHARD));
        if shards <= 1 {
            let mut scratch = ScratchF32::new();
            run_range_f32(
                torus,
                k,
                queries,
                &mut scratch,
                &mut out.indices,
                &mut out.weights,
                &mut out.total_weight,
                table,
                gathered,
            );
            return;
        }
        let chunk = n.div_ceil(shards);
        let mut gs: Vec<&mut [f32]> = Vec::with_capacity(shards);
        if m == 0 {
            gs.resize_with(shards, || &mut []);
        } else {
            gs.extend(gathered.chunks_mut(chunk * m));
        }
        std::thread::scope(|s| {
            let qs = queries.chunks(chunk * 8);
            let is = out.indices.chunks_mut(chunk * k);
            let ws = out.weights.chunks_mut(chunk * k);
            let ts = out.total_weight.chunks_mut(chunk);
            for ((((q, idx), wts), tot), g) in qs.zip(is).zip(ws).zip(ts).zip(gs) {
                s.spawn(move || {
                    let mut scratch = ScratchF32::new();
                    run_range_f32(torus, k, q, &mut scratch, idx, wts, tot, table, g);
                });
            }
        });
    }
}

/// Process a contiguous query range into equally-shaped output shards.
#[allow(clippy::too_many_arguments)]
fn run_range(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    scratch: &mut Scratch,
    indices: &mut [u64],
    weights: &mut [f32],
    totals: &mut [f64],
    table: Option<&ValueTable>,
    gathered: &mut [f32],
) {
    let soa = neighbor_table_soa();
    let nbr = neighbor_table();
    let m = table.map(ValueTable::dim).unwrap_or(0);
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let idx_row = &mut indices[qi * k_top..(qi + 1) * k_top];
        let w_row = &mut weights[qi * k_top..(qi + 1) * k_top];
        totals[qi] = lookup_one(torus, k_top, soa, nbr, q, scratch, idx_row, w_row);
        if let Some(t) = table {
            t.gather_weighted(idx_row, w_row, &mut gathered[qi * m..(qi + 1) * m]);
        }
    }
}

/// [`run_range`] with the backward-cache capture: after each query's
/// selection, store the selected hits' `(d2, candidate)` pairs — read
/// straight from the scratch the selection already filled — into the
/// query's cache rows, padding with [`BackwardCache::NO_HIT`].  The
/// lookup and gather outputs are bit-identical to [`run_range`]'s.
#[allow(clippy::too_many_arguments)]
fn run_range_cached(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    scratch: &mut Scratch,
    indices: &mut [u64],
    weights: &mut [f32],
    totals: &mut [f64],
    table: &ValueTable,
    gathered: &mut [f32],
    cache_d2: &mut [f64],
    cache_cand: &mut [u32],
) {
    let soa = neighbor_table_soa();
    let nbr = neighbor_table();
    let m = table.dim();
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let idx_row = &mut indices[qi * k_top..(qi + 1) * k_top];
        let w_row = &mut weights[qi * k_top..(qi + 1) * k_top];
        totals[qi] = lookup_one(torus, k_top, soa, nbr, q, scratch, idx_row, w_row);
        // `lookup_one` leaves the selection in `scratch.sel` and the full
        // distance row in `scratch.d2`; capture the pairs the backward
        // needs, in selection order
        let d2_row = &mut cache_d2[qi * k_top..(qi + 1) * k_top];
        let ci_row = &mut cache_cand[qi * k_top..(qi + 1) * k_top];
        for (j, &(_w, _row, ci)) in scratch.sel.iter().enumerate() {
            d2_row[j] = scratch.d2[ci as usize];
            ci_row[j] = ci;
        }
        for j in scratch.sel.len()..k_top {
            d2_row[j] = 0.0;
            ci_row[j] = BackwardCache::NO_HIT;
        }
        table.gather_weighted(idx_row, w_row, &mut gathered[qi * m..(qi + 1) * m]);
    }
}

/// Candidate scoring shared by forward and backward: lane-major squared
/// distances into `scratch.d2`, in-support `(weight, candidate)` pairs
/// into `scratch.cand`; returns the total kernel weight.  Forward and
/// backward run the exact same operations in the same order here, so the
/// backward's recomputed selection is bit-identical to the forward's.
fn score_candidates(
    red: &Reduction,
    soa: &[[f64; N_NEIGHBORS]; 8],
    scratch: &mut Scratch,
) -> f64 {
    // Lane-major squared distances: eight contiguous FMA passes over the
    // 232-candidate row.  Accumulation order per candidate (lane 0..7)
    // matches the scalar path's unrolled sum, keeping d2 bit-identical.
    let d2 = &mut scratch.d2;
    // lane 0 initialises the accumulators, lanes 1..8 add — both arrays
    // are fixed [_; 8]s, so the split is bounds-check- and panic-free
    let (z0, z_rest) = (red.z[0], &red.z[1..]);
    let (lane0, lanes_rest) = (&soa[0], &soa[1..]);
    for (acc, &c) in d2.iter_mut().zip(lane0.iter()) {
        let d = z0 - c;
        *acc = d * d;
    }
    for (&zj, lane) in z_rest.iter().zip(lanes_rest.iter()) {
        for (acc, &c) in d2.iter_mut().zip(lane.iter()) {
            let d = zj - c;
            *acc += d * d;
        }
    }

    // Branchless kernel weights; only in-support candidates (d2 < 8,
    // i.e. w > 0) enter the selection.  `t^2 * t^2` is the same
    // operation order as `kernel_f`, so weights stay bit-identical, and
    // adding exact zeros leaves the total bit-identical to the scalar
    // path's in-support-only sum.
    scratch.cand.clear();
    let mut total = 0.0;
    for (ci, &d) in d2.iter().enumerate() {
        let t = (1.0 - d * 0.125).max(0.0);
        let t2 = t * t;
        let w = t2 * t2;
        total += w;
        if w > 0.0 {
            scratch.cand.push((w, ci as u32));
        }
    }
    total
}

/// Canonical top-k selection, shared by every lookup path: pick the
/// `k_top` largest weights, breaking exact weight ties by **ascending
/// torus row**, then ascending candidate index.  `cand` holds the
/// in-support `(weight, candidate)` pairs (consumed as selection
/// scratch); `sel` receives the ordered `(weight, row, candidate)`
/// selection.  Returns whether any exact weight tie participated in the
/// selection (inside it, or straddling the truncation boundary) — the
/// tie-frequency measurement ROADMAP asked for before considering tie
/// *smoothing*.
///
/// Equivalent, set and order, to sorting *all* in-support candidates by
/// `(weight desc, row asc, candidate asc)` and truncating to `k_top` —
/// the quickselect prefilter plus the boundary-weight re-inclusion below
/// just keep it O(n + k log k) in the common untied case.
pub(crate) fn select_canonical<S: Score>(
    torus: TorusK,
    red: &Reduction,
    nbr: &[[i64; 8]; N_NEIGHBORS],
    cand: &mut [(S, u32)],
    sel: &mut Vec<(S, u64, u32)>,
    k_top: usize,
) -> bool {
    sel.clear();
    let top_len = partial_top_k_desc(cand, k_top).len();
    if top_len == 0 {
        return false;
    }
    let boundary = cand[top_len - 1].0;
    let truncated = top_len < cand.len();
    let mut tied = cand[..top_len].windows(2).any(|p| p[0].0 == p[1].0);
    if !tied && truncated {
        tied = cand[top_len..].iter().any(|&(w, _)| w == boundary);
    }
    for &(w, ci) in &cand[..top_len] {
        sel.push((w, torus.index(&red.unmap(&nbr[ci as usize])), ci));
    }
    if tied && truncated {
        // the quickselect picked boundary-weight candidates by ascending
        // candidate index; the canonical rule wants ascending *row*, so
        // every boundary-weight candidate competes again under the full
        // order before the final truncation
        for &(w, ci) in &cand[top_len..] {
            if w == boundary {
                sel.push((w, torus.index(&red.unmap(&nbr[ci as usize])), ci));
            }
        }
    }
    sel.sort_unstable_by(|a, b| {
        desc_nan_last(a.0, b.0).then_with(|| a.1.cmp(&b.1)).then_with(|| a.2.cmp(&b.2))
    });
    sel.truncate(top_len);
    tied
}

/// One query through the fused pipeline; returns the total weight.
#[allow(clippy::too_many_arguments)]
fn lookup_one(
    torus: TorusK,
    k_top: usize,
    soa: &[[f64; N_NEIGHBORS]; 8],
    nbr: &[[i64; 8]; N_NEIGHBORS],
    q: &Vec8,
    scratch: &mut Scratch,
    idx_out: &mut [u64],
    w_out: &mut [f32],
) -> f64 {
    let red = reduce(q);
    let total = score_candidates(&red, soa, scratch);

    select_canonical(torus, &red, nbr, &mut scratch.cand, &mut scratch.sel, k_top);
    for (j, &(w, row, _ci)) in scratch.sel.iter().enumerate() {
        idx_out[j] = row;
        w_out[j] = w as f32;
    }
    for j in scratch.sel.len()..k_top {
        idx_out[j] = 0;
        w_out[j] = 0.0;
    }
    total
}

/// Process a contiguous query range through the f32 SIMD pipeline: f64
/// reduce (exact integer arithmetic dominates there), f32 SIMD scoring,
/// canonical selection, optional fused (de)quantizing gather.
#[allow(clippy::too_many_arguments)]
fn run_range_f32(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    scratch: &mut ScratchF32,
    indices: &mut [u64],
    weights: &mut [f32],
    totals: &mut [f64],
    table: GatherTable<'_>,
    gathered: &mut [f32],
) {
    let nbr = neighbor_table();
    let m = table.dim();
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let red = reduce(q);
        let mut z32 = [0.0f32; 8];
        for (o, &v) in z32.iter_mut().zip(red.z.iter()) {
            *o = v as f32;
        }
        totals[qi] = simd::score_row(&z32, &mut scratch.scores);
        scratch.cand.clear();
        for (ci, &w) in scratch.scores.0.iter().enumerate() {
            if w > 0.0 {
                scratch.cand.push((w, ci as u32));
            }
        }
        select_canonical(torus, &red, nbr, &mut scratch.cand, &mut scratch.sel, k_top);
        let idx_row = &mut indices[qi * k_top..(qi + 1) * k_top];
        let w_row = &mut weights[qi * k_top..(qi + 1) * k_top];
        for (j, &(w, row, _ci)) in scratch.sel.iter().enumerate() {
            idx_row[j] = row;
            w_row[j] = w;
        }
        for j in scratch.sel.len()..k_top {
            idx_row[j] = 0;
            w_row[j] = 0.0;
        }
        match table {
            GatherTable::None => {}
            GatherTable::F32(t) => {
                t.gather_weighted(idx_row, w_row, &mut gathered[qi * m..(qi + 1) * m]);
            }
            GatherTable::Q8(t) => {
                t.gather_weighted(idx_row, w_row, &mut gathered[qi * m..(qi + 1) * m]);
            }
        }
    }
}

/// The routing gradient for a contiguous query range (see
/// [`BatchLookupEngine::backward_gather_ragged_into`]): recompute the
/// forward's scoring + selection, then accumulate
/// `dL/dq = sum_j <dg, T[idx_j]> * f'(d2_j) * 2 (q - p_j)` over the
/// selected hits, with `p_j = unmap(c_j)` the original-frame lattice
/// point (`|q - p_j|^2 = d2_j` because the reduction is an isometry).
fn backward_range(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    table: &ValueTable,
    d_gathered: &[f32],
    scratch: &mut Scratch,
    d_queries: &mut [f64],
) {
    let soa = neighbor_table_soa();
    let nbr = neighbor_table();
    let m = table.dim();
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let dq = &mut d_queries[qi * 8..(qi + 1) * 8];
        dq.fill(0.0);
        let dg = &d_gathered[qi * m..(qi + 1) * m];
        // no-loss queries (unmasked positions) skip the whole pipeline
        if dg.iter().all(|&g| g == 0.0) {
            continue;
        }
        let red = reduce(q);
        score_candidates(&red, soa, scratch);
        select_canonical(torus, &red, nbr, &mut scratch.cand, &mut scratch.sel, k_top);
        for &(_w, row_idx, ci) in scratch.sel.iter() {
            let df = kernel_df_dd2(scratch.d2[ci as usize]);
            let u = red.unmap(&nbr[ci as usize]);
            let row = table.row(row_idx);
            let mut dldw = 0.0f64;
            for (&g, &r) in dg.iter().zip(row) {
                dldw += g as f64 * r as f64;
            }
            let coef = 2.0 * dldw * df;
            if coef == 0.0 {
                continue; // e.g. the hit's value row is all zeros
            }
            for (d, out) in dq.iter_mut().enumerate() {
                *out += coef * (q[d] - u[d] as f64);
            }
        }
    }
}

/// [`backward_range`] over a forward's captured selection: identical
/// per-hit arithmetic in the identical order — `df` from the *stored*
/// `d2` (the exact f64 the forward computed), the lattice point and
/// torus row from the recomputed reduction — so `d_queries` comes out
/// bit-identical to the recompute path's.
#[allow(clippy::too_many_arguments)]
fn backward_range_cached(
    torus: TorusK,
    k_top: usize,
    queries: &[f64],
    table: &ValueTable,
    d_gathered: &[f32],
    cache_d2: &[f64],
    cache_cand: &[u32],
    d_queries: &mut [f64],
) {
    let nbr = neighbor_table();
    let m = table.dim();
    for (qi, chunk) in queries.chunks_exact(8).enumerate() {
        let q = vec8(chunk);
        let dq = &mut d_queries[qi * 8..(qi + 1) * 8];
        dq.fill(0.0);
        let dg = &d_gathered[qi * m..(qi + 1) * m];
        // no-loss queries (unmasked positions) skip the whole pipeline
        if dg.iter().all(|&g| g == 0.0) {
            continue;
        }
        let red = reduce(q);
        let d2_row = &cache_d2[qi * k_top..(qi + 1) * k_top];
        let ci_row = &cache_cand[qi * k_top..(qi + 1) * k_top];
        for (&d2, &ci) in d2_row.iter().zip(ci_row) {
            if ci == BackwardCache::NO_HIT {
                break; // padding is a suffix of the selection
            }
            let df = kernel_df_dd2(d2);
            let u = red.unmap(&nbr[ci as usize]);
            let row_idx = torus.index(&u);
            let row = table.row(row_idx);
            let mut dldw = 0.0f64;
            for (&g, &r) in dg.iter().zip(row) {
                dldw += g as f64 * r as f64;
            }
            let coef = 2.0 * dldw * df;
            if coef == 0.0 {
                continue; // e.g. the hit's value row is all zeros
            }
            for (d, out) in dq.iter_mut().enumerate() {
                *out += coef * (q[d] - u[d] as f64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::kernel::TOTAL_WEIGHT_LOWER;
    use crate::lattice::LatticeLookup;
    use crate::util::rng::Rng;

    fn torus() -> TorusK {
        TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap()
    }

    fn random_queries(rng: &mut Rng, n: usize, span: f64) -> Vec<f64> {
        (0..n * 8).map(|_| rng.uniform(-span, span)).collect()
    }

    #[test]
    fn matches_scalar_oracle_bit_for_bit() {
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut oracle = LatticeLookup::new(torus(), 32);
        let mut rng = Rng::new(77);
        let queries = random_queries(&mut rng, 64, 9.0);
        let out = engine.lookup_batch(&queries);
        assert_eq!(out.queries(), 64);
        for qi in 0..64 {
            let q: Vec8 = queries[qi * 8..(qi + 1) * 8].try_into().unwrap();
            let want = oracle.lookup(&q);
            let (idx, wts) = out.query(qi);
            assert_eq!(out.total_weight[qi], want.total_weight, "query {qi}");
            for (j, hit) in want.hits.iter().enumerate() {
                assert_eq!(idx[j], hit.index, "query {qi} hit {j}");
                assert_eq!(wts[j], hit.weight as f32, "query {qi} hit {j}");
            }
            for j in want.hits.len()..32 {
                assert_eq!(idx[j], 0);
                assert_eq!(wts[j], 0.0);
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut rng = Rng::new(5);
        let queries = random_queries(&mut rng, 101, 12.0);
        let base = BatchLookupEngine::new(torus(), 32).lookup_batch(&queries);
        for threads in [2, 3, 8, 64] {
            let out =
                BatchLookupEngine::with_threads(torus(), 32, threads).lookup_batch(&queries);
            assert_eq!(out.indices, base.indices, "{threads} threads");
            assert_eq!(out.weights, base.weights, "{threads} threads");
            assert_eq!(out.total_weight, base.total_weight, "{threads} threads");
        }
    }

    #[test]
    fn total_weights_stay_in_paper_bounds() {
        let engine = BatchLookupEngine::with_threads(torus(), 32, 4);
        let mut rng = Rng::new(13);
        let queries = random_queries(&mut rng, 500, 10.0);
        let out = engine.lookup_batch(&queries);
        for &tw in &out.total_weight {
            assert!(tw >= TOTAL_WEIGHT_LOWER - 1e-9, "{tw}");
            assert!(tw <= 1.0 + 1e-9, "{tw}");
        }
    }

    #[test]
    fn fused_gather_equals_lookup_then_gather() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(21, 0.02);
        let engine = BatchLookupEngine::with_threads(torus(), 32, 3);
        let mut rng = Rng::new(99);
        let queries = random_queries(&mut rng, 40, 8.0);
        let mut lk = BatchOutput::default();
        let mut fused = vec![0.0f32; 40 * 16];
        engine.lookup_gather_into(&queries, &table, &mut lk, &mut fused);

        let plain = engine.lookup_batch(&queries);
        assert_eq!(lk.indices, plain.indices);
        assert_eq!(lk.weights, plain.weights);
        let mut expect = vec![0.0f32; 16];
        for qi in 0..40 {
            let (idx, wts) = plain.query(qi);
            table.gather_weighted(idx, wts, &mut expect);
            assert_eq!(&fused[qi * 16..(qi + 1) * 16], &expect[..], "query {qi}");
        }
    }

    #[test]
    fn ragged_gather_writes_prefix_only() {
        // serving keeps one max-batch buffer; a ragged final batch must
        // fill exactly its own rows and leave the tail untouched
        let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
        table.randomize(4, 0.1);
        let engine = BatchLookupEngine::new(torus(), 16);
        let mut rng = Rng::new(12);
        let queries = random_queries(&mut rng, 5, 7.0);
        let sentinel = 123.5f32;
        let mut ragged = vec![sentinel; 12 * 8]; // max batch 12, fill 5
        let mut lk = BatchOutput::default();
        engine.lookup_gather_ragged_into(&queries, &table, &mut lk, &mut ragged);
        assert_eq!(lk.queries(), 5);
        let mut exact = vec![0.0f32; 5 * 8];
        let mut lk2 = BatchOutput::default();
        engine.lookup_gather_into(&queries, &table, &mut lk2, &mut exact);
        assert_eq!(&ragged[..5 * 8], &exact[..]);
        assert!(ragged[5 * 8..].iter().all(|&v| v == sentinel), "tail overwritten");
    }

    #[test]
    fn empty_batch_and_reused_output() {
        let engine = BatchLookupEngine::new(torus(), 8);
        let mut out = BatchOutput::default();
        engine.lookup_batch_into(&[], &mut out);
        assert_eq!(out.queries(), 0);
        // shrink a previously larger buffer
        let mut rng = Rng::new(3);
        engine.lookup_batch_into(&random_queries(&mut rng, 10, 5.0), &mut out);
        assert_eq!(out.queries(), 10);
        engine.lookup_batch_into(&random_queries(&mut rng, 2, 5.0), &mut out);
        assert_eq!(out.queries(), 2);
        assert_eq!(out.indices.len(), 16);
    }

    /// `loss = <dg, gathered(q)>` — the scalar probe the backward's
    /// query gradient is checked against by central finite differences.
    fn probe_loss(
        engine: &BatchLookupEngine,
        table: &ValueTable,
        queries: &[f64],
        dg: &[f32],
        lk: &mut BatchOutput,
        gathered: &mut [f32],
    ) -> f64 {
        engine.lookup_gather_into(queries, table, lk, gathered);
        gathered.iter().zip(dg).map(|(&v, &g)| v as f64 * g as f64).sum()
    }

    #[test]
    fn backward_matches_finite_difference_of_the_fused_gather() {
        // k_top = 232 keeps every in-support candidate selected, so the
        // gather is a smooth function of the query (the kernel is C^3 at
        // the support boundary) and a central difference converges
        let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
        table.randomize(7, 0.5);
        let engine = BatchLookupEngine::new(torus(), N_NEIGHBORS);
        let mut rng = Rng::new(31);
        let n = 12;
        let queries = random_queries(&mut rng, n, 6.0);
        let dg: Vec<f32> = (0..n * 8).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut dq = vec![0.0f64; n * 8];
        engine.backward_gather_ragged_into(&queries, &table, &dg, &mut dq);

        let mut lk = BatchOutput::default();
        let mut gathered = vec![0.0f32; n * 8];
        // the forward gathers in f32, so the step must sit well above
        // the f32 rounding floor of the loss difference
        let h = 1e-3;
        let mut probe = queries.clone();
        for i in 0..n * 8 {
            probe[i] = queries[i] + h;
            let up = probe_loss(&engine, &table, &probe, &dg, &mut lk, &mut gathered);
            probe[i] = queries[i] - h;
            let down = probe_loss(&engine, &table, &probe, &dg, &mut lk, &mut gathered);
            probe[i] = queries[i];
            let fd = (up - down) / (2.0 * h);
            let tol = 1e-3 + 1e-2 * fd.abs().max(dq[i].abs());
            assert!(
                (fd - dq[i]).abs() <= tol,
                "lane {i}: analytic {} vs finite difference {fd}",
                dq[i]
            );
        }
    }

    #[test]
    fn backward_zero_upstream_gradient_writes_zeros_and_leaves_the_tail() {
        let mut table = ValueTable::zeros(1 << 18, 4).unwrap();
        table.randomize(3, 0.2);
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(8);
        let queries = random_queries(&mut rng, 5, 6.0);
        // ragged buffers (max batch 9, fill 5) prefilled with sentinels:
        // stale prefix values must be overwritten, the tail untouched
        let dg = vec![0.0f32; 9 * 4];
        let mut dq = vec![7.5f64; 9 * 8];
        engine.backward_gather_ragged_into(&queries, &table, &dg, &mut dq);
        assert!(dq[..5 * 8].iter().all(|&v| v == 0.0), "zero upstream must mean zero grad");
        assert!(dq[5 * 8..].iter().all(|&v| v == 7.5), "tail overwritten");
    }

    #[test]
    fn backward_thread_count_does_not_change_results() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(11, 0.1);
        let mut rng = Rng::new(40);
        let n = 101;
        let queries = random_queries(&mut rng, n, 10.0);
        let dg: Vec<f32> = (0..n * 16).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut base = vec![0.0f64; n * 8];
        BatchLookupEngine::new(torus(), 32)
            .backward_gather_ragged_into(&queries, &table, &dg, &mut base);
        for threads in [2, 3, 8] {
            let mut dq = vec![0.0f64; n * 8];
            BatchLookupEngine::with_threads(torus(), 32, threads)
                .backward_gather_ragged_into(&queries, &table, &dg, &mut dq);
            for (i, (a, b)) in dq.iter().zip(&base).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads, lane {i}");
            }
        }
    }

    #[test]
    fn cached_forward_is_bit_identical_to_the_uncached_path() {
        let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
        table.randomize(5, 0.3);
        let mut rng = Rng::new(90);
        let n = 67;
        let queries = random_queries(&mut rng, n, 9.0);
        for threads in [1, 3] {
            let engine = BatchLookupEngine::with_threads(torus(), 16, threads);
            let mut plain = BatchOutput::default();
            let mut plain_g = vec![0.0f32; n * 8];
            engine.lookup_gather_ragged_into(&queries, &table, &mut plain, &mut plain_g);
            let mut cached = BatchOutput::default();
            let mut cached_g = vec![0.0f32; n * 8];
            let mut cache = BackwardCache::default();
            engine.lookup_gather_ragged_cached_into(
                &queries,
                &table,
                &mut cached,
                &mut cached_g,
                &mut cache,
            );
            assert!(cache.matches(n, 16));
            assert_eq!(plain.indices, cached.indices, "{threads} threads");
            assert_eq!(plain.weights, cached.weights, "{threads} threads");
            for (a, b) in plain_g.iter().zip(&cached_g) {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads");
            }
        }
    }

    #[test]
    fn cached_backward_is_bit_identical_to_the_recompute_path() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(13, 0.2);
        let mut rng = Rng::new(91);
        let n = 53;
        let queries = random_queries(&mut rng, n, 10.0);
        // a training-shaped upstream gradient: most query rows zero
        // (unmasked positions), a few dense
        let mut dg = vec![0.0f32; n * 16];
        for qi in (0..n).step_by(3) {
            for v in dg[qi * 16..(qi + 1) * 16].iter_mut() {
                *v = rng.uniform(-1.0, 1.0) as f32;
            }
        }
        for threads in [1, 4] {
            let engine = BatchLookupEngine::with_threads(torus(), 24, threads);
            let mut lk = BatchOutput::default();
            let mut gathered = vec![0.0f32; n * 16];
            let mut cache = BackwardCache::default();
            engine.lookup_gather_ragged_cached_into(
                &queries,
                &table,
                &mut lk,
                &mut gathered,
                &mut cache,
            );
            let mut recomputed = vec![0.0f64; n * 8];
            engine.backward_gather_ragged_into(&queries, &table, &dg, &mut recomputed);
            let mut from_cache = vec![0.0f64; n * 8];
            engine.backward_gather_ragged_cached_into(
                &queries,
                &table,
                &dg,
                &cache,
                &mut from_cache,
            );
            for (i, (a, b)) in from_cache.iter().zip(&recomputed).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads, lane {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "backward cache is stale")]
    fn stale_backward_cache_panics_instead_of_misrouting_gradients() {
        let table = ValueTable::zeros(1 << 18, 4).unwrap();
        let engine = BatchLookupEngine::new(torus(), 8);
        let mut rng = Rng::new(92);
        let queries = random_queries(&mut rng, 3, 5.0);
        let mut cache = BackwardCache::default();
        {
            let mut lk = BatchOutput::default();
            let mut gathered = vec![0.0f32; 3 * 4];
            engine.lookup_gather_ragged_cached_into(
                &queries,
                &table,
                &mut lk,
                &mut gathered,
                &mut cache,
            );
        }
        cache.invalidate();
        let dg = vec![0.0f32; 3 * 4];
        let mut dq = vec![0.0f64; 3 * 8];
        engine.backward_gather_ragged_cached_into(&queries, &table, &dg, &cache, &mut dq);
    }

    #[test]
    fn lattice_point_queries_hit_themselves() {
        let engine = BatchLookupEngine::with_threads(torus(), 32, 2);
        let k = engine.torus;
        let ids = [0u64, 1, 1000, 12345];
        let mut queries = Vec::new();
        for &idx in &ids {
            let x = k.representative(idx);
            queries.extend(x.iter().map(|&v| v as f64));
        }
        let out = engine.lookup_batch(&queries);
        for (qi, &want) in ids.iter().enumerate() {
            let (idx, wts) = out.query(qi);
            assert_eq!(idx[0], want);
            assert!((wts[0] - 1.0).abs() < 1e-6);
            assert_eq!(wts[1], 0.0, "open-ball kernel: only the point itself");
        }
    }

    /// Queries with exact lattice symmetry (integer coordinates midway
    /// between shells) produce exactly-tied kernel weights by
    /// construction — e.g. `(1,1,0,...,0)` sits at `d2 = 2` from both
    /// the origin and `(2,2,0,...,0)`.
    fn symmetric_probes() -> Vec<f64> {
        let mut queries = Vec::new();
        for base in [
            [1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            [1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0],
            [1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0],
            [2.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            [0.5; 8],
            [3.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        ] {
            queries.extend(base);
        }
        queries
    }

    #[test]
    fn equal_weight_ties_order_by_ascending_row() {
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(55);
        let mut queries = random_queries(&mut rng, 100, 10.0);
        queries.extend(symmetric_probes());
        let out = engine.lookup_batch(&queries);
        let mut tie_runs = 0usize;
        for qi in 0..out.queries() {
            let (idx, wts) = out.query(qi);
            for j in 1..out.k_top() {
                if wts[j] == 0.0 {
                    break;
                }
                assert!(wts[j] <= wts[j - 1], "query {qi}: weights must descend");
                if wts[j] == wts[j - 1] {
                    tie_runs += 1;
                    assert!(
                        idx[j] >= idx[j - 1],
                        "query {qi} hit {j}: tied weights must order by \
                         ascending row ({} then {})",
                        idx[j - 1],
                        idx[j]
                    );
                }
            }
        }
        assert!(tie_runs > 0, "test vacuous: the symmetric probes produced no exact ties");
    }

    #[test]
    fn canonical_selection_equals_full_sort_reference() {
        // select_canonical's quickselect + boundary re-inclusion must be
        // indistinguishable from sorting *all* in-support candidates by
        // (weight desc, row asc, candidate asc) and truncating — the
        // boundary case matters exactly when a tie straddles k_top
        let k = torus();
        let soa = neighbor_table_soa();
        let nbr = neighbor_table();
        let mut rng = Rng::new(71);
        let mut queries = symmetric_probes();
        queries.extend(random_queries(&mut rng, 40, 9.0));
        let mut scratch = Scratch::new();
        for chunk in queries.chunks_exact(8) {
            let q = vec8(chunk);
            let red = reduce(q);
            for k_top in [1usize, 2, 3, 8, 32, N_NEIGHBORS] {
                score_candidates(&red, soa, &mut scratch);
                let mut reference: Vec<(f64, u64, u32)> = scratch
                    .cand
                    .iter()
                    .map(|&(w, ci)| (w, k.index(&red.unmap(&nbr[ci as usize])), ci))
                    .collect();
                reference.sort_by(|a, b| {
                    desc_nan_last(a.0, b.0)
                        .then_with(|| a.1.cmp(&b.1))
                        .then_with(|| a.2.cmp(&b.2))
                });
                reference.truncate(k_top);
                select_canonical(k, &red, nbr, &mut scratch.cand, &mut scratch.sel, k_top);
                assert_eq!(scratch.sel, reference, "k_top {k_top}");
            }
        }
    }

    #[test]
    fn measured_tie_frequency_under_training_shaped_config() {
        // ROADMAP "top-k tie smoothing: measure first" — quantify how
        // often the canonical tie-break actually engages under the
        // training-shaped torus/k_top before considering smoothing.
        // Continuous random queries essentially never tie in f64; the
        // rule exists for the lattice-symmetric queries integer-ish
        // features produce, so both populations are measured.
        let k = torus();
        let soa = neighbor_table_soa();
        let nbr = neighbor_table();
        let mut scratch = Scratch::new();
        let mut count = |queries: &[f64]| -> (usize, usize) {
            let mut tied = 0;
            let mut n = 0;
            for chunk in queries.chunks_exact(8) {
                let red = reduce(vec8(chunk));
                score_candidates(&red, soa, &mut scratch);
                if select_canonical(k, &red, nbr, &mut scratch.cand, &mut scratch.sel, 32) {
                    tied += 1;
                }
                n += 1;
            }
            (tied, n)
        };
        let mut rng = Rng::new(2024);
        let (rand_tied, rand_n) = count(&random_queries(&mut rng, 2000, 10.0));
        let (sym_tied, sym_n) = count(&symmetric_probes());
        println!(
            "tie-break engaged: random queries {rand_tied}/{rand_n} \
             ({:.3}%), symmetric probes {sym_tied}/{sym_n}",
            100.0 * rand_tied as f64 / rand_n as f64
        );
        assert_eq!(sym_tied, sym_n, "every symmetric probe must tie by construction");
        assert!(
            rand_tied * 10 <= rand_n,
            "random f64 queries tying {rand_tied}/{rand_n} of the time \
             suggests a scoring bug, not genuine symmetry"
        );
    }

    #[test]
    fn f32_path_tracks_the_f64_engine_within_tolerance() {
        // k_top = 232 keeps every in-support candidate, so hit sets can
        // only differ within f32 rounding of the d2 = 8 support boundary
        // — where weights are below the same tolerance
        let engine = BatchLookupEngine::new(torus(), N_NEIGHBORS);
        let mut rng = Rng::new(91);
        let queries = random_queries(&mut rng, 48, 9.0);
        let base = engine.lookup_batch(&queries);
        let fast = engine.lookup_batch_f32(&queries);
        let by_row = |o: &BatchOutput, qi: usize| -> std::collections::BTreeMap<u64, f32> {
            let (idx, wts) = o.query(qi);
            idx.iter().zip(wts).filter(|&(_, &w)| w > 0.0).map(|(&i, &w)| (i, w)).collect()
        };
        for qi in 0..48 {
            assert!(
                (fast.total_weight[qi] - base.total_weight[qi]).abs() < 1e-4,
                "query {qi}: totals {} vs {}",
                fast.total_weight[qi],
                base.total_weight[qi]
            );
            let b = by_row(&base, qi);
            let f = by_row(&fast, qi);
            for (row, &w) in &b {
                let fw = f.get(row).copied().unwrap_or(0.0);
                assert!((w - fw).abs() < 1e-4, "query {qi} row {row}: f64 {w} vs f32 {fw}");
            }
            for (row, &w) in &f {
                let bw = b.get(row).copied().unwrap_or(0.0);
                assert!((w - bw).abs() < 1e-4, "query {qi} row {row}: f32 {w} vs f64 {bw}");
            }
        }
    }

    #[test]
    fn f32_thread_count_does_not_change_results() {
        let mut rng = Rng::new(58);
        let queries = random_queries(&mut rng, 101, 12.0);
        let base = BatchLookupEngine::new(torus(), 32).lookup_batch_f32(&queries);
        for threads in [2, 3, 8] {
            let out = BatchLookupEngine::with_threads(torus(), 32, threads)
                .lookup_batch_f32(&queries);
            assert_eq!(out.indices, base.indices, "{threads} threads");
            assert_eq!(out.weights, base.weights, "{threads} threads");
            assert_eq!(out.total_weight, base.total_weight, "{threads} threads");
        }
    }

    #[test]
    fn fused_f32_gather_matches_f32_lookup_then_gather() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(21, 0.02);
        let engine = BatchLookupEngine::with_threads(torus(), 32, 3);
        let mut rng = Rng::new(99);
        let queries = random_queries(&mut rng, 40, 8.0);
        let mut lk = BatchOutput::default();
        let mut fused = vec![0.0f32; 40 * 16];
        engine.lookup_gather_ragged_f32_into(&queries, &table, &mut lk, &mut fused);

        let plain = engine.lookup_batch_f32(&queries);
        assert_eq!(lk.indices, plain.indices);
        assert_eq!(lk.weights, plain.weights);
        let mut expect = vec![0.0f32; 16];
        for qi in 0..40 {
            let (idx, wts) = plain.query(qi);
            table.gather_weighted(idx, wts, &mut expect);
            assert_eq!(&fused[qi * 16..(qi + 1) * 16], &expect[..], "query {qi}");
        }
    }

    #[test]
    fn q8_fused_gather_stays_within_quantisation_error() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(9, 0.02);
        let qt = QuantizedValueTable::from_table(&table).unwrap();
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(14);
        let queries = random_queries(&mut rng, 32, 8.0);
        let mut lk = BatchOutput::default();
        let mut f32g = vec![0.0f32; 32 * 16];
        engine.lookup_gather_ragged_f32_into(&queries, &table, &mut lk, &mut f32g);
        let mut lk2 = BatchOutput::default();
        let mut q8g = vec![0.0f32; 32 * 16];
        engine.lookup_gather_ragged_q8_into(&queries, &qt, &mut lk2, &mut q8g);
        // identical routing (indices/weights come from the same f32
        // scoring); only the gathered values carry quantisation error
        assert_eq!(lk.indices, lk2.indices);
        assert_eq!(lk.weights, lk2.weights);
        // per element: |err| <= sum_j w_j * scale_j / 2, with scale =
        // max_abs/127 and values ~N(0, 0.02) → comfortably under 1e-3
        for (i, (&a, &b)) in f32g.iter().zip(&q8g).enumerate() {
            assert!((a - b).abs() < 1e-3, "elem {i}: f32 {a} vs q8 {b}");
        }
    }

    #[test]
    fn shard_plan_partitions_rows_exactly_once() {
        // the quickcheck-style ownership-partition property: for any
        // (rows, n_shards), every row lies in exactly one shard's range
        // and owner_of names that shard
        let mut rng = Rng::new(9);
        let mut cases: Vec<(u64, usize)> = vec![(1, 1), (1, 4), (7, 8), (233, 3), (1024, 7)];
        for _ in 0..60 {
            let rows = rng.uniform(1.0, 1024.0) as u64;
            let shards = rng.uniform(1.0, 12.0) as usize;
            cases.push((rows.max(1), shards.max(1)));
        }
        for (rows, n_shards) in cases {
            let plan = ShardPlan::new(rows, n_shards);
            assert_eq!(plan.rows(), rows);
            assert_eq!(plan.n_shards(), n_shards);
            assert_eq!(plan.bounds()[0], 0);
            assert!(plan.bounds().windows(2).all(|p| p[0] <= p[1]));
            for row in 0..rows {
                let owners: Vec<usize> =
                    (0..n_shards).filter(|&s| plan.range(s).contains(&row)).collect();
                assert_eq!(owners.len(), 1, "row {row} of {rows} across {n_shards} shards");
                assert_eq!(plan.owner_of(row), owners[0], "row {row}");
            }
        }
    }

    #[test]
    fn shard_plan_round_trips_and_rejects_malformed_bounds() {
        let plan = ShardPlan::new(1000, 3);
        let again = ShardPlan::from_bounds(plan.bounds().to_vec()).unwrap();
        assert_eq!(plan, again);
        assert!(ShardPlan::from_bounds(vec![]).is_err());
        assert!(ShardPlan::from_bounds(vec![0]).is_err());
        assert!(ShardPlan::from_bounds(vec![5, 10]).is_err(), "must start at row 0");
        assert!(ShardPlan::from_bounds(vec![0, 7, 3]).is_err(), "must be non-decreasing");
    }

    /// Compact per-shard copies of `table` (row `r` of shard `s` holds
    /// global row `plan.range(s).start + r`).
    fn shard_tables(table: &ValueTable, plan: &ShardPlan) -> Vec<ValueTable> {
        (0..plan.n_shards())
            .map(|s| {
                let r = plan.range(s);
                let rows = (r.end - r.start).max(1); // zeros() rejects 0
                let mut t = ValueTable::zeros(rows, table.dim()).unwrap();
                for (local, global) in r.enumerate() {
                    t.row_mut(local as u64).copy_from_slice(table.row(global));
                }
                t
            })
            .collect()
    }

    /// Drive the full staged pipeline (score in two query-contiguous
    /// parts → per-shard select → merge → per-shard stage → combine)
    /// and return `(merged, gathered)`.
    fn run_staged_f64(
        engine: &BatchLookupEngine,
        queries: &[f64],
        plan: &ShardPlan,
        tables: &[ValueTable],
        m: usize,
    ) -> (BatchOutput, Vec<f32>) {
        let n = queries.len() / 8;
        let split = (n / 2) * 8;
        let mut parts = vec![ScoredBatch::default(), ScoredBatch::default()];
        engine.score_into(&queries[..split], &mut parts[0]);
        engine.score_into(&queries[split..], &mut parts[1]);
        let mut sels = vec![ShardSelection::default(); plan.n_shards()];
        for (s, sel) in sels.iter_mut().enumerate() {
            engine.select_owned(&parts, plan, s, sel);
        }
        let mut merged = BatchOutput::default();
        engine.merge_into(&parts, &sels, &mut merged);
        let mut stages = vec![GatherStage::default(); plan.n_shards()];
        for (s, st) in stages.iter_mut().enumerate() {
            engine.stage_gather(&merged, plan, s, plan.range(s).start, &tables[s], st);
        }
        let mut gathered = vec![0.0f32; n * m];
        engine.combine_gather(&merged, plan, &stages, &mut gathered);
        (merged, gathered)
    }

    #[test]
    fn staged_pipeline_is_bit_identical_to_fused_f64() {
        // the tentpole contract: for every shard count, the staged
        // score/select/merge/gather pipeline reproduces the fused
        // lookup→gather bit-for-bit — including the symmetric tie
        // probes, whose equal weights exercise the canonical order at
        // the merge boundary
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(21, 0.02);
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(123);
        let mut queries = random_queries(&mut rng, 37, 9.0);
        queries.extend(symmetric_probes());
        let n = queries.len() / 8;

        let mut fused = BatchOutput::default();
        let mut fused_g = vec![0.0f32; n * 16];
        engine.lookup_gather_ragged_into(&queries, &table, &mut fused, &mut fused_g);

        for shards in [1usize, 2, 3, 4, 7] {
            let plan = ShardPlan::new(table.rows(), shards);
            let tables = shard_tables(&table, &plan);
            let (merged, gathered) = run_staged_f64(&engine, &queries, &plan, &tables, 16);
            assert_eq!(merged.indices, fused.indices, "{shards} shards");
            assert_eq!(merged.weights, fused.weights, "{shards} shards");
            assert_eq!(merged.total_weight, fused.total_weight, "{shards} shards");
            assert_eq!(gathered, fused_g, "{shards} shards");
        }
    }

    #[test]
    fn staged_pipeline_is_bit_identical_to_fused_f32() {
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(21, 0.02);
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(321);
        let mut queries = random_queries(&mut rng, 33, 9.0);
        queries.extend(symmetric_probes());
        let n = queries.len() / 8;

        let mut fused = BatchOutput::default();
        let mut fused_g = vec![0.0f32; n * 16];
        engine.lookup_gather_ragged_f32_into(&queries, &table, &mut fused, &mut fused_g);

        for shards in [1usize, 2, 4, 7] {
            let plan = ShardPlan::new(table.rows(), shards);
            let tables = shard_tables(&table, &plan);
            let split = (n / 2) * 8;
            let mut parts = vec![ScoredBatch::default(), ScoredBatch::default()];
            engine.score_f32_into(&queries[..split], &mut parts[0]);
            engine.score_f32_into(&queries[split..], &mut parts[1]);
            let mut sels = vec![ShardSelection::default(); shards];
            for (s, sel) in sels.iter_mut().enumerate() {
                engine.select_owned(&parts, &plan, s, sel);
            }
            let mut merged = BatchOutput::default();
            engine.merge_into(&parts, &sels, &mut merged);
            assert_eq!(merged.indices, fused.indices, "{shards} shards");
            assert_eq!(merged.weights, fused.weights, "{shards} shards");
            assert_eq!(merged.total_weight, fused.total_weight, "{shards} shards");
            let mut stages = vec![GatherStage::default(); shards];
            for (s, st) in stages.iter_mut().enumerate() {
                engine.stage_gather(&merged, &plan, s, plan.range(s).start, &tables[s], st);
            }
            let mut gathered = vec![0.0f32; n * 16];
            engine.combine_gather(&merged, &plan, &stages, &mut gathered);
            assert_eq!(gathered, fused_g, "{shards} shards");
        }
    }

    #[test]
    fn staged_q8_gather_is_bit_identical_to_fused_q8() {
        // per-row quantization is local to the row, so compact shard
        // tables quantize to the same codes/scales as the full table,
        // and the combine replays the fused axpy_q8 kernel exactly
        let mut table = ValueTable::zeros(1 << 18, 16).unwrap();
        table.randomize(9, 0.02);
        let qt = QuantizedValueTable::from_table(&table).unwrap();
        let engine = BatchLookupEngine::new(torus(), 32);
        let mut rng = Rng::new(14);
        let queries = random_queries(&mut rng, 24, 8.0);
        let n = 24;

        let mut fused = BatchOutput::default();
        let mut fused_g = vec![0.0f32; n * 16];
        engine.lookup_gather_ragged_q8_into(&queries, &qt, &mut fused, &mut fused_g);

        for shards in [2usize, 5] {
            let plan = ShardPlan::new(table.rows(), shards);
            let qtables: Vec<QuantizedValueTable> = shard_tables(&table, &plan)
                .iter()
                .map(|t| QuantizedValueTable::from_table(t).unwrap())
                .collect();
            let split = (n / 2) * 8;
            let mut parts = vec![ScoredBatch::default(), ScoredBatch::default()];
            engine.score_f32_into(&queries[..split], &mut parts[0]);
            engine.score_f32_into(&queries[split..], &mut parts[1]);
            let mut sels = vec![ShardSelection::default(); shards];
            for (s, sel) in sels.iter_mut().enumerate() {
                engine.select_owned(&parts, &plan, s, sel);
            }
            let mut merged = BatchOutput::default();
            engine.merge_into(&parts, &sels, &mut merged);
            assert_eq!(merged.indices, fused.indices, "{shards} shards");
            assert_eq!(merged.weights, fused.weights, "{shards} shards");
            let mut stages = vec![GatherStage::default(); shards];
            for (s, st) in stages.iter_mut().enumerate() {
                engine.stage_gather_q8(&merged, &plan, s, plan.range(s).start, &qtables[s], st);
            }
            let mut gathered = vec![0.0f32; n * 16];
            engine.combine_gather(&merged, &plan, &stages, &mut gathered);
            assert_eq!(gathered, fused_g, "{shards} shards");
        }
    }

    #[test]
    fn staged_pipeline_handles_empty_and_ragged_batches() {
        let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
        table.randomize(4, 0.1);
        let engine = BatchLookupEngine::new(torus(), 16);
        let plan = ShardPlan::new(table.rows(), 3);
        let tables = shard_tables(&table, &plan);
        // empty batch: every stage degrades to zero queries
        let (merged, gathered) = run_staged_f64(&engine, &[], &plan, &tables, 8);
        assert_eq!(merged.queries(), 0);
        assert!(gathered.is_empty());
        // ragged gather output: only the first N x m elements written
        let mut rng = Rng::new(12);
        let queries = random_queries(&mut rng, 5, 7.0);
        let (merged, _) = run_staged_f64(&engine, &queries, &plan, &tables, 8);
        let sentinel = 123.5f32;
        let mut ragged = vec![sentinel; 12 * 8];
        let mut stages = vec![GatherStage::default(); 3];
        for (s, st) in stages.iter_mut().enumerate() {
            engine.stage_gather(&merged, &plan, s, plan.range(s).start, &tables[s], st);
        }
        engine.combine_gather(&merged, &plan, &stages, &mut ragged);
        let mut exact = BatchOutput::default();
        let mut want = vec![0.0f32; 5 * 8];
        engine.lookup_gather_ragged_into(&queries, &table, &mut exact, &mut want);
        assert_eq!(&ragged[..5 * 8], &want[..]);
        assert!(ragged[5 * 8..].iter().all(|&v| v == sentinel), "tail overwritten");
    }

    #[test]
    fn f32_nan_and_empty_inputs_degrade_cleanly() {
        let engine = BatchLookupEngine::new(torus(), 8);
        let mut out = BatchOutput::default();
        engine.lookup_batch_f32_into(&[], &mut out);
        assert_eq!(out.queries(), 0);
        let mut q = [0.5f64; 16];
        q[3] = f64::NAN;
        engine.lookup_batch_f32_into(&q, &mut out);
        assert_eq!(out.queries(), 2);
        // the NaN query yields no hits and zero total, like the oracle
        let (idx, wts) = out.query(0);
        assert!(idx.iter().all(|&i| i == 0));
        assert!(wts.iter().all(|&w| w == 0.0));
        assert_eq!(out.total_weight[0], 0.0);
        // the clean query is unaffected
        let (_, wts1) = out.query(1);
        assert!(wts1[0] > 0.0);
        assert!(out.total_weight[1] > TOTAL_WEIGHT_LOWER - 1e-9);
    }
}
