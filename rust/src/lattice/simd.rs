//! f32 SIMD scoring kernels for the serving fast path.
//!
//! The f64 engine in [`crate::lattice::batch`] is the training oracle;
//! serving does not need f64: weights are published as f32 anyway, and
//! the kernel `f(d2) = max(0, 1 - d2/8)^4` is smooth enough that f32
//! scoring stays within ~1e-6 of the oracle (see
//! `rust/tests/numeric_differential.rs` for the enforced bounds).
//! Halving the element width doubles the useful SIMD lane count, and the
//! hand-written kernels below score the full 232-candidate row in 29
//! AVX2 blocks (or 58 NEON blocks) without the bounds checks and strided
//! loads the autovectorizer trips over.
//!
//! Dispatch is resolved once per process at runtime:
//!
//! * x86_64 with AVX2+FMA → [`score_row_avx2`] (aligned 8-lane blocks),
//! * aarch64 → NEON (baseline feature, always available),
//! * anything else, or `LRAM_SIMD=off` in the environment → the scalar
//!   f32 fallback, which computes the same quantities lane by lane.
//!
//! The 232-wide score row lives in [`AlignedScores`] (32-byte aligned;
//! `232 * 4 = 928` bytes is a multiple of 32, so the per-lane rows of
//! the SoA candidate table stay aligned too).  `axpy_f32` / `axpy_q8`
//! are the matching gather primitives: fused weighted row accumulation
//! for f32 and int8-quantized value tables.

use std::sync::OnceLock;

use super::neighbors::{neighbor_table, N_NEIGHBORS};

/// The 232-wide kernel-weight row, 32-byte aligned so AVX2 can use
/// aligned loads/stores on every 8-lane block.
#[repr(C, align(32))]
pub struct AlignedScores(pub [f32; N_NEIGHBORS]);

impl AlignedScores {
    pub fn new() -> Self {
        AlignedScores([0.0; N_NEIGHBORS])
    }
}

impl Default for AlignedScores {
    fn default() -> Self {
        Self::new()
    }
}

/// f32 structure-of-arrays candidate table: `soa[lane][candidate]`,
/// mirroring [`crate::lattice::neighbors::neighbor_table_soa`] at half
/// width.  Each lane row is 928 bytes (29 x 32), so with the struct
/// 32-byte aligned every row starts on a 32-byte boundary.
#[repr(C, align(32))]
struct Soa32([[f32; N_NEIGHBORS]; 8]);

fn soa_f32() -> &'static Soa32 {
    static SOA: OnceLock<Box<Soa32>> = OnceLock::new();
    SOA.get_or_init(|| {
        let nbr = neighbor_table();
        let mut soa = Box::new(Soa32([[0.0; N_NEIGHBORS]; 8]));
        for (ci, c) in nbr.iter().enumerate() {
            for (lane, &v) in c.iter().enumerate() {
                soa.0[lane][ci] = v as f32;
            }
        }
        soa
    })
}

/// Which kernel implementation serving resolved to (one decision per
/// process; `LRAM_SIMD=off` forces `Scalar` for differential testing).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dispatch {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Dispatch {
    /// Human-readable kernel name (bench reports and serve logs).
    pub fn name(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar-f32",
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => "avx2+fma",
            #[cfg(target_arch = "aarch64")]
            Dispatch::Neon => "neon",
        }
    }
}

/// The process-wide dispatch decision (runtime feature detection, made
/// once and cached; set `LRAM_SIMD=off` before first use to force the
/// scalar fallback).
pub fn dispatch() -> Dispatch {
    static DISPATCH: OnceLock<Dispatch> = OnceLock::new();
    *DISPATCH.get_or_init(|| {
        if std::env::var("LRAM_SIMD").as_deref() == Ok("off") {
            return Dispatch::Scalar;
        }
        detect_arch()
    })
}

/// Name of the active kernel (convenience for logs and benches).
pub fn active_kernel_name() -> &'static str {
    dispatch().name()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Dispatch {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Dispatch::Avx2
    } else {
        Dispatch::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Dispatch {
    // NEON is a baseline feature of the aarch64 ABI.
    Dispatch::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Dispatch {
    Dispatch::Scalar
}

/// Score all 232 candidates against the reduced query `z` (f32 copy of
/// `Reduction::z`): writes `f(d2_ci)` per candidate into `out` (zero
/// outside the support) and returns the total weight as f64 (sum of the
/// f32 per-candidate weights).
pub fn score_row(z: &[f32; 8], out: &mut AlignedScores) -> f64 {
    match dispatch() {
        Dispatch::Scalar => score_row_scalar(z, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 variant is only constructed after runtime
        // detection confirmed both avx2 and fma on this CPU.
        Dispatch::Avx2 => unsafe { score_row_avx2(z, out) },
        #[cfg(target_arch = "aarch64")]
        Dispatch::Neon => score_row_neon(z, out),
    }
}

/// Scalar f32 reference for [`score_row`]: same lane-major accumulation
/// and branchless kernel, one candidate at a time.  Always available —
/// this is both the non-SIMD fallback and the `LRAM_SIMD=off` kernel
/// the differential suite pins against.
fn score_row_scalar(z: &[f32; 8], out: &mut AlignedScores) -> f64 {
    let soa = &soa_f32().0;
    for (d, &c) in out.0.iter_mut().zip(&soa[0]) {
        let t = z[0] - c;
        *d = t * t;
    }
    for (&zl, row) in z.iter().zip(soa.iter()).skip(1) {
        for (d, &c) in out.0.iter_mut().zip(row) {
            let t = zl - c;
            *d += t * t;
        }
    }
    let mut total = 0.0f64;
    for w in out.0.iter_mut() {
        let t = (1.0f32 - *w * 0.125).max(0.0);
        let t2 = t * t;
        let w4 = t2 * t2;
        *w = w4;
        total += w4 as f64;
    }
    total
}

/// AVX2+FMA kernel: 29 blocks of 8 candidates, aligned loads from the
/// f32 SoA table, fused multiply-adds for the distance accumulation and
/// the branchless `max(0, 1 - d2/8)^4` evaluation.
///
/// # Safety
///
/// The caller must have verified at runtime that the CPU supports both
/// `avx2` and `fma` (see [`dispatch`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn score_row_avx2(z: &[f32; 8], out: &mut AlignedScores) -> f64 {
    use std::arch::x86_64::*;
    let soa = &soa_f32().0;
    let zs = [
        _mm256_set1_ps(z[0]),
        _mm256_set1_ps(z[1]),
        _mm256_set1_ps(z[2]),
        _mm256_set1_ps(z[3]),
        _mm256_set1_ps(z[4]),
        _mm256_set1_ps(z[5]),
        _mm256_set1_ps(z[6]),
        _mm256_set1_ps(z[7]),
    ];
    let one = _mm256_set1_ps(1.0);
    let eighth = _mm256_set1_ps(0.125);
    let zero = _mm256_setzero_ps();
    let mut total = _mm256_setzero_ps();
    for blk in 0..N_NEIGHBORS / 8 {
        let off = blk * 8;
        let c0 = _mm256_load_ps(soa[0].as_ptr().add(off));
        let t0 = _mm256_sub_ps(zs[0], c0);
        let mut d2 = _mm256_mul_ps(t0, t0);
        for (zv, row) in zs.iter().zip(soa.iter()).skip(1) {
            let c = _mm256_load_ps(row.as_ptr().add(off));
            let t = _mm256_sub_ps(*zv, c);
            d2 = _mm256_fmadd_ps(t, t, d2);
        }
        // t = max(0, 1 - d2/8); w = t^4 = (t^2)^2
        let t = _mm256_max_ps(_mm256_fnmadd_ps(d2, eighth, one), zero);
        let t2 = _mm256_mul_ps(t, t);
        let w = _mm256_mul_ps(t2, t2);
        _mm256_store_ps(out.0.as_mut_ptr().add(off), w);
        total = _mm256_add_ps(total, w);
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), total);
    lanes.iter().map(|&v| v as f64).sum()
}

/// NEON kernel: 58 blocks of 4 candidates.  NEON is baseline on
/// aarch64, so this is a plain safe function with one unsafe region for
/// the intrinsics.
#[cfg(target_arch = "aarch64")]
fn score_row_neon(z: &[f32; 8], out: &mut AlignedScores) -> f64 {
    use std::arch::aarch64::*;
    let soa = &soa_f32().0;
    // SAFETY: NEON is a mandatory aarch64 target feature; all pointer
    // arithmetic stays inside the fixed-size SoA rows and the 232-wide
    // output row (58 * 4 == 232 exactly).
    unsafe {
        let one = vdupq_n_f32(1.0);
        let eighth = vdupq_n_f32(0.125);
        let zero = vdupq_n_f32(0.0);
        let mut total = 0.0f64;
        for blk in 0..N_NEIGHBORS / 4 {
            let off = blk * 4;
            let c0 = vld1q_f32(soa[0].as_ptr().add(off));
            let t0 = vsubq_f32(vdupq_n_f32(z[0]), c0);
            let mut d2 = vmulq_f32(t0, t0);
            for (&zl, row) in z.iter().zip(soa.iter()).skip(1) {
                let c = vld1q_f32(row.as_ptr().add(off));
                let t = vsubq_f32(vdupq_n_f32(zl), c);
                d2 = vfmaq_f32(d2, t, t);
            }
            let t = vmaxq_f32(vfmsq_f32(one, d2, eighth), zero);
            let t2 = vmulq_f32(t, t);
            // NEON vmaxq propagates NaN (unlike x86 maxps, whose NaN
            // rule already yields 0 above): gate on d2 < 8 explicitly so
            // NaN queries score 0, matching the f64 oracle
            let support = vcltq_f32(d2, vdupq_n_f32(8.0));
            let w = vbslq_f32(support, vmulq_f32(t2, t2), zero);
            vst1q_f32(out.0.as_mut_ptr().add(off), w);
            total += vaddvq_f32(w) as f64;
        }
        total
    }
}

/// `acc += w * row`, element-wise over `min(row.len(), acc.len())`
/// elements (callers pass equal lengths; the min is belt-and-braces
/// against slicing bugs, not an API feature).
pub fn axpy_f32(w: f32, row: &[f32], acc: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if dispatch() == Dispatch::Avx2 {
        // SAFETY: Avx2 dispatch implies runtime-verified avx2+fma.
        unsafe { axpy_f32_avx2(w, row, acc) };
        return;
    }
    axpy_f32_scalar(w, row, acc);
}

fn axpy_f32_scalar(w: f32, row: &[f32], acc: &mut [f32]) {
    for (a, &v) in acc.iter_mut().zip(row) {
        *a += w * v;
    }
}

/// # Safety
///
/// Requires runtime-verified `avx2` and `fma` support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_f32_avx2(w: f32, row: &[f32], acc: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = row.len().min(acc.len());
    let wv = _mm256_set1_ps(w);
    let mut i = 0;
    while i + 8 <= n {
        let r = _mm256_loadu_ps(row.as_ptr().add(i));
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_fmadd_ps(wv, r, a));
        i += 8;
    }
    axpy_f32_scalar(w, &row[i..n], &mut acc[i..n]);
}

/// `acc += w_times_scale * dequant(qrow)`: the int8 gather primitive.
/// The caller folds the per-row quantisation scale into the weight, so
/// dequantisation is a single fused multiply-add per element.
pub fn axpy_q8(w_times_scale: f32, qrow: &[i8], acc: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if dispatch() == Dispatch::Avx2 {
        // SAFETY: Avx2 dispatch implies runtime-verified avx2+fma.
        unsafe { axpy_q8_avx2(w_times_scale, qrow, acc) };
        return;
    }
    axpy_q8_scalar(w_times_scale, qrow, acc);
}

fn axpy_q8_scalar(ws: f32, qrow: &[i8], acc: &mut [f32]) {
    for (a, &q) in acc.iter_mut().zip(qrow) {
        *a += ws * q as f32;
    }
}

/// # Safety
///
/// Requires runtime-verified `avx2` and `fma` support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_q8_avx2(ws: f32, qrow: &[i8], acc: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = qrow.len().min(acc.len());
    let wv = _mm256_set1_ps(ws);
    let mut i = 0;
    while i + 8 <= n {
        // widen 8 x i8 -> 8 x i32 -> 8 x f32, then one fused axpy
        let q = _mm_loadl_epi64(qrow.as_ptr().add(i) as *const __m128i);
        let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), _mm256_fmadd_ps(wv, qf, a));
        i += 8;
    }
    axpy_q8_scalar(ws, &qrow[i..n], &mut acc[i..n]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::e8::reduce;
    use crate::lattice::kernel::kernel_f;
    use crate::lattice::neighbors::neighbor_table_f64;
    use crate::util::check::forall;

    fn f32_z(q: &[f64; 8]) -> ([f32; 8], [f64; 8]) {
        let red = reduce(q);
        let mut zf = [0.0f32; 8];
        for (o, &v) in zf.iter_mut().zip(red.z.iter()) {
            *o = v as f32;
        }
        (zf, red.z)
    }

    #[test]
    fn active_dispatch_matches_f64_reference_within_tolerance() {
        let nbrf = neighbor_table_f64();
        forall(60, |rng| {
            let mut q = [0.0f64; 8];
            for v in q.iter_mut() {
                *v = (rng.f64() - 0.5) * 20.0;
            }
            let (zf, z64) = f32_z(&q);
            let mut out = AlignedScores::new();
            let total = score_row(&zf, &mut out);
            let mut want_total = 0.0f64;
            for (ci, c) in nbrf.iter().enumerate() {
                let mut d2 = 0.0f64;
                for (zl, cl) in z64.iter().zip(c) {
                    let t = zl - cl;
                    d2 += t * t;
                }
                let want = kernel_f(d2);
                want_total += want;
                let got = out.0[ci] as f64;
                assert!(
                    (got - want).abs() < 2e-5,
                    "candidate {ci}: got {got}, want {want}"
                );
            }
            assert!(
                (total - want_total).abs() < 1e-3,
                "total: got {total}, want {want_total}"
            );
        });
    }

    #[test]
    fn active_dispatch_stays_close_to_scalar_f32() {
        forall(60, |rng| {
            let mut q = [0.0f64; 8];
            for v in q.iter_mut() {
                *v = (rng.f64() - 0.5) * 12.0;
            }
            let (zf, _) = f32_z(&q);
            let mut active = AlignedScores::new();
            let mut scalar = AlignedScores::new();
            let ta = score_row(&zf, &mut active);
            let ts = score_row_scalar(&zf, &mut scalar);
            for (ci, (&a, &s)) in active.0.iter().zip(scalar.0.iter()).enumerate() {
                assert!((a - s).abs() < 1e-5, "candidate {ci}: {a} vs {s}");
            }
            assert!((ta - ts).abs() < 1e-4, "totals {ta} vs {ts}");
        });
    }

    #[test]
    fn lattice_point_scores_exactly_one_at_the_origin() {
        // z = 0 (a lattice point): d2 = 0 at the origin candidate, so
        // its weight is exactly 1.0 in every dispatch (fma of zeros is
        // exact), and the total is at least 1.
        let origin_ci = neighbor_table()
            .iter()
            .position(|c| c.iter().all(|&v| v == 0))
            .unwrap();
        let mut out = AlignedScores::new();
        let total = score_row(&[0.0; 8], &mut out);
        assert_eq!(out.0[origin_ci], 1.0);
        assert!(total >= 1.0);
        let mut scalar = AlignedScores::new();
        score_row_scalar(&[0.0; 8], &mut scalar);
        assert_eq!(scalar.0[origin_ci], 1.0);
    }

    #[test]
    fn axpy_f32_matches_scalar_reference() {
        forall(40, |rng| {
            let n = 1 + rng.below(70) as usize;
            let w = (rng.f64() - 0.5) as f32;
            let row: Vec<f32> = (0..n).map(|_| (rng.f64() - 0.5) as f32 * 4.0).collect();
            let mut acc: Vec<f32> = (0..n).map(|_| (rng.f64() - 0.5) as f32).collect();
            let mut want = acc.clone();
            axpy_f32_scalar(w, &row, &mut want);
            axpy_f32(w, &row, &mut acc);
            for (i, (&a, &b)) in acc.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-5, "elem {i}: {a} vs {b}");
            }
        });
    }

    #[test]
    fn axpy_q8_matches_scalar_reference() {
        forall(40, |rng| {
            let n = 1 + rng.below(70) as usize;
            let ws = (rng.f64() - 0.5) as f32 * 0.1;
            let qrow: Vec<i8> = (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let mut acc: Vec<f32> = (0..n).map(|_| (rng.f64() - 0.5) as f32).collect();
            let mut want = acc.clone();
            axpy_q8_scalar(ws, &qrow, &mut want);
            axpy_q8(ws, &qrow, &mut acc);
            for (i, (&a, &b)) in acc.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-5, "elem {i}: {a} vs {b}");
            }
        });
    }

    #[test]
    fn dispatch_is_stable_and_named() {
        assert_eq!(dispatch(), dispatch());
        assert!(!active_kernel_name().is_empty());
    }
}
