//! Monte-Carlo kernel-support statistics (Table 1).
//!
//! For Z^8 and E8 we sample uniform queries and count lattice points in
//! the open kernel ball (radius sqrt(2) * covering radius, in each
//! lattice's unimodular scale); the averages are also available
//! analytically (`exotic::LatticeInfo::avg_kernel_support`), which the
//! paper uses for the 12/16/24-dimensional lattices.

use crate::util::rng::Rng;

use super::e8::{reduce, Vec8};
use super::kernel::kernel_f;
use super::neighbors::neighbor_table;
use super::zn;

/// min / mean / max kernel-support counts over `samples` random queries.
#[derive(Debug, Clone, Copy)]
pub struct SupportStats {
    pub min: usize,
    pub mean: f64,
    pub max: usize,
    pub samples: u64,
}

/// E8 (as Lambda = 2*E8; the count is scale-invariant): number of lattice
/// points within the kernel radius sqrt(8).
pub fn e8_support_count(q: &Vec8) -> usize {
    let red = reduce(q);
    let mut count = 0;
    for c in neighbor_table().iter() {
        let mut d2 = 0.0;
        for j in 0..8 {
            let d = red.z[j] - c[j] as f64;
            d2 += d * d;
        }
        if d2 < 8.0 {
            count += 1;
        }
    }
    count
}

/// Monte-Carlo sweep for E8.
pub fn e8_support_stats(samples: u64, seed: u64) -> SupportStats {
    let mut rng = Rng::new(seed);
    let (mut lo, mut hi, mut sum) = (usize::MAX, 0usize, 0u64);
    for _ in 0..samples {
        // uniform over one fundamental cube of the (scaled) lattice
        let q: Vec8 = std::array::from_fn(|_| rng.uniform(0.0, 8.0));
        let c = e8_support_count(&q);
        lo = lo.min(c);
        hi = hi.max(c);
        sum += c as u64;
    }
    SupportStats { min: lo, mean: sum as f64 / samples as f64, max: hi, samples }
}

/// Monte-Carlo sweep for Z^8 (kernel radius 2 in the unimodular scale).
pub fn z8_support_stats(samples: u64, seed: u64) -> SupportStats {
    let mut rng = Rng::new(seed);
    let (mut lo, mut hi, mut sum) = (usize::MAX, 0usize, 0u64);
    let mut q = [0.0f64; 8];
    for _ in 0..samples {
        for v in q.iter_mut() {
            *v = rng.uniform(0.0, 1.0);
        }
        let c = zn::count_in_ball(&q, 4.0);
        lo = lo.min(c);
        hi = hi.max(c);
        sum += c as u64;
    }
    SupportStats { min: lo, mean: sum as f64 / samples as f64, max: hi, samples }
}

/// Mean weight captured by the top-k selection (paper §2.6: ">= 99.5% on
/// average, >= 90% minimum" for k = 32).
pub fn topk_weight_fraction(samples: u64, k: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut weights = Vec::with_capacity(232);
    let (mut min_frac, mut sum_frac) = (f64::MAX, 0.0);
    for _ in 0..samples {
        let q: Vec8 = std::array::from_fn(|_| rng.uniform(0.0, 8.0));
        let red = reduce(&q);
        weights.clear();
        let mut total = 0.0;
        for c in neighbor_table().iter() {
            let mut d2 = 0.0;
            for j in 0..8 {
                let d = red.z[j] - c[j] as f64;
                d2 += d * d;
            }
            let w = kernel_f(d2);
            if w > 0.0 {
                total += w;
                weights.push(w);
            }
        }
        // descending; total_cmp because kernel weights are finite and a
        // typed total order beats an unwrap on partial_cmp regardless
        weights.sort_by(|a, b| b.total_cmp(a));
        let kept: f64 = weights.iter().take(k).sum();
        let frac = kept / total;
        min_frac = min_frac.min(frac);
        sum_frac += frac;
    }
    (sum_frac / samples as f64, min_frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e8_stats_match_paper_at_moderate_samples() {
        let s = e8_support_stats(30_000, 1);
        assert_eq!(s.min, 45, "paper min 45 (m.c.)");
        assert!((s.mean - 64.94).abs() < 0.5, "mean {}", s.mean);
        assert!(s.max <= 121 && s.max >= 95, "max {}", s.max);
    }

    #[test]
    fn z8_stats_match_paper_at_moderate_samples() {
        let s = z8_support_stats(3_000, 2);
        assert!(s.min >= 768, "min {}", s.min);
        assert!((s.mean - 1039.0).abs() < 20.0, "mean {}", s.mean);
        assert!(s.max <= 1312, "max {}", s.max);
    }

    #[test]
    fn top32_fraction_matches_paper() {
        let (avg, min) = topk_weight_fraction(5_000, 32, 3);
        assert!(avg >= 0.99, "avg {avg}");
        assert!(min >= 0.90, "min {min}");
    }
}
