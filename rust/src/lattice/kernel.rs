//! The interpolation kernel (paper §2.5):
//! `f(r) = max(0, 1 - r^2/8)^4`, supported in the open ball of radius
//! `sqrt(8)`; `f = 1` exactly at lattice points, and the total weight
//! `sum_k f(d(q, k))` lies in `[(22158 - 625*sqrt(5))/24389, 1]`.

/// Paper §2.5 lower bound on the total kernel weight.
pub const TOTAL_WEIGHT_LOWER: f64 = 0.851_222_518_575_920_3;

/// Kernel value in terms of the squared distance.
#[inline(always)]
pub fn kernel_f(d2: f64) -> f64 {
    let t = 1.0 - d2 * 0.125;
    if t <= 0.0 {
        0.0
    } else {
        let t2 = t * t;
        t2 * t2
    }
}

/// d/d(d2) of the kernel (for the analytic gradient in the lookup).
#[inline(always)]
pub fn kernel_df_dd2(d2: f64) -> f64 {
    let t = 1.0 - d2 * 0.125;
    if t <= 0.0 {
        0.0
    } else {
        -0.5 * t * t * t
    }
}

/// Partial top-k selection by descending weight over (weight, payload)
/// pairs; stable for ties.  k is small (32) and n fixed (232), so a simple
/// selection keeps the hot path allocation-free when given a scratch
/// buffer.
pub fn top_k_desc<T: Copy>(items: &mut [(f64, T)], k: usize) -> &[(f64, T)] {
    let k = k.min(items.len());
    // partial selection sort — O(n*k) with tiny constants; for n=232,
    // k=32 this beats building a heap in practice (see bench
    // lattice_hot_path).
    for i in 0..k {
        let mut best = i;
        for j in (i + 1)..items.len() {
            if items[j].0 > items[best].0 {
                best = j;
            }
        }
        items.swap(i, best);
    }
    &items[..k]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_boundary_values() {
        assert_eq!(kernel_f(0.0), 1.0);
        assert_eq!(kernel_f(8.0), 0.0);
        assert_eq!(kernel_f(9.0), 0.0);
        assert!((kernel_f(4.0) - 0.0625).abs() < 1e-12); // (1/2)^4
    }

    #[test]
    fn kernel_monotone_decreasing() {
        let mut prev = kernel_f(0.0);
        for i in 1..100 {
            let cur = kernel_f(i as f64 * 0.1);
            assert!(cur <= prev + 1e-15);
            prev = cur;
        }
    }

    #[test]
    fn derivative_matches_finite_difference() {
        for d2 in [0.1, 1.0, 3.0, 6.5, 7.9] {
            let h = 1e-6;
            let fd = (kernel_f(d2 + h) - kernel_f(d2 - h)) / (2.0 * h);
            assert!((fd - kernel_df_dd2(d2)).abs() < 1e-6, "d2 = {d2}");
        }
    }

    #[test]
    fn derivative_matches_finite_difference_across_the_support() {
        // property: everywhere in (and beyond) the support, the analytic
        // derivative agrees with a central difference of kernel_f — the
        // routing gradient (lattice::batch backward) rides this function
        crate::util::check::forall(500, |rng| {
            let d2 = rng.uniform(0.0, 10.0);
            let h = 1e-6;
            let fd = (kernel_f(d2 + h) - kernel_f(d2 - h)) / (2.0 * h);
            let df = kernel_df_dd2(d2);
            assert!(
                (fd - df).abs() <= 1e-8 + 1e-6 * fd.abs(),
                "d2 = {d2}: analytic {df} vs finite difference {fd}"
            );
        });
    }

    #[test]
    fn derivative_vanishes_continuously_at_the_support_boundary() {
        // f = (1 - d2/8)^4 is C^3 at d2 = 8: the derivative approaches 0
        // from inside (like -(eps/8)^3 / 2) and is exactly 0 outside, so
        // the routing gradient never jumps as a hit leaves the support
        assert_eq!(kernel_df_dd2(8.0), 0.0);
        assert_eq!(kernel_df_dd2(9.0), 0.0);
        for eps in [1e-3, 1e-6, 1e-9] {
            let inside = kernel_df_dd2(8.0 - eps);
            assert!(inside < 0.0, "still descending just inside (eps = {eps})");
            assert!(inside.abs() <= 1e-8 + eps.powi(3), "eps = {eps}: {inside}");
            assert_eq!(kernel_df_dd2(8.0 + eps), 0.0, "hard zero outside");
        }
        // a central difference straddling the boundary still converges
        let h = 1e-5;
        let fd = (kernel_f(8.0 + h) - kernel_f(8.0 - h)) / (2.0 * h);
        assert!(fd.abs() < 1e-9, "{fd}");
    }

    #[test]
    fn top_k_selects_descending() {
        let mut items: Vec<(f64, usize)> =
            (0..100).map(|i| (((i * 37) % 100) as f64, i)).collect();
        let top = top_k_desc(&mut items, 5);
        let vals: Vec<f64> = top.iter().map(|t| t.0).collect();
        assert_eq!(vals, vec![99.0, 98.0, 97.0, 96.0, 95.0]);
    }

    #[test]
    fn top_k_with_k_larger_than_n() {
        let mut items = vec![(1.0, 0), (3.0, 1)];
        let top = top_k_desc(&mut items, 10);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].1, 1);
    }
}
