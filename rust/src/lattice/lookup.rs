//! The full O(1) lattice lookup: reduce → score 232 candidates → top-k →
//! inverse isometry → torus memory indices (paper §2.6).
//!
//! This scalar implementation is the *reference oracle*: batched hot
//! paths run through [`crate::lattice::batch::BatchLookupEngine`], whose
//! fused SoA pipeline is differential-tested against this module
//! bit-for-bit (`rust/tests/batch_differential.rs`).  Single queries are
//! allocation-free through [`LatticeLookup::lookup_into`].

use super::e8::{reduce, vec8, Vec8};
use super::kernel::kernel_f;
use super::neighbors::{neighbor_table, N_NEIGHBORS};
use super::torus::TorusK;
use crate::util::topk::desc_nan_last;

/// One selected memory slot: index, kernel weight, squared distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    pub index: u64,
    pub weight: f64,
    pub d2: f64,
}

/// Result of a lookup (top-k hits, weight-descending).
#[derive(Debug, Clone, Default)]
pub struct LookupResult {
    pub hits: Vec<Hit>,
    /// Total weight over *all* candidates (paper bound: [0.851, 1]).
    pub total_weight: f64,
}

/// Reusable lookup engine for a fixed torus.
pub struct LatticeLookup {
    pub torus: TorusK,
    pub k_top: usize,
    // scratch: (weight, torus row, candidate index) triples plus the
    // per-candidate d2 row (kept for `Hit::d2`)
    scratch: Vec<(f64, u64, usize)>,
    d2s: [f64; N_NEIGHBORS],
}

impl LatticeLookup {
    pub fn new(torus: TorusK, k_top: usize) -> Self {
        LatticeLookup {
            torus,
            k_top,
            scratch: Vec::with_capacity(N_NEIGHBORS),
            d2s: [0.0; N_NEIGHBORS],
        }
    }

    /// Lookup a single query point (allocates the result).
    pub fn lookup(&mut self, q: &Vec8) -> LookupResult {
        let mut out = LookupResult::default();
        self.lookup_into(q, &mut out);
        out
    }

    /// Allocation-free lookup into a reusable result buffer.
    pub fn lookup_into(&mut self, q: &Vec8, out: &mut LookupResult) {
        out.hits.clear();
        out.total_weight = 0.0;
        let red = reduce(q);
        let nbr = neighbor_table();
        let nbrf = super::neighbors::neighbor_table_f64();
        self.scratch.clear();
        for (ci, c) in nbrf.iter().enumerate() {
            // unrolled squared distance in the reduced frame
            let d0 = red.z[0] - c[0];
            let d1 = red.z[1] - c[1];
            let d2_ = red.z[2] - c[2];
            let d3 = red.z[3] - c[3];
            let d4 = red.z[4] - c[4];
            let d5 = red.z[5] - c[5];
            let d6 = red.z[6] - c[6];
            let d7 = red.z[7] - c[7];
            let d2 = d0 * d0 + d1 * d1 + d2_ * d2_ + d3 * d3
                + d4 * d4 + d5 * d5 + d6 * d6 + d7 * d7;
            if d2 < 8.0 {
                let w = kernel_f(d2);
                out.total_weight += w;
                self.d2s[ci] = d2;
                let u = red.unmap(&nbr[ci]);
                self.scratch.push((w, self.torus.index(&u), ci));
            }
        }
        // canonical selection — weight descending, torus row ascending,
        // candidate ascending — the exact total order the batch engine's
        // `select_canonical` applies, so engine and oracle stay
        // bit-identical even on exact weight ties.  Sorting all (<= 121)
        // in-support candidates is fine for a reference oracle.
        self.scratch.sort_unstable_by(|a, b| {
            desc_nan_last(a.0, b.0).then_with(|| a.1.cmp(&b.1)).then_with(|| a.2.cmp(&b.2))
        });
        for &(w, row, ci) in self.scratch.iter().take(self.k_top) {
            out.hits.push(Hit { index: row, weight: w, d2: self.d2s[ci] });
        }
    }

    /// Batch lookup (row-major queries, 8 per row).
    ///
    /// **Deprecated in practice**: this is the scalar differential-
    /// testing oracle, kept for cross-checking.  Hot paths should use
    /// [`crate::lattice::batch::BatchLookupEngine`], which runs the same
    /// pipeline fused, allocation-free, over SoA buffers and across
    /// threads.  A single scratch result is reused across queries here
    /// so the only per-query allocation is the exact-sized clone.
    pub fn lookup_batch(&mut self, queries: &[f64]) -> Vec<LookupResult> {
        assert_eq!(queries.len() % 8, 0);
        let mut results = Vec::with_capacity(queries.len() / 8);
        let mut scratch = LookupResult::default();
        for chunk in queries.chunks_exact(8) {
            self.lookup_into(vec8(chunk), &mut scratch);
            results.push(scratch.clone());
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::kernel::TOTAL_WEIGHT_LOWER;
    use crate::util::check::forall;

    fn torus() -> TorusK {
        TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap()
    }

    #[test]
    fn weights_within_paper_bounds() {
        forall(500, |rng| {
            let mut lk = LatticeLookup::new(torus(), 32);
            let q: Vec8 = std::array::from_fn(|_| rng.uniform(-10.0, 10.0));
            let r = lk.lookup(&q);
            assert!(r.total_weight >= TOTAL_WEIGHT_LOWER - 1e-9, "{}", r.total_weight);
            assert!(r.total_weight <= 1.0 + 1e-9, "{}", r.total_weight);
        });
    }

    #[test]
    fn top32_captures_at_least_90_percent() {
        let mut lk = LatticeLookup::new(torus(), 32);
        let mut rng = crate::util::rng::Rng::new(11);
        let mut min_frac = f64::MAX;
        for _ in 0..2000 {
            let q: Vec8 = std::array::from_fn(|_| rng.uniform(-10.0, 10.0));
            let r = lk.lookup(&q);
            let kept: f64 = r.hits.iter().map(|h| h.weight).sum();
            min_frac = min_frac.min(kept / r.total_weight);
        }
        assert!(min_frac >= 0.90, "top-32 kept only {min_frac:.4}");
    }

    #[test]
    fn weights_descending_and_indices_in_range() {
        let mut lk = LatticeLookup::new(torus(), 32);
        let m = lk.torus.num_locations();
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..500 {
            let q: Vec8 = std::array::from_fn(|_| rng.uniform(-30.0, 30.0));
            let r = lk.lookup(&q);
            for w in r.hits.windows(2) {
                assert!(w[0].weight >= w[1].weight - 1e-12);
            }
            for h in &r.hits {
                assert!(h.index < m);
                assert!(h.weight > 0.0 && h.weight <= 1.0 + 1e-12);
                assert!(h.d2 < 8.0);
            }
        }
    }

    #[test]
    fn lattice_point_query_hits_itself_with_weight_one() {
        let mut lk = LatticeLookup::new(torus(), 32);
        let k = lk.torus;
        for idx in [0u64, 1, 1000, 12345] {
            let x = k.representative(idx);
            let q: Vec8 = std::array::from_fn(|i| x[i] as f64);
            let r = lk.lookup(&q);
            assert_eq!(r.hits.len(), 1, "open-ball kernel: only the point itself");
            assert_eq!(r.hits[0].index, idx);
            assert!((r.hits[0].weight - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn count_in_support_matches_paper_range() {
        // paper Table 1 (E8 row): min 45, max 121 points in kernel support
        // for non-degenerate queries
        let mut lk = LatticeLookup::new(torus(), 232);
        let mut rng = crate::util::rng::Rng::new(17);
        let (mut lo, mut hi) = (usize::MAX, 0usize);
        for _ in 0..20000 {
            let q: Vec8 = std::array::from_fn(|_| rng.uniform(0.0, 8.0));
            let r = lk.lookup(&q);
            lo = lo.min(r.hits.len());
            hi = hi.max(r.hits.len());
        }
        assert!(lo >= 45, "min support {lo} below paper's 45");
        assert!(hi <= 121, "max support {hi} above paper's 121");
        assert!(hi >= 90, "max support {hi} suspiciously small");
    }

    #[test]
    fn equal_weight_ties_order_by_ascending_row() {
        // (1,1,0,...,0) sits at d2 = 2 from both the origin and
        // (2,2,0,...,0): the oracle must order such exact ties by
        // ascending torus row, matching the batch engine's canonical rule
        let mut lk = LatticeLookup::new(torus(), 32);
        let probes: [Vec8; 3] = [
            [1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            [1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0],
            [0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5],
        ];
        let mut ties = 0usize;
        for q in &probes {
            let r = lk.lookup(q);
            for w in r.hits.windows(2) {
                assert!(w[0].weight >= w[1].weight);
                if w[0].weight == w[1].weight {
                    ties += 1;
                    assert!(
                        w[1].index >= w[0].index,
                        "tied weights must order by ascending row ({} then {})",
                        w[0].index,
                        w[1].index
                    );
                }
            }
        }
        assert!(ties > 0, "symmetric probes must produce exact ties");
    }

    #[test]
    fn batch_matches_single() {
        let mut lk = LatticeLookup::new(torus(), 32);
        let mut rng = crate::util::rng::Rng::new(23);
        let flat: Vec<f64> = (0..80).map(|_| rng.uniform(-5.0, 5.0)).collect();
        let batch = lk.lookup_batch(&flat);
        for (i, r) in batch.iter().enumerate() {
            let q: Vec8 = flat[i * 8..(i + 1) * 8].try_into().unwrap();
            let single = lk.lookup(&q);
            assert_eq!(single.hits.len(), r.hits.len());
            for (a, b) in single.hits.iter().zip(&r.hits) {
                assert_eq!(a.index, b.index);
            }
        }
    }
}
