//! Torus memory addressing (paper §2.2): the quotient `M = Lambda / L_K`
//! with `L_K = prod(K_i Z)`, `K_i in 4Z`, has `M = prod(K_i) / 256`
//! memory locations.  `torus_index` is the O(1) bijection onto `[0, M)`.
//!
//! Write `x = 2y + p` (parity bit `p`, `y in D8`).  `y_1..y_7` are free
//! mod `K_i/2` (mixed-radix packed); `sum(y)` even makes `y_8`'s parity a
//! function of the others, so `y_8` packs mod `K_8/4` after removing it.

use anyhow::{bail, Result};

use super::e8::IVec8;

/// Validated torus periods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TorusK {
    pub k: [i64; 8],
}

impl TorusK {
    pub fn new(k: [i64; 8]) -> Result<Self> {
        for &v in &k {
            if v < 4 || v % 4 != 0 {
                bail!("each K_i must be a positive multiple of 4 (got {v}) so that L_K <= Lambda");
            }
        }
        Ok(TorusK { k })
    }

    pub fn uniform(k: i64) -> Result<Self> {
        Self::new([k; 8])
    }

    /// Number of memory locations `M = prod(K_i) / 256`.
    pub fn num_locations(&self) -> u64 {
        let p: u64 = self.k.iter().map(|&v| v as u64).product();
        p / super::DET_LAMBDA
    }

    /// O(1) memory index of a lattice point (representative-independent).
    #[inline]
    pub fn index(&self, x: &IVec8) -> u64 {
        let p = x[0].rem_euclid(2);
        let mut m = [0i64; 8];
        let mut s = 0i64;
        for i in 0..8 {
            let y = (x[i] - p) >> 1;
            m[i] = y.rem_euclid(self.k[i] >> 1);
            if i < 7 {
                s += m[i];
            }
        }
        let t = (m[7] - (s & 1)) >> 1;
        let mut idx = p as u64;
        for i in 0..7 {
            idx = idx * (self.k[i] >> 1) as u64 + m[i] as u64;
        }
        idx * (self.k[7] >> 2) as u64 + t as u64
    }

    /// Canonical representative of a memory slot (inverse of `index`).
    pub fn representative(&self, idx: u64) -> IVec8 {
        let mut rest = idx;
        let k84 = (self.k[7] >> 2) as u64;
        let t = rest % k84;
        rest /= k84;
        let mut m = [0i64; 8];
        for i in (0..7).rev() {
            let kh = (self.k[i] >> 1) as u64;
            m[i] = (rest % kh) as i64;
            rest /= kh;
        }
        let p = rest as i64;
        let s: i64 = m[..7].iter().sum::<i64>() & 1;
        m[7] = 2 * t as i64 + s;
        let mut x = [0i64; 8];
        for i in 0..8 {
            x[i] = 2 * m[i] + p;
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::e8::{is_lattice_point, quantize};
    use crate::util::check::forall;

    #[test]
    fn rejects_bad_k() {
        assert!(TorusK::new([8, 8, 8, 8, 8, 8, 8, 2]).is_err());
        assert!(TorusK::new([8, 8, 8, 8, 8, 8, 8, 6]).is_err());
        assert!(TorusK::uniform(8).is_ok());
    }

    #[test]
    fn paper_slot_counts() {
        // Table 5: LRAM-small/medium/large = 2^18 / 2^20 / 2^22 locations
        assert_eq!(TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap().num_locations(), 1 << 18);
        assert_eq!(TorusK::new([16, 16, 16, 16, 8, 8, 8, 8]).unwrap().num_locations(), 1 << 20);
        assert_eq!(
            TorusK::new([16, 16, 16, 16, 16, 16, 8, 8]).unwrap().num_locations(),
            1 << 22
        );
    }

    #[test]
    fn bijection_small_torus() {
        for k in [
            TorusK::uniform(4).unwrap(),
            TorusK::uniform(8).unwrap(),
            TorusK::new([8, 4, 8, 4, 8, 8, 4, 8]).unwrap(),
            TorusK::new([12, 8, 8, 8, 4, 4, 8, 8]).unwrap(),
        ] {
            let m = k.num_locations();
            let mut seen = std::collections::HashSet::new();
            for idx in 0..m {
                let x = k.representative(idx);
                assert!(is_lattice_point(&x), "{x:?}");
                assert_eq!(k.index(&x), idx);
                assert!(seen.insert(x), "duplicate representative {x:?}");
            }
        }
    }

    #[test]
    fn index_invariant_under_l_k_shifts() {
        let k = TorusK::new([8, 8, 16, 8, 8, 4, 8, 8]).unwrap();
        forall(500, |rng| {
            let mut q = [0.0f64; 8];
            for v in q.iter_mut() {
                *v = rng.uniform(-40.0, 40.0);
            }
            let x = quantize(&q);
            let base = k.index(&x);
            assert!(base < k.num_locations());
            let mut shifted = x;
            for i in 0..8 {
                shifted[i] += k.k[i] * rng.range(-3, 4);
            }
            assert_eq!(k.index(&shifted), base);
        });
    }
}
