//! Lattice mathematics for LRAM (paper sections 2.2–2.6).
//!
//! The memory lattice is `Lambda = 2*E8`:
//!
//! ```text
//! Lambda = { x in (2Z)^8 u (2Z+1)^8 : sum(x) = 0 mod 4 }
//! ```
//!
//! with packing radius `sqrt(2)`, covering radius `2`, minimal vector norm
//! `sqrt(8)` and determinant `256`.  A query is answered by reducing it
//! into the fundamental region `F` with a lattice isometry (translation +
//! signed permutation with an even number of sign changes), scoring the
//! fixed table of exactly **232** candidate lattice points that can fall
//! within the kernel radius `sqrt(8)` of `F`, keeping the top-32 weights,
//! and mapping those points to O(1) torus memory indices.
//!
//! This module mirrors `python/compile/kernels/lattice_tables.py`; the two
//! implementations are cross-checked through
//! `artifacts/lattice_fixture.json` (see `rust/tests/fixture.rs`).

pub mod batch;
pub mod e8;
pub mod exotic;
pub mod kernel;
pub mod lookup;
pub mod neighbors;
pub mod simd;
pub mod support;
pub mod torus;
pub mod zn;

pub use batch::{
    BackwardCache, BatchLookupEngine, BatchOutput, GatherStage, MergeWeight, ScoredBatch,
    ShardPlan, ShardSelection,
};
pub use e8::{is_lattice_point, quantize, reduce, Reduction};
pub use kernel::{kernel_f, TOTAL_WEIGHT_LOWER};
pub use lookup::{LatticeLookup, LookupResult};
pub use neighbors::{neighbor_table, N_NEIGHBORS};
pub use torus::TorusK;

/// sqrt(8): kernel support radius and the minimal vector norm of Lambda.
pub const SQRT8: f64 = 2.828_427_124_746_190_3;
/// Determinant (covolume) of Lambda = 2*E8.
pub const DET_LAMBDA: u64 = 256;
/// Covering radius of Lambda.
pub const COVERING_RADIUS: f64 = 2.0;
/// Packing radius of Lambda.
pub const PACKING_RADIUS: f64 = std::f64::consts::SQRT_2;
