//! Higher-dimensional lattices for Table 1: the Coxeter–Todd lattice
//! `K12`, the Barnes–Wall lattice `Lambda16` and the Leech lattice
//! `Lambda24`.
//!
//! The paper reports only the *average* kernel-support count for these
//! (no `(m.c.)` mark), which is analytic: for a unimodular lattice the
//! expected number of points in a ball equals the ball's volume, and the
//! kernel radius is `sqrt(2) *` covering radius.  Packing/covering radii
//! are the classical values from Conway & Sloane (SPLAG), normalised to
//! determinant 1.

/// Classical lattice constants, unimodular normalisation.
#[derive(Debug, Clone, Copy)]
pub struct LatticeInfo {
    pub name: &'static str,
    pub dim: usize,
    pub packing_radius: f64,
    pub covering_radius: f64,
}

/// n-ball volume of radius r.
pub fn ball_volume(n: usize, r: f64) -> f64 {
    // V_n(r) = pi^{n/2} r^n / Gamma(n/2 + 1)
    let half = n as f64 / 2.0;
    (std::f64::consts::PI.powf(half) / gamma(half + 1.0)) * r.powi(n as i32)
}

/// Lanczos approximation of the Gamma function (double precision).
pub fn gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

/// K12, Coxeter–Todd: det 3^6 at min norm 4; covering radius normalised
/// to det 1 per the paper's Table 1 value.
pub const K12: LatticeInfo = LatticeInfo {
    name: "K12",
    dim: 12,
    // SPLAG ch. 4: unimodular-normalised packing radius 3^{1/4}/sqrt(2)/3^{1/4}...
    // the paper's Table 1 lists 0.760 / 1.241; those follow from
    // rho = (min/2)/det^{1/n} = 1/3^{1/4} and R = sqrt(8/3)/3^{1/4}.
    packing_radius: 0.759_835_685_651_593, // 3^{-1/4}
    covering_radius: 1.240_806_478_181_74, // sqrt(8/3) * 3^{-1/4}
};

/// Lambda16, Barnes–Wall: det 2^8, min norm 4, covering radius^2 = 3.
pub const BW16: LatticeInfo = LatticeInfo {
    name: "Lambda16",
    dim: 16,
    packing_radius: 0.840_896_415_253_714_6, // 1/2^{1/4}
    covering_radius: 1.456_475_315_121_9,    // sqrt(3)/2^{1/4}
};

/// Lambda24, Leech: unimodular, min norm 4, covering radius sqrt(2).
pub const LEECH: LatticeInfo = LatticeInfo {
    name: "Lambda24",
    dim: 24,
    packing_radius: 1.0,
    covering_radius: std::f64::consts::SQRT_2,
};

/// Z8 and E8 rows (for uniform Table-1 reporting).
pub const Z8: LatticeInfo = LatticeInfo {
    name: "Z8",
    dim: 8,
    packing_radius: 0.5,
    covering_radius: 1.414_213_562_373_095_1,
};

pub const E8: LatticeInfo = LatticeInfo {
    name: "E8",
    dim: 8,
    packing_radius: 0.707_106_781_186_547_6,
    covering_radius: 1.0,
};

impl LatticeInfo {
    /// Analytic average number of lattice points in the kernel support
    /// (ball of radius sqrt(2) * covering radius; unimodular => expected
    /// count = ball volume).
    pub fn avg_kernel_support(&self) -> f64 {
        ball_volume(self.dim, std::f64::consts::SQRT_2 * self.covering_radius)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_known_values() {
        assert!((gamma(1.0) - 1.0).abs() < 1e-12);
        assert!((gamma(5.0) - 24.0).abs() < 1e-9);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-12);
        assert!((gamma(7.5) - 1871.254_305_797_788).abs() < 1e-6);
    }

    #[test]
    fn ball_volume_known_values() {
        assert!((ball_volume(2, 1.0) - std::f64::consts::PI).abs() < 1e-12);
        assert!((ball_volume(3, 1.0) - 4.188_790_204_786_391).abs() < 1e-9);
        // V_8(sqrt 2) = pi^4 * 16 / 24 = 64.939...
        assert!((ball_volume(8, std::f64::consts::SQRT_2) - 64.939_394_022_668_29).abs() < 1e-6);
    }

    #[test]
    fn table1_average_support_counts() {
        // paper Table 1 row "Average points in kernel support"
        assert!((Z8.avg_kernel_support() - 1039.0).abs() < 1.0, "{}", Z8.avg_kernel_support());
        assert!((E8.avg_kernel_support() - 64.94).abs() < 0.01);
        assert!((K12.avg_kernel_support() - 1138.0).abs() < 6.0, "{}", K12.avg_kernel_support());
        assert!(
            (BW16.avg_kernel_support() - 24704.0).abs() < 150.0,
            "{}",
            BW16.avg_kernel_support()
        );
        assert!(
            (LEECH.avg_kernel_support() - 32373.0).abs() < 200.0,
            "{}",
            LEECH.avg_kernel_support()
        );
    }

    #[test]
    fn e8_beats_z8_by_16x_average_access(){
        // paper §2.4: "lookup with E8 accesses 16 times fewer points on
        // average for the same spatial resolution"
        let ratio = Z8.avg_kernel_support() / E8.avg_kernel_support();
        assert!((ratio - 16.0).abs() < 0.01, "ratio {ratio}");
    }
}
