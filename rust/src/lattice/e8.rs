//! The `Lambda = 2*E8` quantizer and isometry reduction (paper §2.6).
//!
//! `Lambda = 2*D8 u (2*D8 + 1)`: decoding splits into the even and odd
//! cosets; each is a scaled `D8` decode (round every coordinate, fix the
//! worst one if the parity constraint fails — Conway & Sloane ch. 20).

/// One query/lattice point in R^8.
pub type Vec8 = [f64; 8];
/// Integer lattice point.
pub type IVec8 = [i64; 8];

/// Borrow an 8-lane slice (a `chunks_exact(8)` row) as a [`Vec8`].
///
/// The conversion is structurally infallible — every caller hands in a
/// row produced by `chunks_exact(8)` or an exact `[qi*8..(qi+1)*8]`
/// slice — so the length contract lives in exactly one place instead of
/// an `expect` at every hot-path call site.  This is the lattice
/// production path's single allowlisted panic site (`tidy` check 2).
#[inline]
pub fn vec8(chunk: &[f64]) -> &Vec8 {
    chunk.try_into().expect("vec8 callers hand in exactly-8-lane slices")
}

/// Nearest point of `D8 = { y in Z^8 : sum(y) even }` to `y`.
#[inline]
fn decode_d8(y: &Vec8) -> IVec8 {
    let mut f = [0i64; 8];
    let mut sum = 0i64;
    let mut worst = 0usize;
    let mut worst_err = -1.0f64;
    let mut err = [0.0f64; 8];
    for i in 0..8 {
        let r = y[i].round_ties_even();
        f[i] = r as i64;
        sum += f[i];
        err[i] = y[i] - r;
        let a = err[i].abs();
        if a > worst_err {
            worst_err = a;
            worst = i;
        }
    }
    if sum.rem_euclid(2) != 0 {
        f[worst] += if err[worst] >= 0.0 { 1 } else { -1 };
    }
    f
}

/// Nearest point of `Lambda` to `q` (ties broken toward the even coset,
/// matching the python reference).
pub fn quantize(q: &Vec8) -> IVec8 {
    // even coset: 2 * decode_d8(q / 2)
    let mut half = [0.0; 8];
    for i in 0..8 {
        half[i] = q[i] * 0.5;
    }
    let e = decode_d8(&half);
    // odd coset: 2 * decode_d8((q - 1) / 2) + 1
    let mut shifted = [0.0; 8];
    for i in 0..8 {
        shifted[i] = (q[i] - 1.0) * 0.5;
    }
    let o = decode_d8(&shifted);
    let (mut de, mut dodd) = (0.0, 0.0);
    let mut even_pt = [0i64; 8];
    let mut odd_pt = [0i64; 8];
    for i in 0..8 {
        even_pt[i] = 2 * e[i];
        odd_pt[i] = 2 * o[i] + 1;
        let a = q[i] - even_pt[i] as f64;
        let b = q[i] - odd_pt[i] as f64;
        de += a * a;
        dodd += b * b;
    }
    if de <= dodd {
        even_pt
    } else {
        odd_pt
    }
}

/// Membership test for Lambda.
pub fn is_lattice_point(x: &IVec8) -> bool {
    let parity = x[0].rem_euclid(2);
    x.iter().all(|&v| v.rem_euclid(2) == parity) && x.iter().sum::<i64>().rem_euclid(4) == 0
}

/// The isometry mapping a query into the fundamental region F.
///
/// `z[j] = eps[j] * (q - x0)[perm[j]]` with `z` in
/// `F = { z1 >= ... >= z7 >= |z8|, z1 + z2 <= 2, sum(z) <= 4 }` and an
/// even number of `-1` entries in `eps`.
#[derive(Debug, Clone)]
pub struct Reduction {
    /// Nearest lattice point (the translation part).
    pub x0: IVec8,
    /// Sorted-coordinate permutation: `perm[j]` = original index of lane j.
    pub perm: [usize; 8],
    /// Sign flips applied per sorted lane (product is +1).
    pub eps: [f64; 8],
    /// The reduced point in F.
    pub z: Vec8,
}

impl Reduction {
    /// Inverse isometry applied to an integer candidate (reduced frame):
    /// returns the original-frame lattice point.
    #[inline]
    pub fn unmap(&self, c: &IVec8) -> IVec8 {
        let mut u = self.x0;
        for j in 0..8 {
            u[self.perm[j]] += self.eps[j] as i64 * c[j];
        }
        u
    }
}

/// Reduce `q` into the fundamental region (paper §2.6: translation by a
/// lattice vector, a coordinate permutation, and an even number of sign
/// changes — the index-135 subgroup of the full isometry group).
pub fn reduce(q: &Vec8) -> Reduction {
    let x0 = quantize(q);
    let mut r = [0.0f64; 8];
    for i in 0..8 {
        r[i] = q[i] - x0[i] as f64;
    }
    // sort |r| descending, tracking original index and sign
    let mut lanes: [(f64, usize, f64); 8] = [(0.0, 0, 1.0); 8];
    for i in 0..8 {
        lanes[i] = (r[i].abs(), i, if r[i] < 0.0 { -1.0 } else { 1.0 });
    }
    // insertion sort (n = 8), stable, descending by |r|
    for i in 1..8 {
        let key = lanes[i];
        let mut j = i;
        while j > 0 && lanes[j - 1].0 < key.0 {
            lanes[j] = lanes[j - 1];
            j -= 1;
        }
        lanes[j] = key;
    }
    let mut perm = [0usize; 8];
    let mut eps = [1.0f64; 8];
    let mut z = [0.0f64; 8];
    let mut nneg = 0usize;
    for j in 0..8 {
        perm[j] = lanes[j].1;
        eps[j] = lanes[j].2;
        z[j] = lanes[j].0;
        if lanes[j].2 < 0.0 {
            nneg += 1;
        }
    }
    // parity fix: even number of sign changes; the smallest-|.| lane
    // absorbs the leftover flip (z8 may become negative — F allows it)
    if nneg % 2 == 1 {
        eps[7] = -eps[7];
        z[7] = eps[7] * (lanes[7].2 * lanes[7].0); // eps * r[perm[7]]
    }
    Reduction { x0, perm, eps, z }
}

/// Check membership of the fundamental region (tests / diagnostics).
pub fn in_fundamental_region(z: &Vec8, tol: f64) -> bool {
    for i in 0..6 {
        if z[i] < z[i + 1] - tol {
            return false;
        }
    }
    if z[6] < z[7].abs() - tol {
        return false;
    }
    if z[0] + z[1] > 2.0 + tol {
        return false;
    }
    z.iter().sum::<f64>() <= 4.0 + tol
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn rand_q(rng: &mut crate::util::rng::Rng, lo: f64, hi: f64) -> Vec8 {
        let mut q = [0.0; 8];
        for v in q.iter_mut() {
            *v = rng.uniform(lo, hi);
        }
        q
    }

    #[test]
    fn quantize_returns_lattice_points() {
        forall(500, |rng| {
            let q = rand_q(rng, -20.0, 20.0);
            let x = quantize(&q);
            assert!(is_lattice_point(&x), "{x:?} not in Lambda (q = {q:?})");
        });
    }

    #[test]
    fn quantize_within_covering_radius() {
        forall(2000, |rng| {
            let q = rand_q(rng, -10.0, 10.0);
            let x = quantize(&q);
            let d2: f64 = (0..8).map(|i| (q[i] - x[i] as f64).powi(2)).sum();
            assert!(d2 <= 4.0 + 1e-9, "dist^2 {d2} > covering^2");
        });
    }

    #[test]
    fn quantize_fixes_lattice_points() {
        forall(300, |rng| {
            // random lattice point: 2*(random ints) with sum fixed to 0 mod 2
            let mut y = [0i64; 8];
            for v in y.iter_mut() {
                *v = rng.range(-6, 7);
            }
            let s: i64 = y.iter().sum();
            if s.rem_euclid(2) != 0 {
                y[7] += 1;
            }
            let parity = rng.range(0, 2);
            let mut x = [0i64; 8];
            for i in 0..8 {
                x[i] = 2 * y[i] + parity;
            }
            if !is_lattice_point(&x) {
                // fix sum mod 4 by shifting one coordinate by 2
                x[0] += 2;
            }
            assert!(is_lattice_point(&x));
            let q: Vec8 = std::array::from_fn(|i| x[i] as f64);
            assert_eq!(quantize(&q), x);
        });
    }

    #[test]
    fn quantize_translation_equivariant() {
        forall(300, |rng| {
            let q = rand_q(rng, -8.0, 8.0);
            let shift = [4.0, -4.0, 0.0, 8.0, 0.0, 0.0, 0.0, 0.0]; // in Lambda
            let a = quantize(&q);
            let mut q2 = q;
            for i in 0..8 {
                q2[i] += shift[i];
            }
            let b = quantize(&q2);
            for i in 0..8 {
                assert_eq!(b[i] - a[i], shift[i] as i64);
            }
        });
    }

    #[test]
    fn reduction_lands_in_f() {
        forall(3000, |rng| {
            let q = rand_q(rng, -15.0, 15.0);
            let red = reduce(&q);
            assert!(in_fundamental_region(&red.z, 1e-9), "z = {:?}", red.z);
        });
    }

    #[test]
    fn reduction_is_isometry_and_even_signed() {
        forall(1000, |rng| {
            let q = rand_q(rng, -15.0, 15.0);
            let red = reduce(&q);
            // even number of sign changes
            let prod: f64 = red.eps.iter().product();
            assert_eq!(prod, 1.0);
            // norm preserved
            let rn: f64 = (0..8).map(|i| (q[i] - red.x0[i] as f64).powi(2)).sum();
            let zn: f64 = red.z.iter().map(|v| v * v).sum();
            assert!((rn - zn).abs() < 1e-9);
            // unmap of origin gives x0
            assert_eq!(red.unmap(&[0; 8]), red.x0);
        });
    }

    #[test]
    fn unmap_preserves_distance() {
        forall(500, |rng| {
            let q = rand_q(rng, -10.0, 10.0);
            let red = reduce(&q);
            // arbitrary candidate point with matching parity classes exists
            // in the neighbor table; here use a simple lattice vector
            let c: IVec8 = [2, 2, 0, 0, 0, 0, 0, 0];
            let u = red.unmap(&c);
            assert!(is_lattice_point(&u), "{u:?}");
            let dz: f64 = (0..8).map(|j| (red.z[j] - c[j] as f64).powi(2)).sum();
            let dq: f64 = (0..8).map(|i| (q[i] - u[i] as f64).powi(2)).sum();
            assert!((dz - dq).abs() < 1e-9, "{dz} vs {dq}");
        });
    }
}
