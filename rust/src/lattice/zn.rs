//! The cubic lattice `Z^n` (Table 1 baseline).
//!
//! Unimodular by construction; packing radius 1/2, covering radius
//! `sqrt(n)/2`.  Kernel-support counting enumerates integer points in an
//! open ball by pruned DFS (the ball for the Table-1 radius holds ~1e3
//! points in 8D).

/// Packing radius of unimodular Z^n.
pub const fn packing_radius() -> f64 {
    0.5
}

/// Covering radius of unimodular Z^n.
pub fn covering_radius(n: usize) -> f64 {
    (n as f64).sqrt() / 2.0
}

/// Nearest point of Z^n.
pub fn quantize(q: &[f64]) -> Vec<i64> {
    q.iter().map(|v| v.round_ties_even() as i64).collect()
}

/// Count lattice points of Z^n within open ball of radius^2 `r2` of `q`.
pub fn count_in_ball(q: &[f64], r2: f64) -> usize {
    let n = q.len();
    let r = r2.sqrt();
    // per-coordinate candidate offsets, sorted by closeness for pruning
    let mut cands: Vec<Vec<(f64, i64)>> = Vec::with_capacity(n);
    for &qi in q {
        let lo = (qi - r).ceil() as i64;
        let hi = (qi + r).floor() as i64;
        let mut v: Vec<(f64, i64)> = (lo..=hi).map(|x| ((x as f64 - qi).powi(2), x)).collect();
        // total_cmp: the keys are squared offsets (never NaN), and a
        // typed total order beats an unwrap on partial_cmp regardless
        v.sort_by(|a, b| a.0.total_cmp(&b.0));
        cands.push(v);
    }
    fn dfs(cands: &[Vec<(f64, i64)>], depth: usize, d2: f64, r2: f64) -> usize {
        if depth == cands.len() {
            return 1;
        }
        let mut count = 0;
        for &(c2, _) in &cands[depth] {
            let nd = d2 + c2;
            if nd >= r2 {
                break; // sorted by closeness: the rest are farther
            }
            count += dfs(cands, depth + 1, nd, r2);
        }
        count
    }
    dfs(&cands, 0, 0.0, r2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn quantize_is_rounding() {
        assert_eq!(quantize(&[0.4, -0.6, 2.5, 3.49]), vec![0, -1, 2, 3]);
    }

    #[test]
    fn ball_count_at_origin() {
        // open ball radius sqrt(2) around origin in Z^2: (0,0) and 4 axis
        // neighbours = 5 points
        assert_eq!(count_in_ball(&[0.0, 0.0], 2.0 - 1e-12), 5);
        // radius^2 = 2 + eps also captures the 4 diagonal points
        assert_eq!(count_in_ball(&[0.0, 0.0], 2.0 + 1e-9), 9);
    }

    #[test]
    fn ball_count_translation_invariant() {
        forall(100, |rng| {
            let q: Vec<f64> = (0..4).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let shifted: Vec<f64> = q.iter().map(|v| v + 7.0).collect();
            assert_eq!(count_in_ball(&q, 3.7), count_in_ball(&shifted, 3.7));
        });
    }

    #[test]
    fn z8_kernel_support_range_matches_paper() {
        // Table 1: Z^8 kernel radius = sqrt(2) * cov = 2 (open ball).
        // MC min 768, analytic avg 1039, MC max 1312.
        let mut rng = crate::util::rng::Rng::new(99);
        let (mut lo, mut hi, mut sum) = (usize::MAX, 0usize, 0usize);
        let n = 3000;
        for _ in 0..n {
            let q: Vec<f64> = (0..8).map(|_| rng.uniform(0.0, 1.0)).collect();
            let c = count_in_ball(&q, 4.0);
            lo = lo.min(c);
            hi = hi.max(c);
            sum += c;
        }
        let avg = sum as f64 / n as f64;
        assert!((avg - 1039.0).abs() < 25.0, "avg {avg}");
        assert!(lo >= 768, "min {lo}");
        assert!(hi <= 1312, "max {hi}");
    }
}
