//! Production HTTP/1.1 front door for the serving router (std::net;
//! tokio is unavailable offline).
//!
//! The seed server spawned one thread per connection and closed the
//! socket after every response, so under concurrent load the engine's
//! fused lookup idled behind connection churn.  This front door is the
//! shape production serving actually needs:
//!
//! * **fixed worker pool** — `workers` threads own connections taken
//!   from a **bounded accept queue** (`conn_backlog`); when the queue is
//!   full, new connections are shed immediately with a well-formed
//!   `429 Too Many Requests` + `Retry-After` instead of piling into an
//!   unbounded backlog,
//! * **persistent keep-alive connections** — each worker runs a
//!   pipelined request loop per connection (requests already buffered
//!   are served back-to-back), honours `Connection: close`, and closes
//!   idle connections after `keep_alive_timeout`,
//! * **bounded admission** in front of the batcher — `/predict` goes
//!   through [`Batcher::submit_bounded`]; once `max_pending` requests
//!   are in flight the batcher sheds and the front door answers 429
//!   with `Retry-After`, so overload degrades into fast, explicit
//!   rejections rather than a latency collapse,
//! * **graceful drain** — [`Server::shutdown`] stops the acceptor,
//!   lets every in-flight request complete (workers finish the current
//!   response, the batcher finishes the current batch), then joins all
//!   threads.  [`Server::drain_on_termination`] wires SIGTERM/SIGINT
//!   (vendored-libc `sigaction`) to the same drain, which is how
//!   [`serve_until_signaled`] — the `lram serve` daemon loop — exits,
//! * **adaptive `Retry-After`** — every 429 carries a back-off estimate
//!   from live queue depth × measured mean batch latency
//!   ([`Batcher::retry_after_secs`]), so well-behaved clients back off
//!   proportionally to actual overload.
//!
//! Workers are *supervised*: a panic anywhere in the parse/serve path is
//! caught at the connection boundary (`catch_unwind`), counted in
//! `/stats.worker_panics`, and kills only that connection — the pool
//! never silently shrinks.  A panic inside request routing still writes
//! a well-formed 503 before the connection closes; a hung socket is
//! never the failure mode.
//!
//! Endpoints (full contract in `docs/api.md`):
//!   POST /v1/predict  {"text": "... [MASK] ...", "top_k": 5}
//!   POST /predict     compatibility alias for /v1/predict
//!   GET  /healthz     liveness: 200 while the process serves at all
//!   GET  /readyz      readiness: 200 only in the `ready` health state
//!   GET  /stats       batching, latency percentiles, queue/shed/connection
//!                     counters, health state, restarts, memory observability
//!                     (schema_version 1, per-shard breakdown under "shards")
//!
//! Every 4xx/5xx body is the structured envelope
//! `{"error": {"code", "message", "retry_after_s"?}}` built by
//! [`error_body`] — one helper, one shape, no ad-hoc error JSON.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context as _, Result};

use crate::tokenizer::Bpe;
use crate::util::failpoint;
use crate::util::json::{self, Json};
use crate::util::lockcheck::{rank, Mutex};

use super::api::PredictRequest;
use super::batcher::{Batcher, Health, HealthState, SubmitError};

/// Socket-level read poll interval: short enough that idle workers
/// notice shutdown and keep-alive deadlines promptly.
const READ_POLL: Duration = Duration::from_millis(250);
/// A stuck or dead client must not pin a worker on write.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Request-line / header-line length cap.
const MAX_LINE_BYTES: usize = 8 << 10;
/// Header count cap per request.
const MAX_HEADERS: usize = 100;

/// Front-door tunables (`--http-workers`, `--keep-alive-timeout`; the
/// admission cap lives in [`super::BatcherConfig::max_pending`]).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Fixed worker-pool size; each worker serves one connection at a
    /// time, so this bounds concurrent keep-alive connections.
    pub workers: usize,
    /// Idle keep-alive connections are closed after this long.
    pub keep_alive_timeout: Duration,
    /// Accepted connections waiting for a free worker; beyond this the
    /// acceptor sheds with 429 + `Retry-After`.
    pub conn_backlog: usize,
    /// Request bodies larger than this are rejected with 413.
    pub max_body_bytes: usize,
    /// Once a request line has arrived, the rest of the request (headers
    /// + body) must arrive within this window or the client gets 408 and
    /// the worker slot is freed — a half-sent request must not wedge a
    /// worker.
    pub request_deadline: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            workers: 32,
            keep_alive_timeout: Duration::from_secs(5),
            conn_backlog: 256,
            max_body_bytes: 1 << 20,
            request_deadline: Duration::from_secs(10),
        }
    }
}

/// Front-door counters, surfaced in `/stats` next to the batcher's.
#[derive(Debug, Default)]
pub struct HttpStats {
    pub connections_accepted: AtomicU64,
    /// connections shed at accept time (worker queue full)
    pub connections_shed: AtomicU64,
    pub active_connections: AtomicUsize,
    /// requests served over all connections (keep-alive reuse shows up
    /// as `http_requests` ≫ `connections_accepted`)
    pub requests: AtomicU64,
    /// panics caught at the connection boundary; a nonzero value means a
    /// worker hit a bug but the pool survived it
    pub worker_panics: AtomicU64,
}

/// A running front door.  Dropping the handle does *not* stop the
/// server; call [`Server::shutdown`] for a graceful drain or
/// [`Server::join`] to block forever (daemon mode).
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    http: Arc<HttpStats>,
    health: Arc<Health>,
}

/// Clonable trigger for a graceful drain from another thread.
#[derive(Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    health: Arc<Health>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        // flip readiness first so load balancers stop routing here while
        // in-flight requests finish draining
        self.health.set_draining();
        // ORDERING: SeqCst so the drain flag is globally ordered after
        // set_draining above — every thread that sees the flag also sees
        // the draining health state; shutdown is cold, so the fence is free
        self.flag.store(true, Ordering::SeqCst);
    }
}

impl Server {
    /// Bind and start the worker pool.  `addr` may use port 0 to bind an
    /// ephemeral port (see [`Server::local_addr`]).
    pub fn bind(
        addr: &str,
        batcher: Arc<Batcher>,
        bpe: Arc<Bpe>,
        cfg: HttpConfig,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http listener on {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let workers = cfg.workers.max(1);
        let shutdown = Arc::new(AtomicBool::new(false));
        let http = Arc::new(HttpStats::default());
        let health = batcher.health_handle();
        let router = Arc::new(Router {
            batcher,
            bpe,
            http: http.clone(),
            workers,
            keep_alive_timeout: cfg.keep_alive_timeout,
            max_body_bytes: cfg.max_body_bytes,
            request_deadline: cfg.request_deadline,
        });
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(cfg.conn_backlog.max(1));
        let conn_rx = Arc::new(Mutex::new(rank::HTTP_CONN_QUEUE, conn_rx));
        let mut threads = Vec::with_capacity(workers + 1);
        for i in 0..workers {
            let rx = conn_rx.clone();
            let router = router.clone();
            let shutdown = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("http-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &router, &shutdown))?,
            );
        }
        {
            let shutdown = shutdown.clone();
            let router = router.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("http-acceptor".into())
                    .spawn(move || acceptor_loop(&listener, &conn_tx, &router, &shutdown))?,
            );
        }
        log::info!(
            "serving on http://{local} ({workers} workers, keep-alive {:.0}s, \
             conn backlog {}, admission cap {})",
            cfg.keep_alive_timeout.as_secs_f64(),
            cfg.conn_backlog.max(1),
            router.batcher.max_pending()
        );
        Ok(Server { addr: local, shutdown, threads, http, health })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Front-door counters (shared with the worker threads).
    pub fn http_stats(&self) -> Arc<HttpStats> {
        self.http.clone()
    }

    /// A clonable handle that can trigger a graceful drain while some
    /// other thread blocks in [`Server::join`].
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { flag: self.shutdown.clone(), health: self.health.clone() }
    }

    /// Wire SIGTERM/SIGINT to a graceful drain (ROADMAP PR-4 "SIGTERM →
    /// graceful drain"): when either signal arrives, the acceptor stops,
    /// in-flight requests complete, and [`Server::join`] returns.  The
    /// vendored-libc `sigaction` handler only sets an atomic flag; the
    /// watcher thread spawned here turns the flag into the drain.  The
    /// flag is process-global and one-shot — exactly the semantics of
    /// termination.
    pub fn drain_on_termination(&self) -> Result<()> {
        let flag = crate::util::signal::termination_flag();
        let server_down = self.shutdown.clone();
        let handle = self.shutdown_handle();
        // detached by design, but not leaked: the watcher also exits
        // when the server is shut down programmatically, so embedders
        // that never receive a signal don't keep a polling thread (and
        // a ShutdownHandle) alive per server
        let _watcher = std::thread::Builder::new()
            .name("signal-watcher".into())
            .spawn(move || {
                // ORDERING: both flags are polled booleans on a 50ms
                // loop; relaxed staleness costs at most one extra poll
                while !flag.load(Ordering::Relaxed) {
                    if server_down.load(Ordering::Relaxed) {
                        return; // server stopped without a signal
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
                log::info!("termination signal received: draining in-flight requests");
                handle.shutdown();
            })
            .context("spawning the signal watcher")?;
        Ok(())
    }

    /// Graceful drain: stop accepting, let in-flight requests (and the
    /// batches carrying them) complete, close connections, join all
    /// threads.
    pub fn shutdown(mut self) {
        self.health.set_draining();
        // ORDERING: SeqCst pairs with ShutdownHandle::shutdown — the
        // drain flag must be ordered after the draining health state
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block until the server stops (i.e. until a [`ShutdownHandle`]
    /// fires — or forever in daemon mode).
    pub fn join(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Serve until the process is killed (daemon entry point used by `lram
/// serve` and the examples).
pub fn serve(addr: &str, batcher: Arc<Batcher>, bpe: Arc<Bpe>) -> Result<()> {
    serve_with(addr, batcher, bpe, HttpConfig::default())
}

/// [`serve`] with explicit front-door tunables.
pub fn serve_with(
    addr: &str,
    batcher: Arc<Batcher>,
    bpe: Arc<Bpe>,
    cfg: HttpConfig,
) -> Result<()> {
    Server::bind(addr, batcher, bpe, cfg)?.join();
    Ok(())
}

/// Daemon entry point for `lram serve`: serve until SIGTERM or SIGINT
/// arrives, then drain gracefully (in-flight requests complete) and
/// return — so `kill <pid>` and an init system's stop both end the
/// process cleanly instead of dropping mid-flight work.
pub fn serve_until_signaled(
    addr: &str,
    batcher: Arc<Batcher>,
    bpe: Arc<Bpe>,
    cfg: HttpConfig,
) -> Result<()> {
    let server = Server::bind(addr, batcher, bpe, cfg)?;
    server.drain_on_termination()?;
    server.join();
    log::info!("drained cleanly; exiting");
    Ok(())
}

// -- acceptor --------------------------------------------------------------

fn acceptor_loop(
    listener: &TcpListener,
    conn_tx: &SyncSender<TcpStream>,
    router: &Router,
    shutdown: &AtomicBool,
) {
    // conn_tx is dropped when this loop exits, which is what lets idle
    // workers drain the queue and stop
    loop {
        // ORDERING: polled drain flag; a stale read delays the acceptor
        // exit by one accept-loop iteration at most
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // ORDERING: /stats counters — atomicity without fences
                router.http.connections_accepted.fetch_add(1, Ordering::Relaxed);
                match conn_tx.try_send(stream) {
                    Ok(()) => {}
                    Err(TrySendError::Full(stream)) => {
                        // every worker busy and the backlog full: shed at
                        // the door with a well-formed 429 instead of
                        // queueing unboundedly
                        // ORDERING: /stats counter
                        router.http.connections_shed.fetch_add(1, Ordering::Relaxed);
                        shed_connection(stream, router.batcher.retry_after_secs());
                    }
                    Err(TrySendError::Disconnected(_)) => return,
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Best-effort 429 to a connection we cannot serve; errors are ignored
/// (the peer may already be gone).  The brief post-response drain keeps
/// the close from turning into a TCP reset that wipes the 429 on the
/// client side (the peer usually has its request in flight already);
/// its tight read timeout bounds how long a shed can stall the
/// acceptor — under sustained overload that stall is itself
/// backpressure on the accept rate.
fn shed_connection(mut stream: TcpStream, retry_after_secs: u64) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let body = error_body(
        429,
        "server overloaded: connection backlog full",
        Some(retry_after_secs.max(1)),
    );
    let _ = respond(&mut stream, 429, &body, true, 0, retry_after_secs);
    drain_briefly(&mut stream);
}

// -- workers ---------------------------------------------------------------

fn worker_loop(rx: &Mutex<Receiver<TcpStream>>, router: &Router, shutdown: &AtomicBool) {
    loop {
        // hold the lock only while waiting; a poisoned lock (panicked
        // sibling) must not take the whole pool down
        let next = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv_timeout(Duration::from_millis(100))
        };
        match next {
            Ok(stream) => {
                router.http.active_connections.fetch_add(1, Ordering::AcqRel);
                // supervise the connection: a panic anywhere in the
                // parse/serve path kills this connection, not this
                // worker thread — otherwise each panic would silently
                // shrink the pool until nothing serves
                match catch_unwind(AssertUnwindSafe(|| handle_connection(stream, router, shutdown)))
                {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => log::debug!("connection error: {e:#}"),
                    Err(_) => {
                        // ORDERING: /stats counter
                        router.http.worker_panics.fetch_add(1, Ordering::Relaxed);
                        log::error!(
                            "http worker caught a panic serving a connection; \
                             connection dropped, worker continues"
                        );
                    }
                }
                router.http.active_connections.fetch_sub(1, Ordering::AcqRel);
            }
            Err(RecvTimeoutError::Timeout) => {
                // ORDERING: polled drain flag, re-checked every 100ms
                if shutdown.load(Ordering::Relaxed) {
                    return;
                }
            }
            // acceptor gone and queue drained
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// The per-connection keep-alive request loop.
fn handle_connection(stream: TcpStream, router: &Router, shutdown: &AtomicBool) -> Result<()> {
    // accepted sockets inherit the listener's non-blocking mode on
    // BSD/macOS/Windows, which would defeat SO_RCVTIMEO and busy-spin
    // the poll loop; force blocking mode first (no-op on Linux)
    stream.set_nonblocking(false)?;
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(READ_POLL))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let keep_alive_secs = router.keep_alive_timeout.as_secs().max(1);
    loop {
        let req = match read_request(
            &mut reader,
            router.keep_alive_timeout,
            router.request_deadline,
            shutdown,
            router.max_body_bytes,
        ) {
            Ok(req) => req,
            // clean end of a keep-alive connection: peer closed between
            // requests, idle past the deadline, or server draining
            Err(ReadError::Idle) => return Ok(()),
            Err(ReadError::Bad { status, message }) => {
                let body = error_body(status, &message, None);
                let _ = respond(&mut stream, status, &body, true, 0, 0);
                // drain what the client is still sending (e.g. the body
                // of an oversized POST) before closing, so the error
                // response isn't wiped out by a TCP reset on unread data
                drain_briefly(&mut reader);
                return Ok(());
            }
            Err(ReadError::Io(e)) => {
                return Err(anyhow!(e).context("reading request"));
            }
        };
        // ORDERING: /stats counter
        router.http.requests.fetch_add(1, Ordering::Relaxed);
        // supervise routing separately from the connection loop: a panic
        // while handling a parsed request still owes the client a
        // well-formed response — 503 + close, never a silently dropped
        // socket with a request outstanding
        let routed = catch_unwind(AssertUnwindSafe(|| {
            if let Some(e) = failpoint::inject("http.worker") {
                let retry = router.batcher.retry_after_secs().max(1);
                return (503, error_body(503, &format!("{e:#}"), Some(retry)));
            }
            router.route(&req)
        }));
        let panicked = routed.is_err();
        let (status, body) = routed.unwrap_or_else(|_| {
            // ORDERING: /stats counter
            router.http.worker_panics.fetch_add(1, Ordering::Relaxed);
            log::error!("request handler panicked; answering 503 and closing the connection");
            let retry = router.batcher.retry_after_secs().max(1);
            (
                503,
                error_body(
                    503,
                    "request handler panicked; retry on a fresh connection",
                    Some(retry),
                ),
            )
        });
        // shed and not-ready responses tell the client when to come
        // back, from live queue depth x measured batch latency
        let retry =
            if status == 429 || status == 503 { router.batcher.retry_after_secs() } else { 0 };
        // a draining server finishes this response, then closes; so does
        // a worker that just caught a panic (its connection state is
        // suspect)
        // ORDERING: polled drain flag; one stale keep-alive round-trip
        // during a drain is harmless (the next request re-checks)
        let close = !req.keep_alive || panicked || shutdown.load(Ordering::Relaxed);
        respond(&mut stream, status, &body, close, keep_alive_secs, retry)
            .map_err(|e| anyhow!(e).context("writing response"))?;
        if close {
            return Ok(());
        }
    }
}

// -- request parsing -------------------------------------------------------

#[derive(Debug)]
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    body: Vec<u8>,
}

#[derive(Debug)]
enum ReadError {
    /// Clean end of the connection: EOF between requests, idle past the
    /// keep-alive deadline, or shutdown while idle.
    Idle,
    /// The peer sent something we must reject; respond and close.
    Bad { status: u16, message: String },
    /// Transport failure mid-request; close without responding.
    Io(std::io::Error),
}

fn transient(kind: ErrorKind) -> bool {
    matches!(kind, ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted)
}

/// Best-effort, bounded read-and-discard of whatever the peer is still
/// sending, so closing after an error response doesn't turn into a TCP
/// reset that discards the response on the client side.  Capped in both
/// bytes and wall time; all errors end the drain.
fn drain_briefly<R: Read>(r: &mut R) {
    const DRAIN_CAP_BYTES: usize = 256 << 10;
    const DRAIN_CAP_TIME: Duration = Duration::from_millis(300);
    let deadline = Instant::now() + DRAIN_CAP_TIME;
    let mut scratch = [0u8; 8192];
    let mut drained = 0usize;
    while drained < DRAIN_CAP_BYTES && Instant::now() < deadline {
        match r.read(&mut scratch) {
            Ok(0) => return,
            Ok(n) => drained += n,
            Err(_) => return,
        }
    }
}

/// Read one CRLF-terminated line through `fill_buf`/`consume`, riding
/// out socket read timeouts until `deadline`.  `idle_ok` marks the
/// between-requests wait, where EOF / deadline / shutdown are a clean
/// [`ReadError::Idle`] rather than an error.
fn read_line_bounded<R: BufRead>(
    r: &mut R,
    deadline: Instant,
    shutdown: &AtomicBool,
    idle_ok: bool,
) -> Result<String, ReadError> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (consumed, done) = {
            let buf = match r.fill_buf() {
                Ok(b) => b,
                Err(e) if transient(e.kind()) => {
                    // ORDERING: polled drain flag, re-read every
                    // READ_POLL tick while the connection idles
                    if line.is_empty() && idle_ok && shutdown.load(Ordering::Relaxed) {
                        return Err(ReadError::Idle);
                    }
                    if Instant::now() >= deadline {
                        return if line.is_empty() && idle_ok {
                            Err(ReadError::Idle)
                        } else {
                            Err(ReadError::Bad {
                                status: 408,
                                message: "request timed out".into(),
                            })
                        };
                    }
                    continue;
                }
                Err(e) => return Err(ReadError::Io(e)),
            };
            if buf.is_empty() {
                // EOF: clean between requests, fatal mid-request
                return if line.is_empty() && idle_ok {
                    Err(ReadError::Idle)
                } else {
                    Err(ReadError::Io(std::io::Error::new(
                        ErrorKind::UnexpectedEof,
                        "connection closed mid-request",
                    )))
                };
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    line.extend_from_slice(&buf[..pos]);
                    (pos + 1, true)
                }
                None => {
                    line.extend_from_slice(buf);
                    (buf.len(), false)
                }
            }
        };
        r.consume(consumed);
        if line.len() > MAX_LINE_BYTES {
            return Err(ReadError::Bad {
                status: 431,
                message: format!("request line exceeds {MAX_LINE_BYTES} bytes"),
            });
        }
        // enforce the deadline on successful reads too: a slow-drip
        // client that keeps one byte per poll flowing must not be able
        // to pin a worker past the request deadline
        if !done && Instant::now() >= deadline {
            return Err(ReadError::Bad { status: 408, message: "request timed out".into() });
        }
        if done {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return String::from_utf8(line).map_err(|_| ReadError::Bad {
                status: 400,
                message: "request is not utf-8".into(),
            });
        }
    }
}

fn read_exact_bounded<R: BufRead>(
    r: &mut R,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<(), ReadError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(ReadError::Io(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-body",
                )))
            }
            Ok(n) => {
                filled += n;
                // slow-drip bodies must hit the deadline even when
                // every read succeeds
                if filled < buf.len() && Instant::now() >= deadline {
                    return Err(ReadError::Bad {
                        status: 408,
                        message: "request body timed out".into(),
                    });
                }
            }
            Err(e) if transient(e.kind()) => {
                if Instant::now() >= deadline {
                    return Err(ReadError::Bad {
                        status: 408,
                        message: "request body timed out".into(),
                    });
                }
            }
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
    Ok(())
}

/// Parse one HTTP/1.x request off the connection.  Keep-alive defaults
/// on for HTTP/1.1 and off for HTTP/1.0; a `Connection` header
/// overrides either way.
fn read_request<R: BufRead>(
    r: &mut R,
    idle_timeout: Duration,
    request_deadline: Duration,
    shutdown: &AtomicBool,
    max_body: usize,
) -> Result<HttpRequest, ReadError> {
    let idle_deadline = Instant::now() + idle_timeout;
    let line = read_line_bounded(r, idle_deadline, shutdown, true)?;
    // the request line is in: the rest must arrive promptly
    let deadline = Instant::now() + request_deadline;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/") {
        return Err(ReadError::Bad {
            status: 400,
            message: format!("malformed request line '{line}'"),
        });
    }
    let mut keep_alive = version == "HTTP/1.1";
    let mut content_length = 0usize;
    let mut headers_done = false;
    // one extra iteration so exactly MAX_HEADERS headers (plus the
    // terminating blank line) are accepted
    for _ in 0..=MAX_HEADERS {
        let h = read_line_bounded(r, deadline, shutdown, false)?;
        if h.is_empty() {
            headers_done = true;
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().map_err(|_| ReadError::Bad {
                    status: 400,
                    message: format!("bad Content-Length '{value}'"),
                })?;
            } else if name.eq_ignore_ascii_case("connection") {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
        }
    }
    if !headers_done {
        return Err(ReadError::Bad {
            status: 431,
            message: format!("more than {MAX_HEADERS} request headers"),
        });
    }
    if content_length > max_body {
        return Err(ReadError::Bad {
            status: 413,
            message: format!("request body of {content_length} bytes exceeds {max_body}"),
        });
    }
    let mut body = vec![0u8; content_length];
    read_exact_bounded(r, &mut body, deadline)?;
    Ok(HttpRequest { method, path, keep_alive, body })
}

// -- routing ---------------------------------------------------------------

struct Router {
    batcher: Arc<Batcher>,
    bpe: Arc<Bpe>,
    http: Arc<HttpStats>,
    workers: usize,
    keep_alive_timeout: Duration,
    max_body_bytes: usize,
    request_deadline: Duration,
}

impl Router {
    fn route(&self, req: &HttpRequest) -> (u16, String) {
        match (req.method.as_str(), req.path.as_str()) {
            // liveness: 200 whenever the process can answer at all —
            // restarting into degraded still means "don't kill me"
            ("GET", "/healthz") => {
                let state = self.batcher.health().state();
                (200, format!(r#"{{"ok": true, "state": "{}"}}"#, state.as_str()))
            }
            // readiness: 200 only when the executor is up and serving;
            // a degraded/draining instance tells the balancer to route
            // elsewhere without being restarted
            ("GET", "/readyz") => {
                let state = self.batcher.health().state();
                if state == HealthState::Ready {
                    (200, format!(r#"{{"state": "{}"}}"#, state.as_str()))
                } else {
                    let retry = self.batcher.retry_after_secs().max(1);
                    let msg = format!("not ready (state {})", state.as_str());
                    (503, error_body(503, &msg, Some(retry)))
                }
            }
            ("GET", "/stats") => (200, self.stats_json()),
            // /v1/predict is the canonical route (docs/api.md); the
            // unversioned path stays as a compatibility alias
            ("POST", "/predict") | ("POST", "/v1/predict") => self.predict(&req.body),
            _ => (404, error_body(404, "not found", None)),
        }
    }

    fn predict(&self, body: &[u8]) -> (u16, String) {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => return (400, error_body(400, "body is not utf-8", None)),
        };
        let parsed = json::parse(text)
            .map_err(|e| anyhow!(e))
            .and_then(|v| PredictRequest::from_json(&v));
        let req = match parsed {
            Ok(r) => r,
            Err(e) => return (400, error_body(400, &format!("{e:#}"), None)),
        };
        // the retryable statuses mirror Retry-After into the body so
        // JSON-only clients can back off without parsing headers
        let retry = || Some(self.batcher.retry_after_secs().max(1));
        match self.batcher.submit_bounded(&self.bpe, &req) {
            Ok(resp) => (200, resp.to_json().to_string()),
            Err(SubmitError::BadRequest(m)) => (400, error_body(400, &m, None)),
            Err(e @ SubmitError::Overloaded { .. }) => {
                (429, error_body(429, &e.to_string(), retry()))
            }
            // executor died mid-request and the supervisor is restarting
            // it: retryable, so 503 (+ Retry-After), not 500
            Err(e @ SubmitError::Unavailable(_)) => {
                (503, error_body(503, &e.to_string(), retry()))
            }
            // the request expired in queue before the backend saw it
            Err(e @ SubmitError::Timeout { .. }) => {
                (504, error_body(504, &e.to_string(), None))
            }
            Err(SubmitError::Internal(m)) => (500, error_body(500, &m, None)),
        }
    }

    fn stats_json(&self) -> String {
        let s = self.batcher.stats_snapshot();
        let health = self.batcher.health();
        let mean_req = if s.requests > 0 {
            s.total_request_latency_ms / s.requests as f64
        } else {
            0.0
        };
        let mean_exec =
            if s.batches > 0 { s.total_exec_latency_ms / s.batches as f64 } else { 0.0 };
        let memory = match &s.memory {
            Some(m) => {
                let shards = m
                    .per_shard
                    .iter()
                    .map(|p| {
                        format!(
                            r#"{{"shard": {}, "rows": {}, "hits": {}, "utilization": {:.6}}}"#,
                            p.shard, p.rows, p.hits, p.utilization
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    r#", "memory_utilization": {:.6}, "memory_kl": {:.6}, "shards": [{shards}]"#,
                    m.utilization, m.kl_from_uniform
                )
            }
            None => String::new(),
        };
        // which trained weights are live (absent on seed/artifact);
        // the id comes from a user-editable manifest, so emit it
        // through the JSON writer rather than raw interpolation
        let checkpoint = match &s.checkpoint {
            Some(id) => {
                format!(r#", "checkpoint": {}"#, Json::Str(id.clone()).to_string())
            }
            None => String::new(),
        };
        format!(
            r#"{{"schema_version": 1, "backend": "{}", "state": "{}", "restarts": {}, "requests": {}, "batches": {}, "mean_request_latency_ms": {:.3}, "mean_exec_latency_ms": {:.3}, "latency_p50_ms": {:.3}, "latency_p95_ms": {:.3}, "latency_p99_ms": {:.3}, "max_batch_fill": {}, "truncated_masks": {}, "timeouts": {}, "shed": {}, "queue_depth": {}, "max_pending": {}, "http_workers": {}, "active_connections": {}, "connections_accepted": {}, "connections_shed": {}, "http_requests": {}, "worker_panics": {}{}{}}}"#,
            s.backend,
            health.state().as_str(),
            health.restarts(),
            s.requests,
            s.batches,
            mean_req,
            mean_exec,
            s.latency.percentile_ms(0.50),
            s.latency.percentile_ms(0.95),
            s.latency.percentile_ms(0.99),
            s.max_batch_fill,
            s.truncated_masks,
            s.timeouts,
            s.shed,
            self.batcher.queue_depth(),
            self.batcher.max_pending(),
            self.workers,
            // ORDERING: /stats snapshot reads of monotonic counters; the
            // report is advisory and needs no cross-counter consistency
            self.http.active_connections.load(Ordering::Relaxed),
            self.http.connections_accepted.load(Ordering::Relaxed),
            self.http.connections_shed.load(Ordering::Relaxed),
            self.http.requests.load(Ordering::Relaxed),
            self.http.worker_panics.load(Ordering::Relaxed),
            memory,
            checkpoint
        )
    }
}

// -- responses -------------------------------------------------------------

/// Machine-readable error code, one per status the front door emits —
/// the stable half of the error contract (`docs/api.md`): messages are
/// for humans and may change, codes are for clients and must not.
fn error_code(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        408 => "request_timeout",
        413 => "payload_too_large",
        429 => "overloaded",
        431 => "headers_too_large",
        503 => "unavailable",
        504 => "deadline_exceeded",
        _ => "internal",
    }
}

/// The single source of every 4xx/5xx body:
/// `{"error": {"code", "message", "retry_after_s"?}}`.  `retry_after_s`
/// mirrors the `Retry-After` header on retryable statuses so JSON-only
/// clients never need to parse headers.
fn error_body(status: u16, message: &str, retry_after_s: Option<u64>) -> String {
    let mut fields = vec![
        ("code", Json::Str(error_code(status).to_string())),
        ("message", Json::Str(message.to_string())),
    ];
    if let Some(s) = retry_after_s {
        fields.push(("retry_after_s", Json::Num(s as f64)));
    }
    Json::obj(vec![("error", Json::obj(fields))]).to_string()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    }
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    close: bool,
    keep_alive_secs: u64,
    retry_after_secs: u64,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        reason(status),
        body.len()
    );
    if status == 429 || status == 503 {
        // adaptive back-off (queue depth x mean batch latency); the
        // floor of 1 keeps the header meaningful even with no history.
        // 503s carry it too: "executor restarting" and "not ready" are
        // both retryable conditions with a meaningful come-back time
        head.push_str(&format!("Retry-After: {}\r\n", retry_after_secs.max(1)));
    }
    if close {
        head.push_str("Connection: close\r\n\r\n");
    } else {
        head.push_str(&format!(
            "Connection: keep-alive\r\nKeep-Alive: timeout={keep_alive_secs}\r\n\r\n"
        ));
    }
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn no_shutdown() -> AtomicBool {
        AtomicBool::new(false)
    }

    fn parse(raw: &str) -> Result<HttpRequest, ReadError> {
        let mut c = Cursor::new(raw.as_bytes().to_vec());
        read_request(&mut c, Duration::from_secs(1), Duration::from_secs(1), &no_shutdown(), 1 << 20)
    }

    #[test]
    fn parses_post_with_body_and_keeps_alive_by_default() {
        let req = parse("POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello")
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn connection_close_is_honoured() {
        let req =
            parse("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.keep_alive);
    }

    #[test]
    fn http_10_defaults_to_close_but_can_opt_in() {
        assert!(!parse("GET / HTTP/1.0\r\n\r\n").unwrap().keep_alive);
        assert!(
            parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
                .unwrap()
                .keep_alive
        );
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nPOST /predict HTTP/1.1\r\nContent-Length: 2\r\n\r\nok";
        let mut c = Cursor::new(raw.as_bytes().to_vec());
        let s = no_shutdown();
        let t = Duration::from_secs(1);
        let a = read_request(&mut c, t, t, &s, 1 << 20).unwrap();
        assert_eq!(a.path, "/healthz");
        let b = read_request(&mut c, t, t, &s, 1 << 20).unwrap();
        assert_eq!(b.path, "/predict");
        assert_eq!(b.body, b"ok");
    }

    #[test]
    fn eof_between_requests_is_clean_idle() {
        match parse("") {
            Err(ReadError::Idle) => {}
            other => panic!("expected Idle, got {other:?}"),
        }
    }

    #[test]
    fn malformed_request_line_is_400() {
        match parse("NOT-HTTP\r\n\r\n") {
            Err(ReadError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn oversized_body_is_413_before_reading_it() {
        let mut c = Cursor::new(
            b"POST /predict HTTP/1.1\r\nContent-Length: 99\r\n\r\n".to_vec(),
        );
        match read_request(&mut c, Duration::from_secs(1), Duration::from_secs(1), &no_shutdown(), 10) {
            Err(ReadError::Bad { status: 413, .. }) => {}
            other => panic!("expected 413, got {other:?}"),
        }
    }

    #[test]
    fn bad_content_length_is_400() {
        match parse("POST /p HTTP/1.1\r\nContent-Length: nope\r\n\r\n") {
            Err(ReadError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn truncated_body_is_an_io_error() {
        match parse("POST /p HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort") {
            Err(ReadError::Io(e)) => assert_eq!(e.kind(), ErrorKind::UnexpectedEof),
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn error_body_is_the_structured_envelope_and_escapes_via_json_writer() {
        let b = error_body(400, "a \"quoted\" failure", None);
        let v = json::parse(&b).unwrap();
        let e = v.get("error").expect("envelope has an error object");
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "bad_request");
        assert_eq!(e.get("message").unwrap().as_str().unwrap(), "a \"quoted\" failure");
        assert!(e.get("retry_after_s").is_none(), "no retry hint unless retryable");
    }

    #[test]
    fn retryable_errors_mirror_retry_after_into_the_body() {
        let b = error_body(429, "overloaded", Some(7));
        let v = json::parse(&b).unwrap();
        let e = v.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(e.get("retry_after_s").unwrap().as_f64().unwrap(), 7.0);
        // each front-door status maps to a stable machine-readable code
        for (status, code) in [
            (400, "bad_request"),
            (404, "not_found"),
            (408, "request_timeout"),
            (413, "payload_too_large"),
            (429, "overloaded"),
            (431, "headers_too_large"),
            (500, "internal"),
            (503, "unavailable"),
            (504, "deadline_exceeded"),
        ] {
            assert_eq!(error_code(status), code);
        }
    }
}
