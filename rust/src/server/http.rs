//! Production HTTP/1.1 front door for the serving router (std::net +
//! `poll(2)`; tokio is unavailable offline).
//!
//! The previous front door ran a fixed pool of threads, each *owning*
//! one connection at a time and blocking in `fill_buf` between
//! requests.  That shape caps concurrent keep-alive connections at the
//! worker count: 10,000 mostly-idle clients would need 10,000 parked
//! threads.  This revision keeps every externally visible semantic and
//! replaces the thread-per-connection core with an **event-driven
//! readiness layer**:
//!
//! * **event loops, not connection owners** — `workers` threads each
//!   multiplex thousands of nonblocking keep-alive connections through
//!   [`crate::util::poll`].  A connection is a small state machine
//!   (reading head → reading body → dispatched to the batcher →
//!   writing the response), advanced only when its socket is ready,
//!   so idle connections cost one `pollfd`, not one thread,
//! * **self-pipe wakeups** — each loop owns a [`Waker`]; the acceptor
//!   wakes it to hand over new connections, and the batcher's executor
//!   wakes it when a dispatched request completes
//!   ([`Batcher::submit_bounded_async`]), so responses are written the
//!   moment they exist instead of on the next poll tick,
//! * **bounded admission at two layers** — the acceptor sheds beyond
//!   `max_connections` open connections (or a full per-loop intake
//!   queue, `conn_backlog`) with a well-formed `429 Too Many Requests`
//!   + `Retry-After`; `/predict` still goes through the batcher's
//!   `max_pending` admission cap and sheds with the same adaptive 429.
//!   The shed response is written *by an event loop*, never by the
//!   acceptor — a shed client that refuses to read its 429 can no
//!   longer stall `accept(2)` for everyone else,
//! * **persistent keep-alive connections** — pipelined requests are
//!   served back-to-back from the connection's buffer, `Connection:
//!   close` is honoured, and idle connections are closed after
//!   `keep_alive_timeout` by the loops' deadline sweep,
//! * **graceful drain** — [`Server::shutdown`] stops the acceptor,
//!   closes idle connections, lets every in-flight request complete
//!   (the batcher finishes the current batch, the loop writes the
//!   response), then joins all threads.  [`Server::drain_on_termination`]
//!   wires SIGTERM/SIGINT (vendored-libc `sigaction`) to the same
//!   drain, which is how [`serve_until_signaled`] — the `lram serve`
//!   daemon loop — exits,
//! * **adaptive `Retry-After`** — every 429 carries a back-off estimate
//!   from live queue depth × measured mean batch latency
//!   ([`Batcher::retry_after_secs`]), so well-behaved clients back off
//!   proportionally to actual overload.
//!
//! The loops are *supervised*: a panic anywhere in the parse/serve path
//! is caught at the connection boundary (`catch_unwind`), counted in
//! `/stats.worker_panics`, and kills only that connection — the loop
//! never dies.  A panic inside request routing still writes a
//! well-formed 503 before the connection closes; a hung socket is never
//! the failure mode.  `active_connections` is incremented at exactly
//! one place (admission, in the acceptor) and decremented at exactly
//! one place ([`release_admitted`], on teardown), so the gauge returns
//! to zero no matter which error or panic path closed the connection.
//!
//! Endpoints (full contract in `docs/api.md`):
//!   POST /v1/predict  {"text": "... [MASK] ...", "top_k": 5}
//!   POST /predict     compatibility alias for /v1/predict
//!   GET  /healthz     liveness: 200 while the process serves at all
//!   GET  /readyz      readiness: 200 only in the `ready` health state
//!   GET  /stats       batching, latency percentiles, queue/shed/connection
//!                     counters, health state, restarts, memory observability
//!                     (schema_version 1, per-shard breakdown under "shards")
//!
//! Every 4xx/5xx body is the structured envelope
//! `{"error": {"code", "message", "retry_after_s"?}}` built by
//! [`error_body`] — one helper, one shape, no ad-hoc error JSON.

use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context as _, Result};

use crate::tokenizer::Bpe;
use crate::util::failpoint;
use crate::util::json::{self, Json};
use crate::util::lockcheck::{rank, Mutex};
use crate::util::poll::{self, Waker, POLLIN, POLLOUT};

use super::api::PredictRequest;
use super::batcher::{Batcher, Health, HealthState, PendingReply, ReplyNotify, SubmitError};

/// Upper bound on how long an event loop sleeps in `poll(2)` with
/// nothing ready: deadline sweeps (keep-alive idle, request deadlines,
/// write timeouts) and the shutdown flag are re-checked at least this
/// often.  Wakeups (new connections, completed dispatches) interrupt
/// the sleep immediately via the self-pipe.
const POLL_TICK: Duration = Duration::from_millis(100);
/// A stuck or dead client must not pin its response buffer forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// A shed client gets less patience: the 429 write is best-effort.
const SHED_WRITE_TIMEOUT: Duration = Duration::from_secs(1);
/// Request-line / header-line length cap.
const MAX_LINE_BYTES: usize = 8 << 10;
/// Header count cap per request.
const MAX_HEADERS: usize = 100;
/// Socket read granularity for the nonblocking read path.
const READ_CHUNK: usize = 8192;
/// Post-error drain caps: read-and-discard at most this many bytes /
/// this long before closing, so the error response isn't wiped out by
/// a TCP reset on unread request data.
const DRAIN_CAP_BYTES: usize = 256 << 10;
const DRAIN_CAP_TIME: Duration = Duration::from_millis(300);

/// Front-door tunables (`--http-workers`, `--keep-alive-timeout`,
/// `--max-connections`; the request admission cap lives in
/// [`super::BatcherConfig::max_pending`]).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Number of event-loop threads.  Each multiplexes many nonblocking
    /// keep-alive connections, so this sizes CPU parallelism for
    /// parse/route work — not the connection cap (see
    /// [`HttpConfig::max_connections`]).
    pub workers: usize,
    /// Idle keep-alive connections are closed after this long.
    pub keep_alive_timeout: Duration,
    /// Per-loop bound on accepted connections parked in the intake
    /// queue awaiting adoption by the event loop; beyond it the
    /// acceptor sheds with 429 + `Retry-After`.
    pub conn_backlog: usize,
    /// Request bodies larger than this are rejected with 413.
    pub max_body_bytes: usize,
    /// Once a request line has arrived, the rest of the request (headers
    /// + body) must arrive within this window or the client gets 408 —
    /// a half-sent request must not occupy state forever.
    pub request_deadline: Duration,
    /// Hard cap on simultaneously open admitted connections across all
    /// loops; beyond it the acceptor sheds with 429 + `Retry-After`.
    pub max_connections: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            workers: 32,
            keep_alive_timeout: Duration::from_secs(5),
            conn_backlog: 256,
            max_body_bytes: 1 << 20,
            request_deadline: Duration::from_secs(10),
            max_connections: 16384,
        }
    }
}

/// Front-door counters, surfaced in `/stats` next to the batcher's.
#[derive(Debug, Default)]
pub struct HttpStats {
    pub connections_accepted: AtomicU64,
    /// connections shed at accept time (connection cap reached or the
    /// loops' intake queues full)
    pub connections_shed: AtomicU64,
    /// admitted connections currently open (adopted by an event loop or
    /// awaiting adoption); shed connections are never counted
    pub active_connections: AtomicUsize,
    /// requests served over all connections (keep-alive reuse shows up
    /// as `http_requests` ≫ `connections_accepted`)
    pub requests: AtomicU64,
    /// panics caught at the connection boundary; a nonzero value means
    /// the serving path hit a bug but the event loops survived it
    pub worker_panics: AtomicU64,
}

/// A running front door.  Dropping the handle does *not* stop the
/// server; call [`Server::shutdown`] for a graceful drain or
/// [`Server::join`] to block forever (daemon mode).
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    http: Arc<HttpStats>,
    health: Arc<Health>,
}

/// Clonable trigger for a graceful drain from another thread.
#[derive(Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    health: Arc<Health>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        // flip readiness first so load balancers stop routing here while
        // in-flight requests finish draining
        self.health.set_draining();
        // ORDERING: SeqCst so the drain flag is globally ordered after
        // set_draining above — every thread that sees the flag also sees
        // the draining health state; shutdown is cold, so the fence is free
        self.flag.store(true, Ordering::SeqCst);
    }
}

impl Server {
    /// Bind and start the acceptor + event loops.  `addr` may use port 0
    /// to bind an ephemeral port (see [`Server::local_addr`]).
    pub fn bind(
        addr: &str,
        batcher: Arc<Batcher>,
        bpe: Arc<Bpe>,
        cfg: HttpConfig,
    ) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http listener on {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let workers = cfg.workers.max(1);
        // best effort: lift the fd limit toward the admission cap plus
        // slack for wake pipes, the listener, and the rest of the
        // process.  A capped limit is not fatal — the acceptor simply
        // sheds once accept() hits EMFILE territory — but it deserves a
        // log line, because "why does my 10k box stall at 1024?" is the
        // question this answers.
        let want = cfg.max_connections.max(1) as u64 + 2 * workers as u64 + 64;
        match poll::raise_nofile_limit(want) {
            Ok(got) if got < want => log::warn!(
                "fd limit {got} is below max_connections + slack ({want}); \
                 connections past the limit will be shed"
            ),
            Ok(_) => {}
            Err(e) => log::warn!("could not read/raise RLIMIT_NOFILE: {e}"),
        }
        let shutdown = Arc::new(AtomicBool::new(false));
        let http = Arc::new(HttpStats::default());
        let health = batcher.health_handle();
        let router = Arc::new(Router {
            batcher,
            bpe,
            http: http.clone(),
            workers,
            keep_alive_timeout: cfg.keep_alive_timeout,
            max_body_bytes: cfg.max_body_bytes,
            request_deadline: cfg.request_deadline,
            max_connections: cfg.max_connections.max(1),
            conn_backlog: cfg.conn_backlog.max(1),
        });
        let mut loops = Vec::with_capacity(workers);
        for _ in 0..workers {
            loops.push(Arc::new(LoopShared {
                intake: Mutex::new(rank::HTTP_CONN_QUEUE, VecDeque::new()),
                completions: Mutex::new(rank::HTTP_LOOP_COMPLETIONS, Vec::new()),
                waker: Waker::new().context("creating an event-loop wake pipe")?,
            }));
        }
        let mut threads = Vec::with_capacity(workers + 1);
        for (i, shared) in loops.iter().enumerate() {
            let shared = shared.clone();
            let router = router.clone();
            let shutdown = shutdown.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("http-loop-{i}"))
                    .spawn(move || event_loop(&shared, &router, &shutdown))?,
            );
        }
        {
            let shutdown = shutdown.clone();
            let router = router.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("http-acceptor".into())
                    .spawn(move || acceptor_loop(&listener, &loops, &router, &shutdown))?,
            );
        }
        log::info!(
            "serving on http://{local} ({workers} event loops, keep-alive {:.0}s, \
             conn backlog {}, max connections {}, admission cap {})",
            cfg.keep_alive_timeout.as_secs_f64(),
            cfg.conn_backlog.max(1),
            cfg.max_connections.max(1),
            router.batcher.max_pending()
        );
        Ok(Server { addr: local, shutdown, threads, http, health })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Front-door counters (shared with the event-loop threads).
    pub fn http_stats(&self) -> Arc<HttpStats> {
        self.http.clone()
    }

    /// A clonable handle that can trigger a graceful drain while some
    /// other thread blocks in [`Server::join`].
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { flag: self.shutdown.clone(), health: self.health.clone() }
    }

    /// Wire SIGTERM/SIGINT to a graceful drain (ROADMAP PR-4 "SIGTERM →
    /// graceful drain"): when either signal arrives, the acceptor stops,
    /// in-flight requests complete, and [`Server::join`] returns.  The
    /// vendored-libc `sigaction` handler only sets an atomic flag; the
    /// watcher thread spawned here turns the flag into the drain.  The
    /// flag is process-global and one-shot — exactly the semantics of
    /// termination.
    pub fn drain_on_termination(&self) -> Result<()> {
        let flag = crate::util::signal::termination_flag();
        let server_down = self.shutdown.clone();
        let handle = self.shutdown_handle();
        // detached by design, but not leaked: the watcher also exits
        // when the server is shut down programmatically, so embedders
        // that never receive a signal don't keep a polling thread (and
        // a ShutdownHandle) alive per server
        let _watcher = std::thread::Builder::new()
            .name("signal-watcher".into())
            .spawn(move || {
                // ORDERING: both flags are polled booleans on a 50ms
                // loop; relaxed staleness costs at most one extra poll
                while !flag.load(Ordering::Relaxed) {
                    if server_down.load(Ordering::Relaxed) {
                        return; // server stopped without a signal
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
                log::info!("termination signal received: draining in-flight requests");
                handle.shutdown();
            })
            .context("spawning the signal watcher")?;
        Ok(())
    }

    /// Graceful drain: stop accepting, let in-flight requests (and the
    /// batches carrying them) complete, close connections, join all
    /// threads.
    pub fn shutdown(mut self) {
        self.health.set_draining();
        // ORDERING: SeqCst pairs with ShutdownHandle::shutdown — the
        // drain flag must be ordered after the draining health state
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block until the server stops (i.e. until a [`ShutdownHandle`]
    /// fires — or forever in daemon mode).
    pub fn join(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Serve until the process is killed (daemon entry point used by `lram
/// serve` and the examples).
pub fn serve(addr: &str, batcher: Arc<Batcher>, bpe: Arc<Bpe>) -> Result<()> {
    serve_with(addr, batcher, bpe, HttpConfig::default())
}

/// [`serve`] with explicit front-door tunables.
pub fn serve_with(
    addr: &str,
    batcher: Arc<Batcher>,
    bpe: Arc<Bpe>,
    cfg: HttpConfig,
) -> Result<()> {
    Server::bind(addr, batcher, bpe, cfg)?.join();
    Ok(())
}

/// Daemon entry point for `lram serve`: serve until SIGTERM or SIGINT
/// arrives, then drain gracefully (in-flight requests complete) and
/// return — so `kill <pid>` and an init system's stop both end the
/// process cleanly instead of dropping mid-flight work.
pub fn serve_until_signaled(
    addr: &str,
    batcher: Arc<Batcher>,
    bpe: Arc<Bpe>,
    cfg: HttpConfig,
) -> Result<()> {
    let server = Server::bind(addr, batcher, bpe, cfg)?;
    server.drain_on_termination()?;
    server.join();
    log::info!("drained cleanly; exiting");
    Ok(())
}

// -- event-loop plumbing ---------------------------------------------------

/// The cross-thread surface of one event loop: the acceptor pushes
/// connections into `intake`, the batcher's executor pushes finished
/// request tokens into `completions`, and both wake the loop's `poll`
/// through the self-pipe `waker`.
struct LoopShared {
    intake: Mutex<VecDeque<Intake>>,
    completions: Mutex<Vec<u64>>,
    waker: Waker,
}

/// What the acceptor hands an event loop.
enum Intake {
    /// An admitted connection (already counted in `active_connections`).
    Accepted(TcpStream),
    /// A connection shed at the door: write the pre-rendered 429 bytes,
    /// then close.  Writing happens here, on the event loop — the
    /// acceptor must never block on a client that won't read.
    Shed(TcpStream, Vec<u8>),
}

// -- acceptor --------------------------------------------------------------

fn acceptor_loop(
    listener: &TcpListener,
    loops: &[Arc<LoopShared>],
    router: &Router,
    shutdown: &AtomicBool,
) {
    let mut rr = 0usize;
    loop {
        // ORDERING: polled drain flag; a stale read delays the acceptor
        // exit by one accept-loop iteration at most
        if shutdown.load(Ordering::Relaxed) {
            // the loops poll the flag too, but a wake makes the drain
            // prompt instead of one POLL_TICK late
            for l in loops {
                l.waker.wake();
            }
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // ORDERING: /stats counters — atomicity without fences
                router.http.connections_accepted.fetch_add(1, Ordering::Relaxed);
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    // a socket we cannot make nonblocking would wedge an
                    // event loop; drop it (the peer sees a reset)
                    continue;
                }
                // admission: the gauge is incremented here — the single
                // admit point — and decremented only in release_admitted
                let active = router.http.active_connections.load(Ordering::Acquire);
                if active >= router.max_connections {
                    shed_connection(stream, loops, &mut rr, router);
                    continue;
                }
                router.http.active_connections.fetch_add(1, Ordering::AcqRel);
                if !hand_off(loops, &mut rr, router.conn_backlog, Intake::Accepted(stream)) {
                    // every loop's intake queue is full: undo the admit
                    // and drop — there is no capacity even for a polite 429
                    release_admitted(&router.http);
                    // ORDERING: /stats counter
                    router.http.connections_shed.fetch_add(1, Ordering::Relaxed);
                    log::debug!("intake queues full; dropping a connection");
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Shed a connection the cap refuses: render the 429 once, then hand
/// the socket to an event loop to write it.  The acceptor never writes
/// — a shed client that refuses to read its response used to stall the
/// accept loop (and with it every other client) for up to the shed
/// write timeout; now it merely occupies one `pollfd` until the write
/// deadline expires.
fn shed_connection(
    stream: TcpStream,
    loops: &[Arc<LoopShared>],
    rr: &mut usize,
    router: &Router,
) {
    // ORDERING: /stats counter
    router.http.connections_shed.fetch_add(1, Ordering::Relaxed);
    let retry = router.batcher.retry_after_secs();
    let body = error_body(
        429,
        "server overloaded: connection backlog full",
        Some(retry.max(1)),
    );
    let bytes = render_response(429, &body, true, 0, retry).into_bytes();
    // best-effort: if every intake queue is full too, the socket is
    // simply dropped (the peer sees a reset instead of the 429)
    let _ = hand_off(loops, rr, router.conn_backlog, Intake::Shed(stream, bytes));
}

/// Round-robin a connection to the first loop with intake capacity.
/// Returns false (dropping nothing — the caller still owns no socket
/// only on success) when every queue is at `backlog`.
fn hand_off(loops: &[Arc<LoopShared>], rr: &mut usize, backlog: usize, item: Intake) -> bool {
    for _ in 0..loops.len() {
        let l = &loops[*rr % loops.len()];
        *rr = rr.wrapping_add(1);
        let mut q = l.intake.lock().unwrap_or_else(|p| p.into_inner());
        if q.len() < backlog {
            q.push_back(item);
            drop(q);
            l.waker.wake();
            return true;
        }
    }
    false
}

/// The single teardown point for the admission gauge: every admitted
/// connection leaves through here exactly once — normal close, protocol
/// error, write failure, panic, or drain — so `active_connections`
/// cannot drift away from zero.
fn release_admitted(http: &HttpStats) {
    http.active_connections.fetch_sub(1, Ordering::AcqRel);
}

// -- per-connection state machine ------------------------------------------

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    /// Counted in `active_connections` (false for shed 429 writers).
    admitted: bool,
    /// Bytes read but not yet consumed by the parser or body — carries
    /// pipelined follow-up requests across responses.
    inbuf: Vec<u8>,
    state: State,
}

enum State {
    /// Accumulating the request line + headers through [`HeadParser`].
    /// `idle_deadline` is the keep-alive timeout armed when the
    /// connection went idle; `head_deadline` is armed once the request
    /// line arrives (the rest of the head must arrive promptly).
    ReadingHead { parser: HeadParser, idle_deadline: Instant, head_deadline: Option<Instant> },
    /// Head complete; accumulating `content_length` body bytes.
    ReadingBody { head: Head, body: Vec<u8>, deadline: Instant },
    /// Request handed to the batcher; the connection is parked (no
    /// thread waits) until the executor's notify pushes our token into
    /// the loop's completion queue.
    Dispatched { reply: PendingReply, keep_alive: bool },
    /// Writing a rendered response; `drain_after` runs the post-error
    /// read-and-discard before closing.
    Writing { buf: Vec<u8>, off: usize, close: bool, deadline: Instant, drain_after: bool },
    /// Best-effort bounded read-and-discard after an error response, so
    /// closing on unread request data doesn't turn into a TCP reset
    /// that wipes the response on the client side.
    Draining { deadline: Instant, drained: usize },
    /// Transient placeholder while an event is being processed; never
    /// observed between events.
    Moving,
}

impl State {
    /// Fresh between-requests state.
    fn reading(keep_alive_timeout: Duration) -> State {
        State::ReadingHead {
            parser: HeadParser::new(),
            idle_deadline: Instant::now() + keep_alive_timeout,
            head_deadline: None,
        }
    }

    /// The next instant at which this state times out, if any — drives
    /// the loops' deadline sweep.
    fn deadline(&self) -> Option<Instant> {
        match self {
            State::ReadingHead { idle_deadline, head_deadline, .. } => {
                Some(head_deadline.unwrap_or(*idle_deadline))
            }
            State::ReadingBody { deadline, .. }
            | State::Writing { deadline, .. }
            | State::Draining { deadline, .. } => Some(*deadline),
            State::Dispatched { .. } | State::Moving => None,
        }
    }
}

enum Flow {
    Keep,
    Close,
}

enum ReadSome {
    Data,
    Eof,
    WouldBlock,
    Err,
}

fn transient(kind: ErrorKind) -> bool {
    matches!(kind, ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted)
}

/// One nonblocking read into `inbuf`.
fn read_some(stream: &mut TcpStream, inbuf: &mut Vec<u8>) -> ReadSome {
    let mut scratch = [0u8; READ_CHUNK];
    loop {
        match stream.read(&mut scratch) {
            Ok(0) => return ReadSome::Eof,
            Ok(n) => {
                inbuf.extend_from_slice(&scratch[..n]);
                return ReadSome::Data;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if transient(e.kind()) => return ReadSome::WouldBlock,
            Err(e) => {
                log::debug!("connection read error: {e}");
                return ReadSome::Err;
            }
        }
    }
}

/// Advance one connection as far as it can go right now.  Called on
/// socket readiness, batcher completion, and deadline ticks alike — the
/// state machine re-derives everything it needs, so spurious calls are
/// harmless.  Returns whether the connection stays in the loop.
fn advance(
    conn: &mut Conn,
    token: u64,
    shared: &Arc<LoopShared>,
    router: &Router,
    draining: bool,
) -> Flow {
    loop {
        let state = std::mem::replace(&mut conn.state, State::Moving);
        match state {
            State::ReadingHead { mut parser, idle_deadline, mut head_deadline } => {
                loop {
                    // parse whatever is already buffered
                    if !conn.inbuf.is_empty() {
                        let was_started = parser.started();
                        let (consumed, step) = parser.step(&conn.inbuf);
                        conn.inbuf.drain(..consumed);
                        if !was_started && parser.started() {
                            // the request line is in: the rest of the
                            // request must arrive promptly
                            head_deadline = Some(Instant::now() + router.request_deadline);
                        }
                        match step {
                            HeadStep::Done(head) => {
                                if head.content_length > router.max_body_bytes {
                                    // reject before reading a single body
                                    // byte (the drain discards what the
                                    // client insists on sending)
                                    let msg = format!(
                                        "request body of {} bytes exceeds {}",
                                        head.content_length, router.max_body_bytes
                                    );
                                    conn.state = error_response(413, &msg);
                                    break;
                                }
                                let deadline = head_deadline.unwrap_or_else(|| {
                                    Instant::now() + router.request_deadline
                                });
                                let body =
                                    Vec::with_capacity(head.content_length.min(64 << 10));
                                conn.state = State::ReadingBody { head, body, deadline };
                                break;
                            }
                            HeadStep::Bad { status, message } => {
                                conn.state = error_response(status, &message);
                                break;
                            }
                            HeadStep::NeedMore => {}
                        }
                    }
                    // deadlines: between requests an expiry is a silent
                    // close (keep-alive idle timeout); with a partial
                    // request in the buffer it is a 408
                    let now = Instant::now();
                    if let Some(d) = head_deadline {
                        if now >= d {
                            conn.state = error_response(408, "request timed out");
                            break;
                        }
                    } else if now >= idle_deadline {
                        if parser.idle() {
                            return Flow::Close;
                        }
                        conn.state = error_response(408, "request timed out");
                        break;
                    }
                    // a draining server closes idle connections; one with
                    // a request in progress finishes serving it first
                    if draining && parser.idle() {
                        return Flow::Close;
                    }
                    match read_some(&mut conn.stream, &mut conn.inbuf) {
                        ReadSome::Data => continue,
                        // EOF: clean between requests, torn mid-request —
                        // either way the connection closes silently
                        ReadSome::Eof => return Flow::Close,
                        ReadSome::WouldBlock => {
                            conn.state =
                                State::ReadingHead { parser, idle_deadline, head_deadline };
                            return Flow::Keep;
                        }
                        ReadSome::Err => return Flow::Close,
                    }
                }
            }
            State::ReadingBody { head, mut body, deadline } => {
                loop {
                    if !conn.inbuf.is_empty() && body.len() < head.content_length {
                        let take = (head.content_length - body.len()).min(conn.inbuf.len());
                        body.extend(conn.inbuf.drain(..take));
                    }
                    if body.len() == head.content_length {
                        conn.state = finish_request(head, body, token, shared, router, draining);
                        break;
                    }
                    if Instant::now() >= deadline {
                        conn.state = error_response(408, "request body timed out");
                        break;
                    }
                    match read_some(&mut conn.stream, &mut conn.inbuf) {
                        ReadSome::Data => continue,
                        // connection closed mid-body: nothing to answer
                        ReadSome::Eof => return Flow::Close,
                        ReadSome::WouldBlock => {
                            conn.state = State::ReadingBody { head, body, deadline };
                            return Flow::Keep;
                        }
                        ReadSome::Err => return Flow::Close,
                    }
                }
            }
            State::Dispatched { reply, keep_alive } => match reply.try_take() {
                None => {
                    // spurious wake (or not our completion yet): park again
                    conn.state = State::Dispatched { reply, keep_alive };
                    return Flow::Keep;
                }
                Some(outcome) => {
                    let (status, body) = match outcome {
                        Ok(resp) => (200, resp.to_json().to_string()),
                        Err(e) => router.submit_error(e),
                    };
                    conn.state = response(router, status, &body, keep_alive, draining);
                }
            },
            State::Writing { buf, mut off, close, deadline, drain_after } => {
                if Instant::now() >= deadline {
                    // stuck peer: give up on the write, close silently
                    return Flow::Close;
                }
                loop {
                    if off == buf.len() {
                        break;
                    }
                    match conn.stream.write(&buf[off..]) {
                        Ok(0) => return Flow::Close,
                        Ok(n) => off += n,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) if transient(e.kind()) => {
                            conn.state =
                                State::Writing { buf, off, close, deadline, drain_after };
                            return Flow::Keep;
                        }
                        Err(_) => return Flow::Close,
                    }
                }
                // response fully written
                if drain_after {
                    conn.state =
                        State::Draining { deadline: Instant::now() + DRAIN_CAP_TIME, drained: 0 };
                } else if close {
                    return Flow::Close;
                } else {
                    // back to keep-alive; pipelined bytes already in
                    // `inbuf` parse immediately on the next pass
                    conn.state = State::reading(router.keep_alive_timeout);
                }
            }
            State::Draining { deadline, mut drained } => {
                if Instant::now() >= deadline {
                    return Flow::Close;
                }
                drained += conn.inbuf.len();
                conn.inbuf.clear();
                loop {
                    if drained >= DRAIN_CAP_BYTES {
                        return Flow::Close;
                    }
                    let mut scratch = [0u8; READ_CHUNK];
                    match conn.stream.read(&mut scratch) {
                        Ok(0) => return Flow::Close,
                        Ok(n) => drained += n,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) if transient(e.kind()) => {
                            conn.state = State::Draining { deadline, drained };
                            return Flow::Keep;
                        }
                        Err(_) => return Flow::Close,
                    }
                }
            }
            // unreachable by construction (Moving only exists inside one
            // advance call); treat defensively as a teardown
            State::Moving => return Flow::Close,
        }
    }
}

/// A parsed request is in: count it, route it (supervised), and decide
/// what the connection does next — write an immediate response, or park
/// on the batcher's async reply.
fn finish_request(
    head: Head,
    body: Vec<u8>,
    token: u64,
    shared: &Arc<LoopShared>,
    router: &Router,
    draining: bool,
) -> State {
    // ORDERING: /stats counter
    router.http.requests.fetch_add(1, Ordering::Relaxed);
    // supervise routing separately from the connection loop: a panic
    // while handling a parsed request still owes the client a
    // well-formed response — 503 + close, never a silently dropped
    // socket with a request outstanding
    let routed = catch_unwind(AssertUnwindSafe(|| {
        if let Some(e) = failpoint::inject("http.worker") {
            let retry = router.batcher.retry_after_secs().max(1);
            return Routed::Done(503, error_body(503, &format!("{e:#}"), Some(retry)));
        }
        router.route(&head, &body, token, shared)
    }));
    match routed {
        Ok(Routed::Done(status, body_json)) => {
            response(router, status, &body_json, head.keep_alive, draining)
        }
        Ok(Routed::Dispatched(reply)) => State::Dispatched { reply, keep_alive: head.keep_alive },
        Err(_) => {
            // ORDERING: /stats counter
            router.http.worker_panics.fetch_add(1, Ordering::Relaxed);
            log::error!("request handler panicked; answering 503 and closing the connection");
            let retry = router.batcher.retry_after_secs().max(1);
            let body_json = error_body(
                503,
                "request handler panicked; retry on a fresh connection",
                Some(retry),
            );
            // a connection that just survived a panic is suspect: close
            response(router, 503, &body_json, false, draining)
        }
    }
}

/// Render a routed response into a write state.  A draining server (or
/// a request that asked for it) closes after this response.
fn response(router: &Router, status: u16, body: &str, keep_alive: bool, draining: bool) -> State {
    let close = !keep_alive || draining;
    // shed and not-ready responses tell the client when to come back,
    // from live queue depth x measured batch latency
    let retry = if status == 429 || status == 503 { router.batcher.retry_after_secs() } else { 0 };
    let keep_alive_secs = router.keep_alive_timeout.as_secs().max(1);
    let buf = render_response(status, body, close, keep_alive_secs, retry).into_bytes();
    State::Writing {
        buf,
        off: 0,
        close,
        deadline: Instant::now() + WRITE_TIMEOUT,
        drain_after: false,
    }
}

/// Render a protocol-error response (400/408/413/431): always closes,
/// and drains what the client is still sending (e.g. the body of an
/// oversized POST) before the close, so the error response isn't wiped
/// out by a TCP reset on unread data.
fn error_response(status: u16, message: &str) -> State {
    let body = error_body(status, message, None);
    let buf = render_response(status, &body, true, 0, 0).into_bytes();
    State::Writing {
        buf,
        off: 0,
        close: true,
        deadline: Instant::now() + WRITE_TIMEOUT,
        drain_after: true,
    }
}

// -- the event loop --------------------------------------------------------

/// Run one connection through [`advance`] under panic supervision.  A
/// panic anywhere in the parse/serve path kills this connection, not
/// this loop thread — otherwise each panic would silently shrink the
/// serving capacity until nothing serves.
fn drive(
    conns: &mut HashMap<u64, Conn>,
    token: u64,
    shared: &Arc<LoopShared>,
    router: &Router,
    draining: bool,
) {
    let Some(mut conn) = conns.remove(&token) else { return };
    match catch_unwind(AssertUnwindSafe(|| advance(&mut conn, token, shared, router, draining))) {
        Ok(Flow::Keep) => {
            conns.insert(token, conn);
        }
        Ok(Flow::Close) => close_conn(conn, router),
        Err(_) => {
            // ORDERING: /stats counter
            router.http.worker_panics.fetch_add(1, Ordering::Relaxed);
            log::error!(
                "http event loop caught a panic serving a connection; \
                 connection dropped, loop continues"
            );
            close_conn(conn, router);
        }
    }
}

fn close_conn(conn: Conn, router: &Router) {
    if conn.admitted {
        release_admitted(&router.http);
    }
    // dropping `conn.stream` closes the socket
}

fn event_loop(shared: &Arc<LoopShared>, router: &Router, shutdown: &AtomicBool) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token: u64 = 0;
    let mut fds: Vec<poll::pollfd> = Vec::new();
    let mut fd_tokens: Vec<u64> = Vec::new();
    let mut scratch: Vec<u64> = Vec::new();
    loop {
        // ORDERING: polled drain flag, re-read every loop iteration (a
        // wake from the acceptor makes the drain prompt)
        let draining = shutdown.load(Ordering::Relaxed);

        // adopt handed-off connections
        let intake: Vec<Intake> = {
            let mut q = shared.intake.lock().unwrap_or_else(|p| p.into_inner());
            q.drain(..).collect()
        };
        for item in intake {
            match item {
                Intake::Accepted(stream) => {
                    if draining {
                        // admitted before the drain flag flipped, never
                        // adopted: release the admission gauge
                        release_admitted(&router.http);
                        continue;
                    }
                    next_token += 1;
                    conns.insert(
                        next_token,
                        Conn {
                            stream,
                            admitted: true,
                            inbuf: Vec::new(),
                            state: State::reading(router.keep_alive_timeout),
                        },
                    );
                }
                Intake::Shed(stream, buf) => {
                    next_token += 1;
                    conns.insert(
                        next_token,
                        Conn {
                            stream,
                            admitted: false,
                            inbuf: Vec::new(),
                            state: State::Writing {
                                buf,
                                off: 0,
                                close: true,
                                deadline: Instant::now() + SHED_WRITE_TIMEOUT,
                                drain_after: true,
                            },
                        },
                    );
                    // write the 429 immediately if the socket allows
                    drive(&mut conns, next_token, shared, router, draining);
                }
            }
        }

        // completed dispatches (the executor's notify pushed our tokens)
        scratch.clear();
        {
            let mut done = shared.completions.lock().unwrap_or_else(|p| p.into_inner());
            scratch.append(&mut done);
        }
        for i in 0..scratch.len() {
            drive(&mut conns, scratch[i], shared, router, draining);
        }

        // wait for readiness (or a wake, or the tick)
        fds.clear();
        fd_tokens.clear();
        fds.push(poll::entry(shared.waker.read_fd(), POLLIN));
        for (&token, conn) in conns.iter() {
            let events = match conn.state {
                State::ReadingHead { .. } | State::ReadingBody { .. } | State::Draining { .. } => {
                    POLLIN
                }
                State::Writing { .. } => POLLOUT,
                // parked on the batcher: no socket interest (responses
                // are triggered by the completion queue, not the peer)
                State::Dispatched { .. } | State::Moving => continue,
            };
            fds.push(poll::entry(conn.stream.as_raw_fd(), events));
            fd_tokens.push(token);
        }
        match poll::poll(&mut fds, Some(POLL_TICK)) {
            Ok(_) => {}
            Err(e) => {
                log::warn!("poll failed: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        if fds[0].revents != 0 {
            shared.waker.drain();
        }
        scratch.clear();
        for (f, &token) in fds[1..].iter().zip(fd_tokens.iter()) {
            // POLLERR/POLLHUP surface through the read/write attempt
            if f.revents != 0 {
                scratch.push(token);
            }
        }
        for i in 0..scratch.len() {
            drive(&mut conns, scratch[i], shared, router, draining);
        }

        // deadline sweep: keep-alive idle closes, 408s, write timeouts,
        // drain caps — and, while draining, idle connection teardown
        let now = Instant::now();
        scratch.clear();
        for (&token, conn) in conns.iter() {
            let due = match &conn.state {
                State::ReadingHead { parser, .. } if draining && parser.idle() => true,
                s => s.deadline().is_some_and(|d| now >= d),
            };
            if due {
                scratch.push(token);
            }
        }
        for i in 0..scratch.len() {
            drive(&mut conns, scratch[i], shared, router, draining);
        }

        if draining && conns.is_empty() {
            // adopt-then-exit race: release anything still parked in the
            // intake queue (the sockets drop, which the peers see as a
            // reset — same contract as the old bounded accept queue)
            let leftover: Vec<Intake> = {
                let mut q = shared.intake.lock().unwrap_or_else(|p| p.into_inner());
                q.drain(..).collect()
            };
            for item in leftover {
                if let Intake::Accepted(_) = item {
                    release_admitted(&router.http);
                }
            }
            return;
        }
    }
}

// -- request parsing -------------------------------------------------------

/// Everything parsed from one request head.
#[derive(Debug)]
struct Head {
    method: String,
    path: String,
    keep_alive: bool,
    content_length: usize,
}

/// Incremental HTTP/1.x head parser: feed byte chunks through
/// [`HeadParser::step`]; it consumes up to a full head and reports how
/// far it got.  Keep-alive defaults on for HTTP/1.1 and off for
/// HTTP/1.0; a `Connection` header overrides either way.  All limits
/// (line length, header count) are enforced *during* accumulation, so a
/// hostile slow sender is rejected as soon as it crosses one.
struct HeadParser {
    /// The current partial line (no terminator yet).
    line: Vec<u8>,
    /// Method + path once the request line has arrived.
    request_line: Option<(String, String)>,
    keep_alive: bool,
    content_length: usize,
    headers_seen: usize,
}

#[derive(Debug)]
enum HeadStep {
    /// More bytes needed; everything given was consumed.
    NeedMore,
    /// A full head was parsed; unconsumed bytes start the body.
    Done(Head),
    /// The peer sent something we must reject; respond and close.
    Bad { status: u16, message: String },
}

impl HeadParser {
    fn new() -> HeadParser {
        HeadParser {
            line: Vec::new(),
            request_line: None,
            keep_alive: false,
            content_length: 0,
            headers_seen: 0,
        }
    }

    /// True until any request bytes arrive.  Between requests, deadline
    /// expiry and shutdown close the connection silently; once a
    /// partial request exists, the same expiry is a 408.
    fn idle(&self) -> bool {
        self.request_line.is_none() && self.line.is_empty()
    }

    /// True once the full request line has arrived — the moment the
    /// per-request deadline starts (a half-sent request must not hold
    /// its state past it).
    fn started(&self) -> bool {
        self.request_line.is_some()
    }

    /// Consume bytes from `data`; returns `(bytes_consumed, step)`.
    /// On [`HeadStep::Done`] / [`HeadStep::Bad`] the remainder past
    /// `bytes_consumed` was not touched (body bytes, or pipelined junk
    /// for the drain to discard).
    fn step(&mut self, data: &[u8]) -> (usize, HeadStep) {
        let mut consumed = 0usize;
        loop {
            let Some(pos) = data[consumed..].iter().position(|&b| b == b'\n') else {
                self.line.extend_from_slice(&data[consumed..]);
                consumed = data.len();
                // reject over-long lines mid-accumulation: a slow drip
                // of an unbounded line must not grow the buffer forever
                if self.line.len() > MAX_LINE_BYTES {
                    return (
                        consumed,
                        HeadStep::Bad {
                            status: 431,
                            message: format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                        },
                    );
                }
                return (consumed, HeadStep::NeedMore);
            };
            self.line.extend_from_slice(&data[consumed..consumed + pos]);
            consumed += pos + 1;
            if self.line.len() > MAX_LINE_BYTES {
                return (
                    consumed,
                    HeadStep::Bad {
                        status: 431,
                        message: format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                    },
                );
            }
            let mut raw = std::mem::take(&mut self.line);
            if raw.last() == Some(&b'\r') {
                raw.pop();
            }
            let line = match String::from_utf8(raw) {
                Ok(l) => l,
                Err(_) => {
                    return (
                        consumed,
                        HeadStep::Bad { status: 400, message: "request is not utf-8".into() },
                    )
                }
            };
            match self.take_line(line) {
                None => continue,
                Some(step) => return (consumed, step),
            }
        }
    }

    /// Digest one complete line; `Some` ends the head (done or bad).
    fn take_line(&mut self, line: String) -> Option<HeadStep> {
        if self.request_line.is_none() {
            let mut parts = line.split_whitespace();
            let method = parts.next().unwrap_or("").to_string();
            let path = parts.next().unwrap_or("").to_string();
            let version = parts.next().unwrap_or("");
            if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/") {
                return Some(HeadStep::Bad {
                    status: 400,
                    message: format!("malformed request line '{line}'"),
                });
            }
            self.keep_alive = version == "HTTP/1.1";
            self.request_line = Some((method, path));
            return None;
        }
        if line.is_empty() {
            // blank line: head complete
            let (method, path) = self.request_line.take().unwrap_or_default();
            return Some(HeadStep::Done(Head {
                method,
                path,
                keep_alive: self.keep_alive,
                content_length: self.content_length,
            }));
        }
        // exactly MAX_HEADERS headers (plus the terminating blank line)
        // are accepted; one more is a 431
        if self.headers_seen == MAX_HEADERS {
            return Some(HeadStep::Bad {
                status: 431,
                message: format!("more than {MAX_HEADERS} request headers"),
            });
        }
        self.headers_seen += 1;
        if let Some((name, value)) = line.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                match value.parse() {
                    Ok(n) => self.content_length = n,
                    Err(_) => {
                        return Some(HeadStep::Bad {
                            status: 400,
                            message: format!("bad Content-Length '{value}'"),
                        })
                    }
                }
            } else if name.eq_ignore_ascii_case("connection") {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    self.keep_alive = false;
                } else if v.contains("keep-alive") {
                    self.keep_alive = true;
                }
            }
        }
        None
    }
}

// -- routing ---------------------------------------------------------------

struct Router {
    batcher: Arc<Batcher>,
    bpe: Arc<Bpe>,
    http: Arc<HttpStats>,
    workers: usize,
    keep_alive_timeout: Duration,
    max_body_bytes: usize,
    request_deadline: Duration,
    max_connections: usize,
    conn_backlog: usize,
}

/// What routing decided: an immediate response, or a request parked on
/// the batcher (the connection waits in [`State::Dispatched`]).
enum Routed {
    Done(u16, String),
    Dispatched(PendingReply),
}

impl Router {
    fn route(&self, head: &Head, body: &[u8], token: u64, shared: &Arc<LoopShared>) -> Routed {
        match (head.method.as_str(), head.path.as_str()) {
            // liveness: 200 whenever the process can answer at all —
            // restarting into degraded still means "don't kill me"
            ("GET", "/healthz") => {
                let state = self.batcher.health().state();
                Routed::Done(200, format!(r#"{{"ok": true, "state": "{}"}}"#, state.as_str()))
            }
            // readiness: 200 only when the executor is up and serving;
            // a degraded/draining instance tells the balancer to route
            // elsewhere without being restarted
            ("GET", "/readyz") => {
                let state = self.batcher.health().state();
                if state == HealthState::Ready {
                    Routed::Done(200, format!(r#"{{"state": "{}"}}"#, state.as_str()))
                } else {
                    let retry = self.batcher.retry_after_secs().max(1);
                    let msg = format!("not ready (state {})", state.as_str());
                    Routed::Done(503, error_body(503, &msg, Some(retry)))
                }
            }
            ("GET", "/stats") => Routed::Done(200, self.stats_json()),
            // /v1/predict is the canonical route (docs/api.md); the
            // unversioned path stays as a compatibility alias
            ("POST", "/predict") | ("POST", "/v1/predict") => self.predict(body, token, shared),
            _ => Routed::Done(404, error_body(404, "not found", None)),
        }
    }

    fn predict(&self, body: &[u8], token: u64, shared: &Arc<LoopShared>) -> Routed {
        let text = match std::str::from_utf8(body) {
            Ok(t) => t,
            Err(_) => return Routed::Done(400, error_body(400, "body is not utf-8", None)),
        };
        let parsed = json::parse(text)
            .map_err(|e| anyhow!(e))
            .and_then(|v| PredictRequest::from_json(&v));
        let req = match parsed {
            Ok(r) => r,
            Err(e) => return Routed::Done(400, error_body(400, &format!("{e:#}"), None)),
        };
        // the notify runs on the executor thread with no locks held: it
        // queues our token and interrupts this connection's event loop
        let notify: ReplyNotify = {
            let shared = shared.clone();
            Arc::new(move || {
                {
                    let mut done =
                        shared.completions.lock().unwrap_or_else(|p| p.into_inner());
                    done.push(token);
                }
                shared.waker.wake();
            })
        };
        match self.batcher.submit_bounded_async(&self.bpe, &req, notify) {
            Ok(reply) => Routed::Dispatched(reply),
            Err(e) => {
                let (status, body) = self.submit_error(e);
                Routed::Done(status, body)
            }
        }
    }

    /// Map a batcher rejection (or a completed dispatch's error) onto
    /// the wire contract.  The retryable statuses mirror `Retry-After`
    /// into the body so JSON-only clients can back off without parsing
    /// headers.
    fn submit_error(&self, e: SubmitError) -> (u16, String) {
        let retry = || Some(self.batcher.retry_after_secs().max(1));
        match e {
            SubmitError::BadRequest(m) => (400, error_body(400, &m, None)),
            e @ SubmitError::Overloaded { .. } => (429, error_body(429, &e.to_string(), retry())),
            // executor died mid-request and the supervisor is restarting
            // it: retryable, so 503 (+ Retry-After), not 500
            e @ SubmitError::Unavailable(_) => (503, error_body(503, &e.to_string(), retry())),
            // the request expired in queue before the backend saw it
            e @ SubmitError::Timeout { .. } => (504, error_body(504, &e.to_string(), None)),
            SubmitError::Internal(m) => (500, error_body(500, &m, None)),
        }
    }

    fn stats_json(&self) -> String {
        let s = self.batcher.stats_snapshot();
        let health = self.batcher.health();
        let mean_req = if s.requests > 0 {
            s.total_request_latency_ms / s.requests as f64
        } else {
            0.0
        };
        let mean_exec =
            if s.batches > 0 { s.total_exec_latency_ms / s.batches as f64 } else { 0.0 };
        let memory = match &s.memory {
            Some(m) => {
                let shards = m
                    .per_shard
                    .iter()
                    .map(|p| {
                        format!(
                            r#"{{"shard": {}, "rows": {}, "hits": {}, "utilization": {:.6}}}"#,
                            p.shard, p.rows, p.hits, p.utilization
                        )
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    r#", "memory_utilization": {:.6}, "memory_kl": {:.6}, "shards": [{shards}]"#,
                    m.utilization, m.kl_from_uniform
                )
            }
            None => String::new(),
        };
        // which trained weights are live (absent on seed/artifact);
        // the id comes from a user-editable manifest, so emit it
        // through the JSON writer rather than raw interpolation
        let checkpoint = match &s.checkpoint {
            Some(id) => {
                format!(r#", "checkpoint": {}"#, Json::Str(id.clone()).to_string())
            }
            None => String::new(),
        };
        format!(
            r#"{{"schema_version": 1, "backend": "{}", "state": "{}", "restarts": {}, "requests": {}, "batches": {}, "mean_request_latency_ms": {:.3}, "mean_exec_latency_ms": {:.3}, "latency_p50_ms": {:.3}, "latency_p95_ms": {:.3}, "latency_p99_ms": {:.3}, "max_batch_fill": {}, "truncated_masks": {}, "timeouts": {}, "shed": {}, "queue_depth": {}, "max_pending": {}, "http_workers": {}, "active_connections": {}, "connections_accepted": {}, "connections_shed": {}, "http_requests": {}, "worker_panics": {}{}{}}}"#,
            s.backend,
            health.state().as_str(),
            health.restarts(),
            s.requests,
            s.batches,
            mean_req,
            mean_exec,
            s.latency.percentile_ms(0.50),
            s.latency.percentile_ms(0.95),
            s.latency.percentile_ms(0.99),
            s.max_batch_fill,
            s.truncated_masks,
            s.timeouts,
            s.shed,
            self.batcher.queue_depth(),
            self.batcher.max_pending(),
            self.workers,
            // ORDERING: /stats snapshot reads of monotonic counters; the
            // report is advisory and needs no cross-counter consistency
            self.http.active_connections.load(Ordering::Relaxed),
            self.http.connections_accepted.load(Ordering::Relaxed),
            self.http.connections_shed.load(Ordering::Relaxed),
            self.http.requests.load(Ordering::Relaxed),
            self.http.worker_panics.load(Ordering::Relaxed),
            memory,
            checkpoint
        )
    }
}

// -- responses -------------------------------------------------------------

/// Machine-readable error code, one per status the front door emits —
/// the stable half of the error contract (`docs/api.md`): messages are
/// for humans and may change, codes are for clients and must not.
fn error_code(status: u16) -> &'static str {
    match status {
        400 => "bad_request",
        404 => "not_found",
        408 => "request_timeout",
        413 => "payload_too_large",
        429 => "overloaded",
        431 => "headers_too_large",
        503 => "unavailable",
        504 => "deadline_exceeded",
        _ => "internal",
    }
}

/// The single source of every 4xx/5xx body:
/// `{"error": {"code", "message", "retry_after_s"?}}`.  `retry_after_s`
/// mirrors the `Retry-After` header on retryable statuses so JSON-only
/// clients never need to parse headers.
fn error_body(status: u16, message: &str, retry_after_s: Option<u64>) -> String {
    let mut fields = vec![
        ("code", Json::Str(error_code(status).to_string())),
        ("message", Json::Str(message.to_string())),
    ];
    if let Some(s) = retry_after_s {
        fields.push(("retry_after_s", Json::Num(s as f64)));
    }
    Json::obj(vec![("error", Json::obj(fields))]).to_string()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    }
}

/// Render a full response (head + body) into one buffer for the
/// nonblocking write path.  Byte-identical to what the worker-pool
/// front door wrote: status line, `Content-Type`/`Content-Length`,
/// `Retry-After` on the retryable statuses, and either `Connection:
/// close` or `Connection: keep-alive` + `Keep-Alive: timeout=`.
fn render_response(
    status: u16,
    body: &str,
    close: bool,
    keep_alive_secs: u64,
    retry_after_secs: u64,
) -> String {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        reason(status),
        body.len()
    );
    if status == 429 || status == 503 {
        // adaptive back-off (queue depth x mean batch latency); the
        // floor of 1 keeps the header meaningful even with no history.
        // 503s carry it too: "executor restarting" and "not ready" are
        // both retryable conditions with a meaningful come-back time
        head.push_str(&format!("Retry-After: {}\r\n", retry_after_secs.max(1)));
    }
    if close {
        head.push_str("Connection: close\r\n\r\n");
    } else {
        head.push_str(&format!(
            "Connection: keep-alive\r\nKeep-Alive: timeout={keep_alive_secs}\r\n\r\n"
        ));
    }
    head.push_str(body);
    head
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the incremental parser over a fully buffered request, the
    /// way the event loop does when everything arrived at once.
    /// Returns the step plus the unconsumed remainder (body bytes).
    fn parse(raw: &[u8]) -> (HeadStep, Vec<u8>) {
        let mut p = HeadParser::new();
        let (consumed, step) = p.step(raw);
        (step, raw[consumed..].to_vec())
    }

    fn head_of(step: HeadStep) -> Head {
        match step {
            HeadStep::Done(h) => h,
            other => panic!("expected a parsed head, got {other:?}"),
        }
    }

    #[test]
    fn parses_post_with_body_and_keeps_alive_by_default() {
        let (step, rest) =
            parse(b"POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello");
        let head = head_of(step);
        assert_eq!(head.method, "POST");
        assert_eq!(head.path, "/predict");
        assert!(head.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(head.content_length, 5);
        assert_eq!(rest, b"hello", "body bytes stay unconsumed for the body reader");
    }

    #[test]
    fn connection_close_is_honoured() {
        let (step, _) = parse(b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!head_of(step).keep_alive);
    }

    #[test]
    fn http_10_defaults_to_close_but_can_opt_in() {
        let (plain, _) = parse(b"GET / HTTP/1.0\r\n\r\n");
        assert!(!head_of(plain).keep_alive);
        let (opted, _) = parse(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(head_of(opted).keep_alive);
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let raw =
            b"GET /healthz HTTP/1.1\r\n\r\nPOST /predict HTTP/1.1\r\nContent-Length: 2\r\n\r\nok";
        let (a, rest) = parse(raw);
        assert_eq!(head_of(a).path, "/healthz");
        // a fresh parser picks up the very next buffered request
        let (b, body) = parse(&rest);
        let b = head_of(b);
        assert_eq!(b.path, "/predict");
        assert_eq!(b.content_length, 2);
        assert_eq!(body, b"ok");
    }

    #[test]
    fn byte_at_a_time_feeding_parses_identically() {
        // the event loop may receive any fragmentation; feed the worst
        let raw = b"POST /predict HTTP/1.1\r\nContent-Length: 2\r\n\r\nok";
        let mut p = HeadParser::new();
        let mut done = None;
        let mut used = 0;
        for (i, b) in raw.iter().enumerate() {
            let (consumed, step) = p.step(std::slice::from_ref(b));
            match step {
                HeadStep::NeedMore => assert_eq!(consumed, 1),
                HeadStep::Done(h) => {
                    done = Some(h);
                    used = i + 1;
                    break;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        let h = done.expect("head must complete");
        assert_eq!(h.path, "/predict");
        assert_eq!(h.content_length, 2);
        assert_eq!(&raw[used..], b"ok");
    }

    #[test]
    fn fresh_parser_is_idle_and_any_byte_ends_idleness() {
        let mut p = HeadParser::new();
        assert!(p.idle(), "no bytes yet: timeout closes silently");
        let (_, step) = p.step(b"G");
        assert!(matches!(step, HeadStep::NeedMore));
        assert!(!p.idle(), "a partial request line must 408, not close silently");
        assert!(!p.started(), "the request deadline arms only on a full request line");
        let (_, step) = p.step(b"ET /x HTTP/1.1\r\n");
        assert!(matches!(step, HeadStep::NeedMore));
        assert!(p.started(), "request line in: the request deadline starts");
    }

    #[test]
    fn malformed_request_line_is_400() {
        let (step, _) = parse(b"NOT-HTTP\r\n\r\n");
        match step {
            HeadStep::Bad { status: 400, message } => {
                assert!(message.contains("malformed request line"), "{message}")
            }
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn non_utf8_line_is_400() {
        let (step, _) = parse(b"GET /\xff\xfe HTTP/1.1\r\n\r\n");
        match step {
            HeadStep::Bad { status: 400, message } => assert!(message.contains("utf-8")),
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn bad_content_length_is_400() {
        let (step, _) = parse(b"POST /p HTTP/1.1\r\nContent-Length: nope\r\n\r\n");
        match step {
            HeadStep::Bad { status: 400, message } => {
                assert!(message.contains("Content-Length"), "{message}")
            }
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn oversized_line_is_431_even_without_a_terminator() {
        // a slow-loris line that never ends must be rejected as soon as
        // it crosses the cap, not buffered forever
        let mut p = HeadParser::new();
        let chunk = vec![b'a'; MAX_LINE_BYTES / 2];
        assert!(matches!(p.step(&chunk).1, HeadStep::NeedMore));
        assert!(matches!(p.step(&chunk).1, HeadStep::NeedMore));
        match p.step(b"aa").1 {
            HeadStep::Bad { status: 431, message } => {
                assert!(message.contains("request line exceeds"), "{message}")
            }
            other => panic!("expected 431, got {other:?}"),
        }
    }

    #[test]
    fn too_many_headers_is_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..=MAX_HEADERS {
            raw.extend_from_slice(format!("X-{i}: v\r\n").as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        let (step, _) = parse(&raw);
        match step {
            HeadStep::Bad { status: 431, message } => {
                assert!(message.contains("request headers"), "{message}")
            }
            other => panic!("expected 431, got {other:?}"),
        }
        // exactly MAX_HEADERS is still fine
        let mut ok = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..MAX_HEADERS {
            ok.extend_from_slice(format!("X-{i}: v\r\n").as_bytes());
        }
        ok.extend_from_slice(b"\r\n");
        let (step, _) = parse(&ok);
        assert_eq!(head_of(step).path, "/");
    }

    #[test]
    fn short_body_leaves_the_connection_waiting_for_more() {
        // "POST with Content-Length: 10 but only 5 bytes" is not a parse
        // error: the body reader keeps waiting and the request deadline
        // (or EOF) decides the outcome — same as the blocking reader
        let (step, rest) = parse(b"POST /p HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort");
        let head = head_of(step);
        assert_eq!(head.content_length, 10);
        assert!(rest.len() < head.content_length, "body incomplete: keep reading");
    }

    #[test]
    fn render_response_matches_the_wire_contract() {
        let keep = render_response(200, "{}", false, 5, 0);
        assert!(keep.starts_with("HTTP/1.1 200 OK\r\n"), "{keep}");
        assert!(keep.contains("Content-Type: application/json\r\n"), "{keep}");
        assert!(keep.contains("Content-Length: 2\r\n"), "{keep}");
        assert!(
            keep.contains("Connection: keep-alive\r\nKeep-Alive: timeout=5\r\n\r\n"),
            "{keep}"
        );
        assert!(!keep.contains("Retry-After"), "{keep}");

        let shed = render_response(429, "{}", true, 0, 7);
        assert!(shed.contains("Retry-After: 7\r\n"), "{shed}");
        assert!(shed.contains("Connection: close\r\n\r\n"), "{shed}");

        let nohist = render_response(503, "{}", true, 0, 0);
        assert!(nohist.contains("Retry-After: 1\r\n"), "floored at 1: {nohist}");
    }

    #[test]
    fn error_body_is_the_structured_envelope_and_escapes_via_json_writer() {
        let b = error_body(400, "a \"quoted\" failure", None);
        let v = json::parse(&b).unwrap();
        let e = v.get("error").expect("envelope has an error object");
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "bad_request");
        assert_eq!(e.get("message").unwrap().as_str().unwrap(), "a \"quoted\" failure");
        assert!(e.get("retry_after_s").is_none(), "no retry hint unless retryable");
    }

    #[test]
    fn retryable_errors_mirror_retry_after_into_the_body() {
        let b = error_body(429, "overloaded", Some(7));
        let v = json::parse(&b).unwrap();
        let e = v.get("error").unwrap();
        assert_eq!(e.get("code").unwrap().as_str().unwrap(), "overloaded");
        assert_eq!(e.get("retry_after_s").unwrap().as_f64().unwrap(), 7.0);
        // each front-door status maps to a stable machine-readable code
        for (status, code) in [
            (400, "bad_request"),
            (404, "not_found"),
            (408, "request_timeout"),
            (413, "payload_too_large"),
            (429, "overloaded"),
            (431, "headers_too_large"),
            (500, "internal"),
            (503, "unavailable"),
            (504, "deadline_exceeded"),
        ] {
            assert_eq!(error_code(status), code);
        }
    }
}
