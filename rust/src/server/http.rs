//! Minimal threaded HTTP/1.1 front door for the serving router
//! (std::net; tokio is unavailable offline).  One thread per connection —
//! batching happens downstream in [`super::batcher`], which is where the
//! coordination actually matters.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::tokenizer::Bpe;
use crate::util::json;

use super::api::PredictRequest;
use super::batcher::Batcher;

/// Serve until the process is killed.  Endpoints:
///   POST /predict  {"text": "... [MASK] ...", "top_k": 5}
///   GET  /healthz
///   GET  /stats
pub fn serve(addr: &str, batcher: Arc<Batcher>, bpe: Arc<Bpe>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log::info!("serving on http://{addr} (POST /predict)");
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log::warn!("accept failed: {e}");
                continue;
            }
        };
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle(stream, &batcher, &bpe) {
                log::debug!("connection error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle(mut stream: TcpStream, batcher: &Batcher, bpe: &Bpe) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    // headers: we only need Content-Length
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }

    let (status, body) = match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => (200, r#"{"ok": true}"#.to_string()),
        ("GET", "/stats") => {
            let s = batcher.stats.lock().unwrap().clone();
            let mean_req = if s.requests > 0 {
                s.total_request_latency_ms / s.requests as f64
            } else {
                0.0
            };
            let mean_exec =
                if s.batches > 0 { s.total_exec_latency_ms / s.batches as f64 } else { 0.0 };
            let memory = match (s.memory_utilization, s.memory_kl) {
                (Some(u), Some(kl)) => {
                    format!(r#", "memory_utilization": {u:.6}, "memory_kl": {kl:.6}"#)
                }
                _ => String::new(),
            };
            // which trained weights are live (absent on seed/artifact);
            // the id comes from a user-editable manifest, so emit it
            // through the JSON writer rather than raw interpolation
            let checkpoint = match &s.checkpoint {
                Some(id) => {
                    format!(r#", "checkpoint": {}"#, json::Json::Str(id.clone()).to_string())
                }
                None => String::new(),
            };
            (
                200,
                format!(
                    r#"{{"backend": "{}", "requests": {}, "batches": {}, "mean_request_latency_ms": {:.3}, "mean_exec_latency_ms": {:.3}, "max_batch_fill": {}, "truncated_masks": {}{}{}}}"#,
                    s.backend,
                    s.requests,
                    s.batches,
                    mean_req,
                    mean_exec,
                    s.max_batch_fill,
                    s.truncated_masks,
                    memory,
                    checkpoint
                ),
            )
        }
        ("POST", "/predict") => {
            let mut raw = vec![0u8; content_length];
            reader.read_exact(&mut raw)?;
            handle_post(&raw, batcher, bpe)
        }
        _ => (404, r#"{"error": "not found"}"#.to_string()),
    };
    respond(&mut stream, status, &body)
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

fn handle_post(body: &[u8], batcher: &Batcher, bpe: &Bpe) -> (u16, String) {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return (400, r#"{"error": "body is not utf-8"}"#.into()),
    };
    let parsed = json::parse(text)
        .map_err(|e| anyhow!(e))
        .and_then(|v| PredictRequest::from_json(&v));
    match parsed {
        Ok(req) => match batcher.submit(bpe, &req) {
            Ok(resp) => (200, resp.to_json().to_string()),
            Err(e) => (400, format!(r#"{{"error": "{e}"}}"#)),
        },
        Err(e) => (400, format!(r#"{{"error": "{e}"}}"#)),
    }
}
