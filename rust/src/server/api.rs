//! Request/response types for the MLM serving API.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// A fill-mask request.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub text: String,
    /// top-k predictions per mask (default 5)
    pub top_k: usize,
}

impl PredictRequest {
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(PredictRequest {
            text: v
                .req("text")?
                .as_str()
                .ok_or_else(|| anyhow!("'text' must be a string"))?
                .to_string(),
            top_k: v.get("top_k").and_then(Json::as_usize).unwrap_or(5),
        })
    }
}

/// One candidate token for a masked position.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenScore {
    pub token: String,
    pub logprob: f64,
}

/// Outcome for one `[MASK]` position.
///
/// A mask that the fixed sequence length truncated away can not be
/// predicted; that is an explicit per-mask error, never a silent empty
/// prediction list.
#[derive(Debug, Clone)]
pub enum MaskPrediction {
    /// Top-k candidates, logprob-descending.
    Scores(Vec<TokenScore>),
    /// The mask sat at token `position`, beyond the model's `seq_len`.
    Truncated { position: usize, seq_len: usize },
}

impl MaskPrediction {
    /// The candidate list, if this mask was predicted.
    pub fn scores(&self) -> Option<&[TokenScore]> {
        match self {
            MaskPrediction::Scores(s) => Some(s),
            MaskPrediction::Truncated { .. } => None,
        }
    }

    pub fn is_truncated(&self) -> bool {
        matches!(self, MaskPrediction::Truncated { .. })
    }

    /// Every mask serialises to an object — `{"scores": [...]}` or
    /// `{"error": ...}` — so the `masks` array stays homogeneous and
    /// clients can branch on one key.
    fn to_json(&self) -> Json {
        match self {
            MaskPrediction::Scores(cands) => Json::obj(vec![(
                "scores",
                Json::Arr(
                    cands
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("token", Json::Str(c.token.clone())),
                                ("logprob", Json::Num(c.logprob)),
                            ])
                        })
                        .collect(),
                ),
            )]),
            MaskPrediction::Truncated { position, seq_len } => Json::obj(vec![
                (
                    "error",
                    Json::Str(format!(
                        "mask at token position {position} was truncated \
                         (model seq_len is {seq_len})"
                    )),
                ),
                ("position", Json::Num(*position as f64)),
                ("seq_len", Json::Num(*seq_len as f64)),
            ]),
        }
    }
}

/// Response: predictions per `[MASK]` position, in order of appearance.
#[derive(Debug, Clone, Default)]
pub struct PredictResponse {
    pub masks: Vec<MaskPrediction>,
    /// true request latency: enqueue → reply, not just batch execution
    pub latency_ms: f64,
    pub batch_size: usize,
}

impl PredictResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("masks", Json::Arr(self.masks.iter().map(MaskPrediction::to_json).collect())),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("batch_size", Json::Num(self.batch_size as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parses_request() {
        let v = json::parse(r#"{"text": "a [MASK] b", "top_k": 3}"#).unwrap();
        let r = PredictRequest::from_json(&v).unwrap();
        assert_eq!(r.text, "a [MASK] b");
        assert_eq!(r.top_k, 3);
    }

    #[test]
    fn default_top_k() {
        let v = json::parse(r#"{"text": "x"}"#).unwrap();
        assert_eq!(PredictRequest::from_json(&v).unwrap().top_k, 5);
    }

    #[test]
    fn missing_text_is_error() {
        let v = json::parse(r#"{"top_k": 1}"#).unwrap();
        assert!(PredictRequest::from_json(&v).is_err());
    }

    #[test]
    fn response_serialises() {
        let resp = PredictResponse {
            masks: vec![MaskPrediction::Scores(vec![TokenScore {
                token: "cat".into(),
                logprob: -0.5,
            }])],
            latency_ms: 12.0,
            batch_size: 2,
        };
        let j = resp.to_json().to_string();
        let v = json::parse(&j).unwrap();
        assert_eq!(
            v.get("masks").unwrap().as_arr().unwrap()[0]
                .get("scores")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .get("token")
                .unwrap()
                .as_str()
                .unwrap(),
            "cat"
        );
    }

    #[test]
    fn truncated_mask_serialises_as_explicit_error() {
        let resp = PredictResponse {
            masks: vec![MaskPrediction::Truncated { position: 57, seq_len: 32 }],
            latency_ms: 1.0,
            batch_size: 1,
        };
        let v = json::parse(&resp.to_json().to_string()).unwrap();
        let m = &v.get("masks").unwrap().as_arr().unwrap()[0];
        assert!(m.get("error").unwrap().as_str().unwrap().contains("truncated"));
        assert_eq!(m.get("position").unwrap().as_usize().unwrap(), 57);
        assert_eq!(m.get("seq_len").unwrap().as_usize().unwrap(), 32);
        assert!(resp.masks[0].is_truncated());
        assert!(resp.masks[0].scores().is_none());
    }
}
