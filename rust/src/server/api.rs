//! Request/response types for the MLM serving API.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// A fill-mask request.
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub text: String,
    /// top-k predictions per mask (default 5)
    pub top_k: usize,
}

impl PredictRequest {
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(PredictRequest {
            text: v
                .req("text")?
                .as_str()
                .ok_or_else(|| anyhow!("'text' must be a string"))?
                .to_string(),
            top_k: v.get("top_k").and_then(Json::as_usize).unwrap_or(5),
        })
    }
}

/// One candidate token for a masked position.
#[derive(Debug, Clone)]
pub struct TokenScore {
    pub token: String,
    pub logprob: f64,
}

/// Response: predictions per `[MASK]` position, in order of appearance.
#[derive(Debug, Clone, Default)]
pub struct PredictResponse {
    pub masks: Vec<Vec<TokenScore>>,
    pub latency_ms: f64,
    pub batch_size: usize,
}

impl PredictResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "masks",
                Json::Arr(
                    self.masks
                        .iter()
                        .map(|cands| {
                            Json::Arr(
                                cands
                                    .iter()
                                    .map(|c| {
                                        Json::obj(vec![
                                            ("token", Json::Str(c.token.clone())),
                                            ("logprob", Json::Num(c.logprob)),
                                        ])
                                    })
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("batch_size", Json::Num(self.batch_size as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parses_request() {
        let v = json::parse(r#"{"text": "a [MASK] b", "top_k": 3}"#).unwrap();
        let r = PredictRequest::from_json(&v).unwrap();
        assert_eq!(r.text, "a [MASK] b");
        assert_eq!(r.top_k, 3);
    }

    #[test]
    fn default_top_k() {
        let v = json::parse(r#"{"text": "x"}"#).unwrap();
        assert_eq!(PredictRequest::from_json(&v).unwrap().top_k, 5);
    }

    #[test]
    fn missing_text_is_error() {
        let v = json::parse(r#"{"top_k": 1}"#).unwrap();
        assert!(PredictRequest::from_json(&v).is_err());
    }

    #[test]
    fn response_serialises() {
        let resp = PredictResponse {
            masks: vec![vec![TokenScore { token: "cat".into(), logprob: -0.5 }]],
            latency_ms: 12.0,
            batch_size: 2,
        };
        let j = resp.to_json().to_string();
        let v = json::parse(&j).unwrap();
        assert_eq!(
            v.get("masks").unwrap().as_arr().unwrap()[0].as_arr().unwrap()[0]
                .get("token")
                .unwrap()
                .as_str()
                .unwrap(),
            "cat"
        );
    }
}
